"""Yield-study demo (core/yield_study.py).

Wafer-scale parts ship with dead NPUs — does the strategy auto-chosen
for the pristine wafer survive the wafer you actually get?  For each
requested registry architecture: run the defect-free sweep, pick the
winner with the auto-strategy tiebreak, draw N defect masks at the
target dead-NPU rate, and report per mask whether the winner survives
(with its degraded slowdown) or which fallback strategy the degraded
re-sweep picks instead.

    PYTHONPATH=src python examples/yield_study.py [--archs a,b,...]
        [--shape train_4k] [--npus 20] [--masks 32] [--dead-rate 0.02]
        [--dead-link-rate 0.0] [--seed0 0] [--csv]
"""

import argparse


def main():
    from repro.core.yield_study import (YIELD_CSV_HEADER, model_yield_study,
                                        yield_csv_rows)

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", type=str, default="llama3.2-1b,qwen3-32b")
    ap.add_argument("--shape", type=str, default="train_4k")
    ap.add_argument("--npus", type=int, default=20, help="NPUs per wafer")
    ap.add_argument("--masks", type=int, default=32,
                    help="independent defect draws per arch")
    ap.add_argument("--dead-rate", type=float, default=0.02,
                    help="target dead-NPU rate per draw")
    ap.add_argument("--dead-link-rate", type=float, default=0.0,
                    help="dead mesh-link rate (baseline winners only)")
    ap.add_argument("--seed0", type=int, default=0)
    ap.add_argument("--csv", action="store_true",
                    help="emit the per-mask CSV instead of the summary")
    args = ap.parse_args()

    reports = [model_yield_study(
        arch, args.shape, n_npus=args.npus, n_masks=args.masks,
        dead_npu_rate=args.dead_rate, dead_link_rate=args.dead_link_rate,
        seed0=args.seed0) for arch in args.archs.split(",")]

    if args.csv:
        print(YIELD_CSV_HEADER)
        for rep in reports:
            for row in yield_csv_rows(rep):
                print(row)
        return

    for rep in reports:
        print(rep.summary())
        for o in rep.outcomes:
            if not o.survived and o.fallback is not None:
                f = o.fallback
                print(f"  seed {o.seed}: {o.reason.split(':')[0]} -> "
                      f"fallback {f.fabric} mp={f.strategy.mp} "
                      f"dp={f.strategy.dp} pp={f.strategy.pp} "
                      f"({f.total / rep.winner.total:.3f}x healthy time)")
        print(f"  ({rep.study_seconds:.2f}s for {rep.n_masks} masks)\n")


if __name__ == "__main__":
    main()
