"""Quickstart: init a small model, train 20 steps, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.models.config import ParallelConfig, ShapeConfig
from repro.serve.engine import Engine, EngineConfig, Request
from repro.train.train_loop import Trainer, TrainerConfig


def main():
    cfg = get_config("llama3.2-1b").reduced(d_model=128, num_layers=4,
                                            vocab_size=512)
    shape = ShapeConfig("quickstart", "train", seq_len=64, global_batch=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    pcfg = ParallelConfig(remat="none")

    trainer = Trainer(cfg, shape, mesh, pcfg,
                      tcfg=TrainerConfig(steps=20, log_every=5,
                                         checkpoint_every=10,
                                         checkpoint_dir="/tmp/quickstart_ckpt"))
    state = trainer.run()
    print(f"final loss: {trainer.history[-1]['loss']:.4f} "
          f"(started {trainer.history[0]['loss']:.4f})")

    engine = Engine(state.params, cfg,
                    ecfg=EngineConfig(max_batch=2, cache_len=96))
    reqs = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8),
            Request(uid=1, prompt=[4, 5, 6], max_new_tokens=8)]
    for r in engine.run_batch(reqs):
        print(f"request {r.uid}: prompt={r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
