"""Weight-streaming training demo (the paper's Sec. III-A execution mode).

Parameters live on the host ("off-wafer DRAM"); each layer streams to the
device for forward and again for backward; gradients stream out and a
near-storage optimizer updates host weights.  Also prints what the FRED
vs mesh fabric models predict for this loop's sustainable I/O rate.

    PYTHONPATH=src python examples/weight_streaming.py
"""

import jax

from repro.configs.registry import get_config
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.models import transformer as tfm
from repro.models.config import ParallelConfig
from repro.models.modules import split
from repro.train.streaming import HostParams, stream_train_step


def main():
    cfg = get_config("llama3.2-1b").reduced(d_model=128, num_layers=6,
                                            vocab_size=512)
    pcfg = ParallelConfig(remat="none")
    params, _ = split(tfm.init(jax.random.PRNGKey(0), cfg))
    hp = HostParams(params, cfg.num_layers)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 64),
                                          0, cfg.vocab_size)}
    print("weight-streaming training (params resident on host):")
    for step in range(8):
        loss = stream_train_step(hp, batch, cfg, pcfg, lr=5e-3)
        print(f"  step {step}: loss={loss:.4f}")

    mesh, fred = MeshFabric(), FredFabric(CONFIGS["FRED-D"])
    print("\nfabric-model I/O analysis for this loop (paper Fig. 4):")
    print(f"  2D-mesh sustainable stream rate: "
          f"{mesh.io_stream_rate()/1e12:.2f} TB/s "
          f"(hotspot factor {mesh.io_linerate_factor():.2f})")
    print(f"  FRED sustainable stream rate:    "
          f"{fred.io_stream_rate()/1e12:.2f} TB/s (line rate)")


if __name__ == "__main__":
    main()
