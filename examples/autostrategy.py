"""Sweep-driven auto-strategy demo (core/autostrategy.py).

The paper's Fig. 2 question — which (mp, dp, pp) should this model use? —
answered by the analytical FRED simulator instead of a hand-set config:
for each requested registry architecture the (fabric × wafer shape ×
wafer count × strategy) sweep runs under the per-NPU memory-feasibility
model (weights + optimizer state + remat-scaled activations vs the HBM
budget) and the Pareto-optimal feasible point is chosen.  Models too big
to hold weights stationary (arctic-480b) fall back to weight streaming
(Sec. III-A), exactly like the paper's Transformer-1T.

    PYTHONPATH=src python examples/autostrategy.py [--archs a,b,...]
        [--shape train_4k] [--npus 64] [--max-wafers 2] [--hbm-gib 16]
        [--fabrics baseline,FRED-C,FRED-D]
"""

import argparse


def main():
    from repro.configs.registry import ARCH_IDS
    from repro.core.autostrategy import decision_table
    from repro.core.workloads import DEFAULT_NPU_HBM_BYTES

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", type=str, default=",".join(ARCH_IDS))
    ap.add_argument("--shape", type=str, default="train_4k")
    ap.add_argument("--npus", type=int, default=64, help="NPUs per wafer")
    ap.add_argument("--max-wafers", type=int, default=2)
    ap.add_argument("--hbm-gib", type=float,
                    default=DEFAULT_NPU_HBM_BYTES / 2**30,
                    help="per-NPU HBM budget, GiB")
    ap.add_argument("--fabrics", type=str, default="baseline,FRED-C,FRED-D")
    args = ap.parse_args()

    decisions = decision_table(
        args.archs.split(","), shape_name=args.shape,
        n_npus=args.npus, max_wafers=args.max_wafers,
        npu_hbm_bytes=args.hbm_gib * 2**30,
        fabrics=tuple(args.fabrics.split(",")))

    print(f"{'arch':16s} {'chosen':24s} {'fabric':8s} {'wafer':7s} "
          f"{'inter':16s} {'exec':10s} {'mem/NPU':>8s} {'t/sample':>10s} "
          f"{'cand':>5s} {'infeas':>6s} {'dom':>5s}")
    for d in decisions:
        inter = (f"{d.inter_topology}[" +
                 "x".join(map(str, d.hierarchy)) + "]"
                 if d.wafers > 1 else "-")
        print(f"{d.arch:16s} {str(d.strategy):24s} {d.fabric:8s} "
              f"{d.wafer_shape[0]}x{d.wafer_shape[1]:<5d} "
              f"{inter:16s} {d.execution:10s} "
              f"{d.memory_bytes_per_npu / 2**30:6.2f}Gi "
              f"{d.time_per_sample_s * 1e6:8.3f}us "
              f"{d.n_candidates:5d} {d.n_infeasible:6d} {d.n_dominated:5d}")
    print(f"\n(memory budget {args.hbm_gib:.0f} GiB/NPU; 'infeas' = "
          f"candidates failing it, 'dom' = feasible but Pareto-dominated)")


if __name__ == "__main__":
    main()
