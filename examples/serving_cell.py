"""Serving-cell auto-strategy demo (core/serving.py, ISSUE 10).

The ROADMAP's north-star serving question — *how many wafers does it
take to serve qwen3-32b to 1M concurrent users at a 200 ms p99 TTFT?* —
answered by the analytical serving cost model: for each requested
registry architecture the (placement × wafers × inter-topology ×
prefill plan × decode plan) sweep prices prefill and decode rooflines
on the FRED collective simulator, batches decode under the KV-cache
memory model, runs the M/D/c queueing layer against the offered load,
and elects the cheapest cell composition whose p99 TTFT meets the SLO.

    PYTHONPATH=src python examples/serving_cell.py [--archs a,b,...]
        [--users 1000000] [--think-s 60] [--p99-ms 200]
        [--prompt 1024] [--output 256] [--npus 64] [--max-wafers 2]
"""

import argparse


def main():
    from repro.core.autostrategy import (SERVESWEEP_ARCHS,
                                         choose_serving_strategy)
    from repro.core.specs import Objective
    from repro.configs.registry import get_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", type=str, default=",".join(SERVESWEEP_ARCHS))
    ap.add_argument("--users", type=int, default=1_000_000,
                    help="concurrent users (arrival rate = users/think)")
    ap.add_argument("--think-s", type=float, default=60.0)
    ap.add_argument("--p99-ms", type=float, default=200.0,
                    help="TTFT p99 SLO, milliseconds")
    ap.add_argument("--prompt", type=int, default=1024)
    ap.add_argument("--output", type=int, default=256)
    ap.add_argument("--npus", type=int, default=64, help="NPUs per wafer")
    ap.add_argument("--max-wafers", type=int, default=2)
    args = ap.parse_args()

    objective = Objective.serving(
        target_p99_ms=args.p99_ms, concurrent_users=args.users,
        think_time_s=args.think_s, prompt_tokens=args.prompt,
        output_tokens=args.output)

    print(f"{'arch':14s} {'placement':14s} {'wafers':>6s} {'inter':8s} "
          f"{'prefill':>12s} {'decode':>16s} {'cells':>5s} "
          f"{'total':>6s} {'p99 TTFT':>9s}")
    for arch in args.archs.split(","):
        d = choose_serving_strategy(
            get_config(arch), objective,
            n_npus=args.npus, max_wafers=args.max_wafers)
        pf = f"{d.prefill_fabric} mp={d.prefill_mp}"
        dec = f"{d.decode_fabric} mp={d.decode_mp} b={d.decode_batch}"
        inter = d.inter_topology if d.wafers_per_cell > 1 else "-"
        print(f"{arch:14s} {d.placement:14s} {d.wafers_per_cell:6d} "
              f"{inter:8s} {pf:>12s} {dec:>16s} {d.n_cells:5d} "
              f"{d.total_wafers:6d} {d.ttft_p99_ms:7.2f}ms")
    rate = args.users / args.think_s
    print(f"\n(offered load {rate:,.0f} req/s = {args.users:,} users / "
          f"{args.think_s:.0f}s think time; 'total' wafers is the "
          f"north-star answer; p99 is at the per-cell operating rate)")


if __name__ == "__main__":
    main()
