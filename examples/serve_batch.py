"""Batched serving driver (deliverable (b), serving flavor): load/initialize
a ~100M model and serve batches of requests with prefill + decode.

    PYTHONPATH=src python examples/serve_batch.py --requests 8
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.registry import get_config
from repro.models import transformer as tfm
from repro.models.modules import split
from repro.serve.engine import Engine, EngineConfig, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--small", action="store_true",
                    help="tiny model for CI-speed runs")
    args = ap.parse_args()

    base = get_config("llama3.2-1b")
    if args.small:
        cfg = base.reduced(d_model=128, num_layers=4, vocab_size=512)
    else:  # ~100M backbone
        cfg = dataclasses.replace(base, num_layers=12, d_model=768,
                                  n_heads=12, n_kv_heads=4, head_dim=64,
                                  d_ff=3072, vocab_size=32000,
                                  vocab_pad_to=64)
    params, _ = split(tfm.init(jax.random.PRNGKey(0), cfg))
    engine = Engine(params, cfg, ecfg=EngineConfig(
        max_batch=args.requests, cache_len=128))

    reqs = [Request(uid=i, prompt=[(7 * i + j) % 100 + 1 for j in range(12)],
                    max_new_tokens=args.new_tokens,
                    temperature=0.8 if i % 2 else 0.0, top_k=20)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run_batch(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid} ({'greedy' if r.temperature == 0 else 'sampled'}): "
              f"{r.output[:10]}...")


if __name__ == "__main__":
    main()
