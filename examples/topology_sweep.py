"""Strategy/topology co-exploration demo (core/sweep.py).

Sweeps every (mp, dp, pp) strategy and wafer shape for a workload at a
given NPU count on baseline-mesh and FRED fabrics, then prints the
per-fabric Pareto front on (time-per-sample, parameter-bytes-per-NPU) —
the question the paper's Fig. 2 asks for one fixed wafer, answered for
arbitrary ones.

``--max-wafers N`` adds the multi-wafer scale-out axis (core/cluster.py):
the wafer is the manufacturing unit, so clusters of 2..N wafers multiply
the NPU count, DP replicas map across wafers, and the DP All-Reduce runs
hierarchically (reduce-scatter within wafer → per-level inter
collectives → all-gather within wafer).  ``--inter-topologies`` crosses
every cluster with the listed inter-wafer collective models (ring /
fully_connected / switch) and ``--max-levels 2`` adds the rack/pod
stackings of each wafer count (4 wafers → flat ring-of-4 and 2×2
rack×pod).  Cross-wafer strategies print as ``...-W(n)`` with their
per-level (intra/inter-wafer) DP time; the CSV gains the ``n_wafers`` /
``inter_wafer_bw`` / ``hierarchy`` / ``inter_topology`` /
``dp_intra_s`` / ``dp_inter_s`` / ``dp_level_*_s`` columns (schema:
benchmarks/README.md).

``--engine {batched,scalar}`` selects the evaluator (default batched —
the vectorized NumPy engine of core/batch_engine.py; scalar walks
``Simulator.run`` per point).  Both are bit-identical; the measured
sweep wall time is printed so the speedup is visible:

    PYTHONPATH=src python examples/topology_sweep.py --npus 64 \
        --max-wafers 4 --engine scalar     # ~10-15x the batched time

    PYTHONPATH=src python examples/topology_sweep.py [--npus 20]
        [--fabrics baseline,FRED-C,FRED-D] [--workload t17b|gpt3]
        [--max-wafers 2] [--inter-links 32] [--inter-bw-gbps 400]
        [--inter-topologies ring,fully_connected,switch] [--max-levels 2]
        [--check-routing] [--engine batched|scalar] [--csv out.csv]
"""

import argparse
import time

from repro.core.placement import Strategy
from repro.core.sweep import (CSV_HEADER, sweep, to_csv_rows,
                              transformer_17b)
from repro.core.workloads import transformer


def gpt3(strategy: Strategy):
    return transformer("GPT-3", 96, 12288, 2048, strategy, "streaming")


WORKLOADS = {"t17b": (transformer_17b, 78), "gpt3": (gpt3, 96)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--npus", type=int, default=20,
                    help="NPUs per wafer (total = npus × wafer count)")
    ap.add_argument("--fabrics", type=str, default="baseline,FRED-C,FRED-D")
    ap.add_argument("--workload", choices=sorted(WORKLOADS), default="t17b")
    ap.add_argument("--max-wafers", type=int, default=1,
                    help="also sweep clusters of up to this many wafers "
                         "(adds the n_wafers axis + cross-wafer DP "
                         "strategies; 1 = single wafer only)")
    ap.add_argument("--inter-links", type=int, default=32,
                    help="wafer↔wafer links per wafer")
    ap.add_argument("--inter-bw-gbps", type=float, default=400.0,
                    help="per-link wafer↔wafer bandwidth, GB/s per "
                         "direction")
    ap.add_argument("--inter-topologies", type=str, default="ring",
                    help="comma list of inter-wafer collective models to "
                         "sweep: ring, fully_connected, switch "
                         "(core/cluster.py)")
    ap.add_argument("--max-levels", type=int, default=1,
                    help="hierarchy depth to sweep: 1 = flat "
                         "wafer↔wafer level, 2 = also rack/pod "
                         "stackings of each wafer count")
    ap.add_argument("--check-routing", action="store_true",
                    help="verify conflict-free routing per FRED "
                         "(strategy, shape) pair")
    ap.add_argument("--hbm-gib", type=float, default=0.0,
                    help="per-NPU HBM budget in GiB: turns on the "
                         "memory-feasibility objective (Pareto on "
                         "time/sample × memory/NPU over feasible points)")
    ap.add_argument("--engine", choices=("batched", "scalar"),
                    default="batched",
                    help="sweep evaluator: vectorized NumPy batch engine "
                         "(default) or the scalar per-point reference — "
                         "bit-identical results, very different wall time")
    ap.add_argument("--csv", type=str, default="",
                    help="write the full sweep as CSV (schema incl. wafer "
                         "columns: benchmarks/README.md)")
    args = ap.parse_args()

    from repro.core.workloads import MemoryModel
    memory = (MemoryModel(npu_hbm_bytes=args.hbm_gib * 2**30)
              if args.hbm_gib else None)
    workload_fn, n_layers = WORKLOADS[args.workload]
    t0 = time.perf_counter()
    results = sweep(workload_fn, args.npus,
                    fabrics=tuple(args.fabrics.split(",")),
                    n_layers=n_layers, check_routing=args.check_routing,
                    max_wafers=args.max_wafers,
                    inter_wafer_links=args.inter_links,
                    inter_wafer_bw=args.inter_bw_gbps * 1e9,
                    inter_topologies=tuple(
                        args.inter_topologies.split(",")),
                    max_levels=args.max_levels,
                    memory=memory, prune_symmetric=True,
                    engine=args.engine)
    elapsed = time.perf_counter() - t0
    wafers = f", up to {args.max_wafers} wafers" if args.max_wafers > 1 else ""
    print(f"{args.workload} on {args.npus} NPUs/wafer{wafers}: "
          f"{len(results)} sweep points in {elapsed:.3f} s "
          f"({args.engine} engine, {len(results)/elapsed:,.0f} points/s)")

    for fabric in args.fabrics.split(","):
        front = sorted((r for r in results
                        if r.fabric == fabric and r.pareto),
                       key=lambda r: r.time_per_sample)
        print(f"\n{fabric} Pareto front "
              f"(time/sample vs param bytes/NPU):")
        for r in front:
            route = ""
            if r.routable is not None:
                route = "  routes" if r.routable else "  CONFLICT"
            level = ""
            if r.n_wafers > 1:
                hier = "x".join(map(str, r.hierarchy))
                level = (f"  {r.inter_topology}[{hier}]"
                         f"  dp intra/inter="
                         f"{r.breakdown.dp_intra*1e3:.2f}/"
                         f"{r.breakdown.dp_inter*1e3:.2f} ms")
            mem = ""
            if r.feasible is not None:
                mem = f"  mem/NPU={r.memory_bytes_per_npu/2**30:6.2f} GiB"
            print(f"  {str(r.strategy):26s} shape={r.shape[0]}x{r.shape[1]}"
                  f"{'x' + str(r.n_wafers) + 'w' if r.n_wafers > 1 else ''}"
                  f"  t/sample={r.time_per_sample*1e6:9.2f} us"
                  f"  params/NPU={r.param_bytes_per_npu/1e9:6.2f} GB"
                  f"{mem}{route}{level}")

    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(CSV_HEADER + "\n")
            fh.write("\n".join(to_csv_rows(results)) + "\n")
        print(f"\nwrote {len(results)} rows to {args.csv}")


if __name__ == "__main__":
    main()
