"""Strategy/topology co-exploration demo (core/sweep.py).

Sweeps every (mp, dp, pp) strategy and wafer shape for a workload at a
given NPU count on baseline-mesh and FRED fabrics, then prints the
per-fabric Pareto front on (time-per-sample, parameter-bytes-per-NPU) —
the question the paper's Fig. 2 asks for one fixed wafer, answered for
arbitrary ones.

    PYTHONPATH=src python examples/topology_sweep.py [--npus 20]
        [--fabrics baseline,FRED-C,FRED-D] [--workload t17b|gpt3]
        [--check-routing] [--csv out.csv]
"""

import argparse

from repro.core.placement import Strategy
from repro.core.sweep import (CSV_HEADER, sweep, to_csv_rows,
                              transformer_17b)
from repro.core.workloads import transformer


def gpt3(strategy: Strategy):
    return transformer("GPT-3", 96, 12288, 2048, strategy, "streaming")


WORKLOADS = {"t17b": (transformer_17b, 78), "gpt3": (gpt3, 96)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--npus", type=int, default=20)
    ap.add_argument("--fabrics", type=str, default="baseline,FRED-C,FRED-D")
    ap.add_argument("--workload", choices=sorted(WORKLOADS), default="t17b")
    ap.add_argument("--check-routing", action="store_true",
                    help="verify conflict-free routing per FRED strategy")
    ap.add_argument("--csv", type=str, default="",
                    help="write the full sweep as CSV (schema: "
                         "benchmarks/README.md)")
    args = ap.parse_args()

    workload_fn, n_layers = WORKLOADS[args.workload]
    results = sweep(workload_fn, args.npus,
                    fabrics=tuple(args.fabrics.split(",")),
                    n_layers=n_layers, check_routing=args.check_routing)
    print(f"{args.workload} on {args.npus} NPUs: {len(results)} sweep points")

    for fabric in args.fabrics.split(","):
        front = sorted((r for r in results
                        if r.fabric == fabric and r.pareto),
                       key=lambda r: r.time_per_sample)
        print(f"\n{fabric} Pareto front "
              f"(time/sample vs param bytes/NPU):")
        for r in front:
            route = ""
            if r.routable is not None:
                route = "  routes" if r.routable else "  CONFLICT"
            print(f"  {str(r.strategy):22s} shape={r.shape[0]}x{r.shape[1]}"
                  f"  t/sample={r.time_per_sample*1e6:9.2f} us"
                  f"  params/NPU={r.param_bytes_per_npu/1e9:6.2f} GB{route}")

    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(CSV_HEADER + "\n")
            fh.write("\n".join(to_csv_rows(results)) + "\n")
        print(f"\nwrote {len(results)} rows to {args.csv}")


if __name__ == "__main__":
    main()
