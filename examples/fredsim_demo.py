"""FRED interconnect walk-through: switch construction, conflict-free
routing of concurrent collectives, and the end-to-end speedup table.

    PYTHONPATH=src python examples/fredsim_demo.py
"""

from repro.core.calibrate import CALIBRATED, PAPER_SPEEDUPS, simulate_speedups
from repro.core.flows import all_reduce
from repro.core.routing import fig7j_flows, routable, route
from repro.core.switch import FredSwitch, hw_overhead


def main():
    print("=== FRED_3(8) switch ===")
    sw = FredSwitch.build(8, m=3)
    print("microswitches:", sw.count_microswitches(), "depth:", sw.depth())
    print("hw overhead:", hw_overhead(sw))

    print("\n=== concurrent All-Reduce routing (Fig. 7h) ===")
    flows = [all_reduce([0, 1, 2])[0][0], all_reduce([3, 4, 5])[0][0]]
    asg = route(sw, flows)
    for f, c in asg.colors.items():
        print(f"  {f} -> middle subnetwork {c}")
    print("  reductions at input µswitches:",
          [(i, f.tag) for i, f in asg.reduce_at])

    print("\n=== Fig. 7(j) routing conflict ===")
    print("  FRED_2(8) routable:", routable(FredSwitch.build(8, 2),
                                            fig7j_flows()), "(paper: False)")
    print("  FRED_3(8) routable:", routable(sw, fig7j_flows()),
          "(paper: True)")

    print("\n=== Fig. 10 end-to-end speedups (calibrated) ===")
    sp = simulate_speedups(CALIBRATED["compute_efficiency"],
                           CALIBRATED["mesh_step_overhead"],
                           CALIBRATED["fred_step_overhead"])
    for w, row in sp.items():
        tgt = PAPER_SPEEDUPS[w]
        print(f"  {w:16s} FRED-C {row['FRED-C']:.2f} (paper {tgt['FRED-C']}) "
              f"FRED-D {row['FRED-D']:.2f} (paper {tgt['FRED-D']})")


if __name__ == "__main__":
    main()
