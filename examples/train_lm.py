"""End-to-end training driver: train an LM on the synthetic corpus.

Default is a fast ~10M-parameter run; ``--preset 100m`` trains a ~100M
model for a few hundred steps (the deliverable-(b) configuration — slow on
CPU, sized for a single TPU host).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import dataclasses

from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.train.optim import OptimConfig
from repro.train.train_loop import Trainer, TrainerConfig

PRESETS = {
    # ~10M params: d=256, L=6, V=2048
    "10m": dict(d_model=256, num_layers=6, n_heads=8, n_kv_heads=4,
                head_dim=32, d_ff=1024, vocab_size=2048, seq=256, batch=8),
    # ~100M params: d=768, L=12, V=32000 (deliverable configuration)
    "100m": dict(d_model=768, num_layers=12, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32000, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="10m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="family donor (any of the 10 assigned ids)")
    ap.add_argument("--checkpoint-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    base = get_config(args.arch)
    cfg = dataclasses.replace(
        base, num_layers=p["num_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        vocab_pad_to=64)
    shape = ShapeConfig("train", "train", p["seq"], p["batch"])
    mesh = make_mesh((1, 1), ("data", "model"))
    trainer = Trainer(
        cfg, shape, mesh, ParallelConfig(remat="none"),
        OptimConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, log_every=10, checkpoint_every=50,
                      checkpoint_dir=args.checkpoint_dir))
    trainer.run()
    losses = [h["loss"] for h in trainer.history]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
