"""Sweep-driven auto-strategy (ISSUE 3 tentpole).

Covers: (a) the memory-feasibility model and its monotonicity properties,
(b) the ModelConfig→Workload adapter, (c) choose_strategy returning a
feasible simulator-chosen strategy for every registry model with the
golden strategy-regression gate, (d) cell_policy's frozen paper-faithful
defaults when autostrategy=False, and (e) the canonical-form symmetry
pruning preserving the Pareto front exactly (incl. the numeric
counterexample showing mp↔dp swaps are NOT time-symmetric, which is why
the dedup keys on simulation inputs).
"""

import json
from pathlib import Path

import pytest

from repro.core.autostrategy import (AutoStrategyDecision, check_goldens,
                                     choose_strategy, decision_table)
from repro.core.placement import Strategy
from repro.core.simulator import Simulator
from repro.core.sweep import (CSV_HEADER, sim_signature, strategy_space,
                              sweep, to_csv_rows, transformer_17b,
                              transformer_17b_sweep)
from repro.core.workloads import (DEFAULT_NPU_HBM_BYTES, MemoryModel,
                                  Workload, from_model_config, is_feasible,
                                  memory_bytes_per_npu,
                                  optimizer_bytes_per_param)

GOLDENS = Path(__file__).parent / "goldens" / "autostrategy.json"


def _cfg(arch):
    from repro.configs.registry import get_config
    return get_config(arch)


def _shape(name="train_4k"):
    from repro.models.config import SHAPES_BY_NAME
    return SHAPES_BY_NAME[name]


# --------------------------------------------------------------------------
# (a) memory-feasibility model
# --------------------------------------------------------------------------

def test_optimizer_bytes_per_param_modes():
    # fp32 master + fp32 moments: 4 + 2·4
    assert optimizer_bytes_per_param(True, "float32") == 12.0
    # the arctic-480b mode: no master, int8 moments
    assert optimizer_bytes_per_param(False, "int8") == 2.0
    assert optimizer_bytes_per_param(True, "bfloat16") == 8.0


def _workload(params_per_layer=1e8, n_layers=16, act=4096.0, seq=1024,
              st=Strategy(2, 4, 1), execution="stationary"):
    return Workload(name="synthetic", n_layers=n_layers,
                    params_per_layer=params_per_layer,
                    flops_fwd_per_sample_layer=2 * params_per_layer,
                    act_bytes_per_sample=act, strategy=st,
                    execution=execution, seq=seq)


def test_memory_model_components():
    w = _workload(st=Strategy(1, 1, 1), n_layers=1, seq=1)
    mem = MemoryModel(master=True, moments_dtype="float32", remat="full")
    # 1 layer, no sharding: weights 2B + grads 2B + opt 12B + boundary act
    assert memory_bytes_per_npu(w, mem) == pytest.approx(
        16 * w.params_per_layer + w.act_bytes_per_sample)
    # MP halves every term
    w2 = _workload(st=Strategy(2, 1, 1), n_layers=1, seq=1)
    assert memory_bytes_per_npu(w2, mem) == pytest.approx(
        memory_bytes_per_npu(w, mem) / 2)
    # streaming: only 3 layer buffers, no optimizer state
    ws = _workload(st=Strategy(1, 1, 1), n_layers=64, seq=1,
                   execution="streaming")
    assert memory_bytes_per_npu(ws, mem) == pytest.approx(
        3 * ws.params_per_layer * 2 + 64 * ws.act_bytes_per_sample)


def test_remat_orders_activation_footprint():
    w = _workload()
    mems = [memory_bytes_per_npu(w, MemoryModel(remat=r))
            for r in ("full", "block", "none")]
    assert mems[0] < mems[1] < mems[2]


def test_feasibility_monotone_in_budget_and_model_size():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hs

    @given(params=hs.floats(1e6, 1e12), layers=hs.integers(1, 200),
           act=hs.floats(1e2, 1e8), seq=hs.integers(1, 65536),
           mp=hs.integers(1, 16), dp=hs.integers(1, 16),
           pp=hs.integers(1, 8),
           budget=hs.floats(1e9, 1e12), extra=hs.floats(0, 1e12),
           scale=hs.floats(1.0, 100.0),
           master=hs.booleans(),
           moments=hs.sampled_from(["float32", "bfloat16", "int8"]),
           remat=hs.sampled_from(["none", "block", "full"]),
           execution=hs.sampled_from(["stationary", "streaming"]))
    @settings(deadline=None)
    def run(params, layers, act, seq, mp, dp, pp, budget, extra, scale,
            master, moments, remat, execution):
        pp = min(pp, layers)
        st = Strategy(mp, dp, pp)
        w = _workload(params, layers, act, seq, st, execution)
        mem = MemoryModel(npu_hbm_bytes=budget, master=master,
                          moments_dtype=moments, remat=remat)
        # more HBM never removes a feasible strategy
        if is_feasible(w, mem):
            assert is_feasible(w, MemoryModel(
                npu_hbm_bytes=budget + extra, master=master,
                moments_dtype=moments, remat=remat))
        # a larger model never adds a feasible strategy
        big = _workload(params * scale, layers, act * scale, seq, st,
                        execution)
        if not is_feasible(w, mem):
            assert not is_feasible(big, mem)
        assert memory_bytes_per_npu(big, mem) >= \
            memory_bytes_per_npu(w, mem) - 1e-9

    run()


# --------------------------------------------------------------------------
# (b) ModelConfig → Workload adapter
# --------------------------------------------------------------------------

def test_adapter_covers_every_registry_family():
    from repro.configs.registry import ARCH_IDS
    shape = _shape()
    for arch in ARCH_IDS:
        cfg = _cfg(arch)
        w = from_model_config(cfg, shape, Strategy(2, 2, 1))
        assert w.params_per_layer > 0 and w.flops_fwd_per_sample_layer > 0
        assert w.n_layers >= cfg.num_layers
        # minibatch ≈ the cell's fixed global token count
        assert w.minibatch == pytest.approx(
            shape.global_batch * shape.seq_len, rel=0.01)


def test_adapter_total_params_sane():
    # llama3.2-1b is ~1.2B params incl. embeddings; first-order accounting
    # must land within 20%
    w = from_model_config(_cfg("llama3.2-1b"), _shape(), Strategy(1, 1, 1))
    assert w.params_total == pytest.approx(1.24e9, rel=0.2)
    # arctic-480b: ~482B resident
    w = from_model_config(_cfg("arctic-480b"), _shape(), Strategy(1, 1, 1))
    assert w.params_total == pytest.approx(480e9, rel=0.2)


def test_adapter_moe_active_fraction():
    w = from_model_config(_cfg("mixtral-8x7b"), _shape(), Strategy(1, 1, 1))
    assert w.active_param_fraction < 0.5          # top-2 of 8 experts
    dense = from_model_config(_cfg("llama3.2-1b"), _shape(),
                              Strategy(1, 1, 1))
    assert dense.active_param_fraction == 1.0


def test_adapter_serving_kv_cache():
    w = from_model_config(_cfg("llama3.2-1b"), _shape("decode_32k"),
                          Strategy(1, 1, 1))
    assert w.kv_bytes_per_sample_layer > 0
    ssm = from_model_config(_cfg("mamba2-1.3b"), _shape("decode_32k"),
                            Strategy(1, 1, 1))
    assert ssm.kv_bytes_per_sample_layer == 0     # attention-free


# --------------------------------------------------------------------------
# (c) choose_strategy + the golden strategy-regression gate
# --------------------------------------------------------------------------

def test_choose_strategy_feasible_for_every_registry_model():
    """Acceptance: a simulator-chosen, memory-feasible (mp, dp, pp,
    wafers) for every model in configs/registry.py."""
    from repro.configs.registry import ARCH_IDS
    shape = _shape()
    from repro.parallel.policy import paper_defaults
    for arch in ARCH_IDS:
        cfg = _cfg(arch)
        _, ocfg = paper_defaults(cfg, shape)
        d = choose_strategy(cfg, shape, master=ocfg.master,
                            moments_dtype=ocfg.moments_dtype,
                            fabrics=("FRED-C",))   # single fabric: fast path
        assert d.memory_bytes_per_npu <= d.npu_hbm_bytes
        assert d.strategy.n_workers >= 1
        assert d.n_candidates > 0
        assert d.n_infeasible + d.n_dominated < d.n_candidates


def test_decision_table_matches_goldens():
    """The CI strategy-regression gate: a cost-model change that silently
    flips a chosen (mp, dp, pp, wafers) fails here (and in the workflow's
    `--goldens` step).  Regenerate with:
      PYTHONPATH=src python -m benchmarks.run --only autostrategy
    then update tests/goldens/autostrategy.json from the printed table."""
    # keep in sync with benchmarks.run.AUTOSTRATEGY_ARCHS (not imported:
    # the benchmarks dir is not on the test path)
    decisions = decision_table(("llama3.2-1b", "mixtral-8x7b",
                                "arctic-480b"))
    errors = check_goldens(decisions, str(GOLDENS))
    new = {f"{d.arch}/{d.shape}": d.golden() for d in decisions}
    assert not errors, (
        "chosen strategies diverge from goldens:\n  " + "\n  ".join(errors)
        + "\nnew table (update tests/goldens/autostrategy.json if "
        f"intended):\n{json.dumps(new, indent=2)}")


def test_check_goldens_flags_divergence(tmp_path):
    d = decision_table(["llama3.2-1b"])[0]
    bad = {f"{d.arch}/{d.shape}": dict(d.golden(), mp=d.mp + 1)}
    p = tmp_path / "g.json"
    p.write_text(json.dumps(bad))
    assert check_goldens([d], str(p))
    missing = tmp_path / "m.json"
    missing.write_text("{}")
    assert check_goldens([d], str(missing))
    # a golden whose model vanished from the decision list must fail too
    # (otherwise dropping a model from the bench silently weakens the gate)
    stale = tmp_path / "s.json"
    stale.write_text(json.dumps({f"{d.arch}/{d.shape}": d.golden(),
                                 "ghost-arch/train_4k": d.golden()}))
    errs = check_goldens([d], str(stale))
    assert errs and "ghost-arch" in errs[0]


def test_moe_archs_elect_expert_parallelism():
    """ISSUE 8 acceptance: with the ep/sp axes searchable, both MoE
    registry models choose ep > 1 (the epsweep CI gate pins the full
    decisions in tests/goldens/epsweep.json; this is the tier-1 view)."""
    from repro.core.autostrategy import EP_SWEEP_KW, MOE_ARCHS
    decisions = decision_table(MOE_ARCHS, **EP_SWEEP_KW)
    assert [d.arch for d in decisions] == list(MOE_ARCHS)
    for d in decisions:
        assert d.ep > 1, d.arch
        assert d.strategy.ep == d.ep and d.strategy.sp == d.sp
        assert d.golden()["ep"] == d.ep


def test_golden_dict_adds_ep_sp_keys_only_when_set():
    """Dense-model goldens must stay byte-identical across the EP PR:
    ``golden()`` emits the new axes only at non-default values."""
    from repro.core.autostrategy import EP_SWEEP_KW
    plain = decision_table(["llama3.2-1b"])[0]
    assert plain.ep == 1 and plain.sp == 1
    assert "ep" not in plain.golden() and "sp" not in plain.golden()
    # a dense model never elects ep, but may take the free sp sharding
    searched = decision_table(["llama3.2-1b"], **EP_SWEEP_KW)[0]
    assert searched.ep == 1 and "ep" not in searched.golden()
    if searched.sp > 1:
        assert searched.golden()["sp"] == searched.sp


def test_decision_csv_rows_carry_ep_sp():
    from repro.core.autostrategy import (DECISION_CSV_HEADER,
                                         decision_csv_rows)
    assert ",ep,sp," in DECISION_CSV_HEADER
    ds = decision_table(["llama3.2-1b"])
    n = len(DECISION_CSV_HEADER.split(","))
    rows = decision_csv_rows(ds)
    assert rows and all(len(r.split(",")) == n for r in rows)


def test_streaming_fallback_for_480b():
    """arctic-480b cannot hold 482B params weight-stationary on ≤128
    16-GiB NPUs — the decision must fall back to weight streaming
    (Sec. III-A), the paper's own answer for Transformer-1T."""
    d = choose_strategy(_cfg("arctic-480b"), _shape(),
                        master=False, moments_dtype="int8",
                        fabrics=("FRED-C",))
    assert d.execution == "streaming"
    assert d.memory_bytes_per_npu <= d.npu_hbm_bytes


def test_infeasible_raises():
    from repro.core.autostrategy import InfeasibleModelError
    with pytest.raises(InfeasibleModelError):
        choose_strategy(_cfg("arctic-480b"), _shape(),
                        npu_hbm_bytes=2**20,     # 1 MiB: nothing fits
                        fabrics=("FRED-C",))


# --------------------------------------------------------------------------
# (d) cell_policy: frozen defaults vs sweep-driven selection
# --------------------------------------------------------------------------

def test_cell_policy_defaults_frozen():
    """autostrategy=False returns the paper-faithful defaults bit-for-bit
    (the pre-autostrategy behavior the dry-run artifacts recorded)."""
    from repro.parallel.policy import cell_policy
    cases = {
        ("arctic-480b", "train_4k"): dict(master=False,
                                          moments_dtype="int8",
                                          remat="full"),
        ("qwen3-32b", "train_4k"): dict(master=True,
                                        moments_dtype="bfloat16",
                                        remat="full"),
        ("llama3.2-1b", "train_4k"): dict(master=True,
                                          moments_dtype="float32",
                                          remat="full"),
        ("llama3.2-1b", "prefill_32k"): dict(master=True,
                                             moments_dtype="float32",
                                             remat="block"),
    }
    for (arch, shape_name), want in cases.items():
        pcfg, ocfg = cell_policy(_cfg(arch), _shape(shape_name), mesh=None)
        assert ocfg.master is want["master"], arch
        assert ocfg.moments_dtype == want["moments_dtype"], arch
        assert pcfg.remat == want["remat"], (arch, shape_name)
        assert pcfg.auto_strategy == (0, 0, 0, 0, "")
    # long-context chunking default unchanged
    pcfg, _ = cell_policy(_cfg("llama3.2-1b"), _shape("prefill_32k"), None)
    assert (pcfg.attn_q_chunk, pcfg.attn_k_chunk) == (512, 1024)


def test_cell_policy_autostrategy_stamps_strategy():
    from repro.parallel.policy import cell_policy
    pcfg, ocfg = cell_policy(
        _cfg("llama3.2-1b"), _shape(), mesh=None, autostrategy=True,
        sweep_kw=dict(fabrics=("FRED-C",), max_wafers=2))
    mp, dp, pp, wf, topo = pcfg.auto_strategy
    assert mp * dp * pp >= 1 and wf >= 1
    if wf > 1:
        assert pcfg.grad_sync == "hierarchical"
        assert topo in ("ring", "fully_connected", "switch")
    else:
        assert topo == ""
    # the frozen optimizer mode is unchanged by strategy selection
    assert ocfg.master is True and ocfg.moments_dtype == "float32"


def test_cell_policy_accepts_precomputed_decision():
    from repro.parallel.policy import cell_policy
    d = choose_strategy(_cfg("llama3.2-1b"), _shape(),
                        fabrics=("FRED-C",))
    pcfg, _ = cell_policy(_cfg("llama3.2-1b"), _shape(), None,
                          autostrategy=True, decision=d)
    assert pcfg.auto_strategy == (d.mp, d.dp, d.pp, d.wafers,
                                  d.inter_topology)


# --------------------------------------------------------------------------
# (e) canonical-form symmetry pruning
# --------------------------------------------------------------------------

def test_mp_dp_swap_is_not_time_symmetric():
    """The counterexample motivating signature-keyed (not sorted-triple)
    canonicalization: swapping mp↔dp changes BOTH objectives, so a
    syntactic dedup would corrupt the Pareto front."""
    sim = Simulator("FRED-C")
    a, b = Strategy(9, 2, 1), Strategy(2, 9, 1)
    wa, wb = transformer_17b(a), transformer_17b(b)
    ta = sim.run(wa).total / wa.minibatch
    tb = sim.run(wb).total / wb.minibatch
    assert ta != pytest.approx(tb, rel=1e-6)
    assert sim_signature(a, wa) != sim_signature(b, wb)


def test_pruned_sweep_preserves_pareto_front_20_npus():
    """Satellite acceptance: pruned and unpruned Pareto fronts identical
    on the 20-NPU reference (by construction — the signature captures
    exactly the simulator's inputs — and checked here point-for-point)."""
    plain = transformer_17b_sweep(20)
    pruned = transformer_17b_sweep(20, prune_symmetric=True)
    key = lambda r: (r.fabric, r.shape, r.strategy, r.n_wafers)
    assert [key(r) for r in plain] == [key(r) for r in pruned]
    assert [r.time_per_sample for r in plain] == \
        [r.time_per_sample for r in pruned]
    assert {key(r) for r in plain if r.pareto} == \
        {key(r) for r in pruned if r.pareto}


def test_signature_injective_on_divisor_triples():
    # every divisor triple is objective-distinct for this workload (see
    # the swap counterexample above), so the canonical map is injective
    sts = strategy_space(20, n_layers=78)
    sigs = {sim_signature(st, transformer_17b(st)) for st in sts}
    assert len(sigs) == len(sts)


def test_sweep_dedup_shares_breakdown_for_identical_signatures():
    # a signature-equal duplicate IS collapsed to a single simulator call:
    # its sweep row replicates the representative's breakdown object
    dup = [Strategy(3, 3, 2), Strategy(3, 3, 2)]
    res = sweep(transformer_17b, 20, fabrics=("FRED-C",), n_layers=78,
                strategies=dup, prune_symmetric=True)
    by_shape = {}
    for r in res:
        by_shape.setdefault(r.shape, []).append(r)
    for rows in by_shape.values():
        assert len(rows) == 2
        assert rows[0].breakdown is rows[1].breakdown      # memo hit
    # and without pruning the values are identical anyway
    res0 = sweep(transformer_17b, 20, fabrics=("FRED-C",), n_layers=78,
                 strategies=dup)
    assert [r.time_per_sample for r in res] == \
        [r.time_per_sample for r in res0]


def test_64_npu_sweep_under_two_seconds():
    """Acceptance: a 64-NPU sweep with pruning completes in < 2 s."""
    import time
    t0 = time.perf_counter()
    res = transformer_17b_sweep(64, prune_symmetric=True)
    dt = time.perf_counter() - t0
    assert res and dt < 2.0, f"64-NPU sweep took {dt:.2f}s"


# --------------------------------------------------------------------------
# sweep memory objective / CSV schema
# --------------------------------------------------------------------------

def test_sweep_memory_objective_and_csv():
    mem = MemoryModel(npu_hbm_bytes=DEFAULT_NPU_HBM_BYTES)
    res = sweep(transformer_17b, 20, fabrics=("FRED-C",), n_layers=78,
                memory=mem)
    assert all(r.feasible is not None for r in res)
    assert all(r.memory_bytes_per_npu > 0 for r in res)
    # infeasible points are never Pareto members
    assert not any(r.pareto and not r.feasible for r in res)
    # memory strictly exceeds the weight-only proxy (grads + opt + acts)
    assert all(r.memory_bytes_per_npu > r.param_bytes_per_npu
               for r in res if r.strategy.wafers == 1)
    rows = to_csv_rows(res)
    n_fields = len(CSV_HEADER.split(","))
    assert all(len(r.split(",")) == n_fields for r in rows)
    # without a memory model the new columns stay empty/zero
    res0 = sweep(transformer_17b, 16, fabrics=("FRED-C",), n_layers=78)
    assert all(r.feasible is None and r.memory_bytes_per_npu == 0.0
               for r in res0)
