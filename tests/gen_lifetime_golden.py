"""Regenerate tests/goldens/lifetimesweep.json — the pinned time-vs-
goodput auto-strategy decision pairs (``repro.core.autostrategy
.LIFETIME_ARCHS`` at ``LIFETIME_MTBF_NPU_HOURS`` under
``LIFETIME_SWEEP_KW``).  Run after an *intentional* cost-model change:

    PYTHONPATH=src python -m tests.gen_lifetime_golden

``--check`` regenerates in memory only and exits non-zero if the fresh
decisions differ from the committed file — the nightly golden-drift gate
(catches env-dependent float drift before it surfaces as a confusing PR
failure), mirroring tests/gen_epsweep_golden.py.

The generator refuses to write a golden in which *no* arch flips: the
lifetimesweep CI gate exists to pin MTBF-driven strategy flips, so a
flip-free golden would make the gate vacuous (fix the failure /
degradation model first).
"""

import argparse
import json
import sys
from pathlib import Path

GOLDEN = Path(__file__).parent / "goldens" / "lifetimesweep.json"


def fresh_goldens() -> dict:
    from repro.core.autostrategy import (lifetime_decision_pairs,
                                         lifetime_golden)
    pairs = lifetime_decision_pairs()
    out = {f"{t.arch}/{t.shape}": lifetime_golden((t, g))
           for t, g in pairs}
    flips = [k for k, v in out.items() if v["flip"]]
    if not flips:
        sys.exit(f"refusing to write {GOLDEN}: no arch flips its decision "
                 f"between the time and goodput objectives — the "
                 f"lifetimesweep gate would be vacuous (fix the failure/"
                 f"degradation model first)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff the regenerated decisions against the "
                         "committed golden instead of overwriting it; "
                         "exit 1 on drift")
    args = ap.parse_args()
    got = fresh_goldens()
    if args.check:
        want = json.loads(GOLDEN.read_text())
        if got != want:
            diffs = [k for k in sorted(set(got) | set(want))
                     if got.get(k) != want.get(k)]
            print(f"golden drift: regenerated lifetime decisions differ "
                  f"from {GOLDEN} ({', '.join(diffs)}).\n"
                  f"If a cost-model change is intended, regenerate with "
                  f"`python -m tests.gen_lifetime_golden`; otherwise the "
                  f"environment introduced float drift.", file=sys.stderr)
            print(json.dumps(got, indent=1, sort_keys=True),
                  file=sys.stderr)
            return 1
        print(f"golden check OK: {len(got)} lifetime decision pairs "
              f"identical to {GOLDEN}")
        return 0
    GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
    n_flips = sum(v["flip"] for v in got.values())
    print(f"wrote {GOLDEN} ({len(got)} decision pairs, {n_flips} flips)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
