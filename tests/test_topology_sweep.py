"""Generalized wafer topologies + strategy/topology sweep engine.

Covers the ISSUE 1 tentpole: (a) the generalized mesh/FRED fabrics at the
paper's 5×4 / 5-groups-of-4 shape reproduce the seed numbers exactly,
(b) sanity properties (monotone collective time in group size, bisection
scaling) hold at other shapes, (c) the sweep engine returns non-empty,
undominated Pareto sets on ≥ 3 distinct wafer sizes.
"""

import pytest

from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy, fred_placement, mesh_placement
from repro.core.simulator import Simulator, speedup_table
from repro.core.specs import FabricSpec
from repro.core.sweep import (CSV_HEADER, factor_pairs, fred_shapes,
                              mesh_shapes, pareto_front, strategy_space,
                              sweep, to_csv_rows, transformer_17b,
                              transformer_17b_sweep)
from repro.core.workloads import paper_workloads

ALL_FABRICS = ("baseline", "FRED-A", "FRED-B", "FRED-C", "FRED-D")

# speedup_table() of the seed repo (v0), frozen — the generalized models
# must keep the default-shape numbers bit-stable.
SEED_SPEEDUPS = {
    "ResNet-152": {"FRED-C": 1.66998856164116, "FRED-D": 1.8845671243325728},
    "Transformer-17B": {"FRED-C": 2.5064965146824023,
                        "FRED-D": 3.3276133740886134},
    "GPT-3": {"FRED-C": 1.5360042777542344, "FRED-D": 1.5360042777542344},
    "Transformer-1T": {"FRED-C": 1.5359999999999996,
                       "FRED-D": 1.5359999999999996},
}


# --------------------------------------------------------------------------
# (a) paper shape ≡ seed, explicit shape ≡ default
# --------------------------------------------------------------------------

def test_default_shape_reproduces_seed_speedups():
    sp = speedup_table()
    for w, row in SEED_SPEEDUPS.items():
        for cfg, v in row.items():
            assert sp[w][cfg] == pytest.approx(v, abs=1e-9)


def test_explicit_paper_shape_matches_default_exactly():
    for w in paper_workloads():
        for fab in ALL_FABRICS:
            a = Simulator(fab).run(w).as_dict()
            b = Simulator(fab, spec=FabricSpec(
                mesh_shape=(5, 4), fred_shape=(5, 4),
                n_io=18)).run(w).as_dict()
            for k, v in a.items():
                assert b[k] == pytest.approx(v, abs=1e-9)


def test_collective_cache_is_transparent():
    w = paper_workloads()[1]          # Transformer-17B
    for fab in ("baseline", "FRED-C"):
        cache = {}
        cached = Simulator(fab, collective_cache=cache)
        plain = Simulator(fab)
        first = cached.run(w).total
        assert cache                               # cache actually filled
        assert cached.run(w).total == pytest.approx(first, abs=0)
        assert plain.run(w).total == pytest.approx(first, abs=1e-12)


def test_collective_cache_shared_across_fabrics_is_safe():
    """Keys carry the fabric's physical identity: one dict shared across
    fabrics and shapes must never cross-contaminate."""
    w = paper_workloads()[1]
    shared = {}
    totals = {}
    for fab, shape in (("FRED-A", (5, 4)), ("FRED-C", (5, 4)),
                       ("FRED-C", (4, 5)), ("baseline", (5, 4))):
        sim = Simulator(fab, spec=FabricSpec(fred_shape=shape,
                                             mesh_shape=shape),
                        collective_cache=shared)
        totals[(fab, shape)] = sim.run(w).total
    for (fab, shape), t in totals.items():
        fresh = Simulator(fab, spec=FabricSpec(
            fred_shape=shape, mesh_shape=shape)).run(w)
        assert t == pytest.approx(fresh.total, abs=1e-12), (fab, shape)
    assert totals[("FRED-A", (5, 4))] != totals[("FRED-C", (5, 4))]


# --------------------------------------------------------------------------
# (b) generalized-shape sanity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols,n_io,hotspot,corner", [
    (5, 4, 18, 9, 2),      # paper wafer
    (4, 4, 16, 7, 2),
    (8, 8, 32, 15, 2),
    (1, 8, 10, 15, 1),     # degenerate line
])
def test_mesh_derived_quantities(rows, cols, n_io, hotspot, corner):
    m = MeshFabric(rows=rows, cols=cols)
    assert m.n_io_controllers() == n_io
    assert m.io_hotspot_load() == hotspot
    assert m.corner_degree() == corner
    assert m.wafer_wide_allreduce_bw() == corner * m.link_bw


def test_mesh_n_io_override():
    assert MeshFabric(rows=5, cols=4, n_io=6).n_io_controllers() == 6


def test_bisection_scaling():
    # mesh: min-dimension links cross the cut, ×2 full duplex — pinned
    assert MeshFabric(5, 4).bisection_bw() == 2 * 4 * 750e9
    assert MeshFabric(5, 8).bisection_bw() == \
        pytest.approx(MeshFabric(5, 4).bisection_bw() * 5 / 4)
    # FRED: the cut severs the smaller half's uplinks (n_groups // 2),
    # ×2 full duplex, consistent with the mesh definition — pinned
    cfg = CONFIGS["FRED-C"]
    assert FredFabric(cfg, n_groups=4, group_size=4).bisection == \
        2 * 2 * cfg.l1_l2_bw
    # odd group counts: the smaller half has floor(n_groups/2) uplinks
    assert FredFabric(cfg, n_groups=5, group_size=4).bisection == \
        2 * 2 * cfg.l1_l2_bw
    a = FredFabric(cfg, n_groups=4, group_size=4).bisection
    b = FredFabric(cfg, n_groups=8, group_size=4).bisection
    assert b == pytest.approx(2 * a)
    # bisection_bw() alias matches MeshFabric naming
    assert FredFabric(cfg).bisection_bw() == FredFabric(cfg).bisection


@pytest.mark.parametrize("cfg", ALL_FABRICS[1:])
@pytest.mark.parametrize("n_groups,group_size", [(5, 4), (4, 8), (8, 4)])
def test_fred_collective_time_monotone_in_group_size(cfg, n_groups,
                                                     group_size):
    fab = FredFabric(CONFIGS[cfg], n_groups=n_groups, group_size=group_size)
    D = 1e9
    times = [fab.collective_time("all_reduce", list(range(n)), D)
             for n in range(2, fab.n_npus + 1)]
    assert all(b >= a - 1e-12 for a, b in zip(times, times[1:]))


def test_mesh_collective_time_monotone_in_group_size():
    m = MeshFabric(8, 8)
    D = 1e9
    times = [m.collective_time("all_reduce", list(range(n)), D)
             for n in (2, 4, 8, 16, 32)]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_fred_io_distribution_and_inventory():
    fab = FredFabric(CONFIGS["FRED-C"])           # 18 I/O over 5 groups
    assert fab.io_per_group() == [4, 4, 4, 3, 3]
    inv = fab.switch_inventory()
    # paper wafer: FRED3(12) and FRED3(11) L1 classes + the L2 spine
    assert ("L1", 12, 3) in inv and ("L1", 11, 2) in inv
    acc = fab.hw_accounting()
    assert acc["switches"] == 6 and acc["area_mm2"] > 0
    # HW accounting scales with the wafer
    big = FredFabric(CONFIGS["FRED-C"], n_groups=10, group_size=4, n_io=36)
    assert big.hw_accounting()["area_mm2"] > acc["area_mm2"]


def test_placement_rejects_oversubscription():
    with pytest.raises(ValueError):
        fred_placement(Strategy(5, 5, 1), n_npus=20)
    with pytest.raises(ValueError):
        mesh_placement(Strategy(5, 5, 1), 5, 4)
    with pytest.raises(ValueError):
        Simulator("baseline",
                  spec=FabricSpec(mesh_shape=(4, 4))).run(paper_workloads()[3])


def test_invalid_shapes_rejected():
    with pytest.raises(ValueError):
        MeshFabric(rows=0, cols=4)
    with pytest.raises(ValueError):
        FredFabric(CONFIGS["FRED-C"], n_groups=0, group_size=4)


def test_strategy_routable_generalized_shapes():
    from repro.core.routing import strategy_routable
    assert strategy_routable(Strategy(3, 3, 2), 20)
    assert strategy_routable(Strategy(4, 2, 2), 16)
    assert not strategy_routable(Strategy(5, 5, 1), 20)  # oversubscribed
    # shape-aware path: the actual (n_groups, group_size) fabric shape
    assert strategy_routable(Strategy(3, 3, 2), (5, 4))
    assert strategy_routable(Strategy(4, 2, 2), (4, 4))
    assert not strategy_routable(Strategy(5, 5, 1), (5, 4))  # oversubscribed
    assert strategy_routable(Strategy(1, 1, 1), (2, 2))      # trivial


# --------------------------------------------------------------------------
# (c) sweep engine
# --------------------------------------------------------------------------

def test_strategy_space_respects_constraints():
    sts = strategy_space(20, n_layers=78, min_utilization=0.9)
    assert sts
    assert len(set(sts)) == len(sts)
    for st in sts:
        assert 18 <= st.n_workers <= 20
        assert 78 % st.pp == 0
    # the paper's Transformer-17B strategy is in the space
    assert Strategy(3, 3, 2) in sts


def test_shape_enumeration():
    assert (5, 4) in mesh_shapes(20)
    assert (5, 4) in fred_shapes(20)
    assert all(a * b == 20 for a, b in factor_pairs(20))
    assert all(g >= 2 for g, _k in fred_shapes(20))
    # perfect squares appear once, not twice (16 = 4×4)
    for n in (16, 36):
        assert len(fred_shapes(n)) == len(set(fred_shapes(n)))
        assert len(mesh_shapes(n)) == len(set(mesh_shapes(n)))


def test_sweep_has_no_duplicate_points():
    res = transformer_17b_sweep(16)
    keys = [(r.fabric, r.shape, r.strategy) for r in res]
    assert len(keys) == len(set(keys))


def test_sweep_io_budget_uniform_across_fabrics():
    """Baseline and FRED compete under the same I/O controller count."""
    from repro.core.sweep import _simulator, scaled_n_io
    for n in (16, 20, 32):
        mesh_sim = _simulator("baseline", (n, 1), n, {}, 0.45)
        fred_sim = _simulator("FRED-C", (2, n // 2), n, {}, 0.45)
        assert mesh_sim.mesh.n_io_controllers() == scaled_n_io(n)
        assert fred_sim.fred.n_io == scaled_n_io(n)


@pytest.mark.parametrize("n_npus", [16, 20, 32])
def test_sweep_pareto_nonempty_and_undominated(n_npus):
    res = transformer_17b_sweep(n_npus)
    assert res
    front = [r for r in res if r.pareto]
    assert front                                  # acceptance criterion
    # no Pareto member is dominated by any sweep point of the same fabric
    for r in front:
        same = [o for o in res if o.fabric == r.fabric]
        assert not any(
            o.time_per_sample <= r.time_per_sample and
            o.param_bytes_per_npu <= r.param_bytes_per_npu and
            (o.time_per_sample < r.time_per_sample or
             o.param_bytes_per_npu < r.param_bytes_per_npu)
            for o in same)


def test_sweep_fred_beats_mesh_at_best_point():
    res = transformer_17b_sweep(20)
    best = {f: min(r.time_per_sample for r in res if r.fabric == f)
            for f in ("baseline", "FRED-C", "FRED-D")}
    assert best["FRED-C"] < best["baseline"]
    assert best["FRED-D"] <= best["FRED-C"]


def test_sweep_csv_schema():
    res = transformer_17b_sweep(16)
    rows = to_csv_rows(res)
    n_fields = len(CSV_HEADER.split(","))
    assert len(rows) == len(res)
    assert all(len(r.split(",")) == n_fields for r in rows)


def test_sweep_check_routing_flags():
    res = sweep(transformer_17b, 16, fabrics=("FRED-C",), n_layers=78,
                check_routing=True)
    assert all(r.routable is not None for r in res)
    assert any(r.routable for r in res)


def test_pareto_front_basic():
    res = transformer_17b_sweep(16, fabrics=("FRED-C",))
    front = pareto_front(res)
    assert front and len(front) <= len(res)
