"""Batched sweep engine (ISSUE 4): bit-parity with the scalar oracle.

Deterministic coverage (hypothesis-free — the property-test versions of
the same invariants live in tests/test_batch_engine_props.py):
  (a) seeded-random and fixed-case parity — batched and scalar engines
      agree *bit-identically* on every Breakdown field (hence ``total``)
      and on ``pareto_front`` membership;
  (b) the structural twins (NumPy ring congestion/hops and L1 span) are
      exactly the scalar fabric walks;
  (c) the exhaustive 512-NPU batched sweep's Pareto front is pinned as a
      golden (tests/goldens/sweep512_pareto.json);
  (d) the satellite caches (placement-group memo, LRU collective cache)
      are transparent.
"""

import json
import random
from pathlib import Path

import numpy as np
import pytest

from repro.core.batch_engine import (BatchEngine, CandidateBatch,
                                     _ring_structures_np,
                                     _span_structures_np, feasible_batch,
                                     memory_bytes_batch)
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import (Strategy, cached_placement_groups,
                                  cluster_placement, fred_placement,
                                  placement_groups, strided_group)
from repro.core.simulator import LRUCache, Simulator
from repro.core.specs import ClusterSpec, FabricSpec
from repro.core.sweep import sweep, transformer_17b_sweep
from repro.core.workloads import (MemoryModel, Workload,
                                  memory_bytes_per_npu, paper_workloads,
                                  transformer)

GOLDEN = Path(__file__).parent / "goldens" / "sweep512_pareto.json"

ALL_FABRICS = ("baseline", "FRED-A", "FRED-B", "FRED-C", "FRED-D")


# --------------------------------------------------------------------------
# seeded-random case generation (shared with the hypothesis module)
# --------------------------------------------------------------------------

def random_sim_case(rng: random.Random):
    """(Simulator, Workload) with a random fabric, shape, wafer count,
    inter-wafer topology, hierarchy stacking and strategy — every branch
    of the cost model reachable."""
    from repro.core.cluster import INTER_TOPOLOGIES
    from repro.core.sweep import hierarchy_specs
    fabric = rng.choice(ALL_FABRICS)
    a, b = rng.randint(1, 8), rng.randint(1, 8)
    npw = a * b
    n_wafers = rng.choice((1, 2, 3, 4, 6))
    wafers = rng.randint(1, n_wafers)
    for _ in range(64):
        mp, pp, dpw = rng.randint(1, 4), rng.randint(1, 3), rng.randint(1, 4)
        if mp * pp * dpw <= npw:
            break
    else:
        mp = pp = dpw = 1
    ep = rng.choice([d for d in (1, 2, 3, 4) if dpw % d == 0])
    sp = rng.choice([d for d in (1, 2, 3) if mp % d == 0])
    strategy = Strategy(mp, dpw * wafers, pp, wafers=wafers, ep=ep, sp=sp)
    w = Workload(
        name="rand", n_layers=rng.randint(pp, 60),
        params_per_layer=rng.uniform(1e3, 1e10),
        flops_fwd_per_sample_layer=rng.uniform(1e3, 1e12),
        act_bytes_per_sample=rng.uniform(1.0, 1e7),
        strategy=strategy,
        execution=rng.choice(("stationary", "streaming")),
        mp_allreduce_per_layer=rng.randint(0, 2),
        samples_per_dp=rng.randint(1, 64),
        seq=rng.randint(1, 64),
        kv_bytes_per_sample_layer=rng.uniform(0.0, 1e5),
        a2a_bytes_per_sample_layer=rng.choice((0.0, rng.uniform(1.0, 1e6))),
        expert_param_fraction=rng.uniform(0.0, 0.95),
    )
    cspec = None
    if n_wafers > 1:
        cspec = ClusterSpec(n_wafers=n_wafers,
                            inter_wafer_links=rng.randint(1, 64),
                            inter_wafer_bw=rng.uniform(1e9, 1e12),
                            inter_topology=rng.choice(INTER_TOPOLOGIES),
                            hierarchy=rng.choice(hierarchy_specs(n_wafers, 2)))
    sim = Simulator(fabric,
                    comm_overlap_fraction=rng.choice(
                        (0.0, rng.uniform(0.0, 1.0))),
                    spec=FabricSpec(mesh_shape=(a, b), fred_shape=(a, b),
                                    n_io=rng.randint(1, 32)),
                    cluster_spec=cspec)
    return sim, w


def random_memory_model(rng: random.Random) -> MemoryModel:
    return MemoryModel(
        npu_hbm_bytes=rng.uniform(2**28, 2**36),
        master=rng.choice((True, False)),
        moments_dtype=rng.choice(("float32", "bfloat16", "int8")),
        remat=rng.choice(("none", "block", "full")),
        training=rng.choice((True, False)))


def assert_sweeps_bit_identical(a, b):
    """Shared assertion: same points, bit-equal breakdowns/memory (incl.
    the per-inter-level dp split), same Pareto membership."""
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert (ra.fabric, ra.shape, ra.strategy, ra.n_wafers,
                ra.hierarchy, ra.inter_topology) == \
            (rb.fabric, rb.shape, rb.strategy, rb.n_wafers,
             rb.hierarchy, rb.inter_topology)
        assert rb.breakdown.total == ra.breakdown.total
        assert rb.breakdown.as_dict() == ra.breakdown.as_dict()
        assert rb.breakdown.dp_levels == ra.breakdown.dp_levels
        assert rb.memory_bytes_per_npu == ra.memory_bytes_per_npu
        assert rb.feasible == ra.feasible
        assert rb.pareto == ra.pareto           # front membership


# --------------------------------------------------------------------------
# (a) bit-parity
# --------------------------------------------------------------------------

def test_batched_breakdown_bit_identical_seeded():
    rng = random.Random(0)
    for _ in range(200):
        sim, w = random_sim_case(rng)
        scalar = sim.run(w)
        batched = BatchEngine(sim).run_batch([w])[0]
        assert batched.as_dict() == scalar.as_dict()   # exact, not approx
        assert batched.dp_levels == scalar.dp_levels


def test_memory_batch_bit_identical_seeded():
    rng = random.Random(1)
    for _ in range(200):
        _sim, w = random_sim_case(rng)
        mem = random_memory_model(rng)
        scalar = memory_bytes_per_npu(w, mem)
        arr, feas = feasible_batch([w], mem)
        assert float(arr[0]) == scalar
        assert bool(feas[0]) == (scalar <= mem.npu_hbm_bytes)


@pytest.mark.parametrize("kw", [
    dict(n_npus=20, max_wafers=2),
    dict(n_npus=16, fabrics=ALL_FABRICS),
    dict(n_npus=20, max_wafers=2, memory=MemoryModel()),
    dict(n_npus=24, max_wafers=3, prune_symmetric=True),
])
def test_sweep_engines_agree_fixed_cases(kw):
    def t17b(strat):
        return transformer("T17B", 78, 4256, 1024, strat, "stationary")

    def gpt3(strat):
        return transformer("GPT-3", 96, 12288, 2048, strat, "streaming")

    for wl, nl in ((t17b, 78), (gpt3, 96)):
        a = sweep(wl, n_layers=nl, engine="scalar", **kw)
        b = sweep(wl, n_layers=nl, engine="batched", **kw)
        assert a                                  # non-trivial sweep
        assert_sweeps_bit_identical(a, b)


def _moe_t17b(strat):
    """T17B with mixtral-style expert annotations (per-token dispatch
    bytes + an 80% expert parameter share)."""
    import dataclasses
    w = transformer("T17B-moe", 78, 4256, 1024, strat, "stationary")
    return dataclasses.replace(w, a2a_bytes_per_sample_layer=2 * 4256 * 2.0,
                               expert_param_fraction=0.8)


def test_sweep_engines_agree_on_moe_ep_axes():
    """ISSUE 8 parity: the ep × sp × overlap axes stay bit-identical to
    the scalar oracle on a workload where the EP path is actually hot."""
    kw = dict(n_npus=20, n_layers=78, max_wafers=2, memory=MemoryModel(),
              ep_candidates=(1, 2, 4), sp_candidates=(1, 2),
              comm_overlap_fraction=0.3)
    a = sweep(_moe_t17b, engine="scalar", **kw)
    b = sweep(_moe_t17b, engine="batched", **kw)
    assert {r.strategy.ep for r in a} > {1}     # EP points present
    assert {r.strategy.sp for r in a} > {1}
    assert any(r.breakdown.ep_s > 0 for r in a)
    assert any(r.breakdown.exposed_comm_s > 0 for r in a)
    assert_sweeps_bit_identical(a, b)


def test_ep_axes_at_defaults_bit_identical_to_pr7_sweep():
    """The new sweep kwargs at their defaults reproduce the pre-EP sweep
    bit-for-bit (same guarantee the sweep512 golden pins at scale)."""
    a = transformer_17b_sweep(20)
    b = sweep(lambda st: _moe_t17b(st), 20, n_layers=78,
              ep_candidates=(1,), sp_candidates=(1,),
              comm_overlap_fraction=0.0)
    # same strategy space; ep=1 ignores the expert annotations entirely
    assert [r.strategy for r in a] == [r.strategy for r in b]
    assert [r.breakdown.as_dict() for r in a] == \
        [r.breakdown.as_dict() for r in b]


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        transformer_17b_sweep(16, engine="vectorized")


def test_run_batch_validates_like_scalar():
    sim = Simulator("FRED-C", spec=FabricSpec(fred_shape=(4, 4)))
    w = transformer("t", 12, 256, 64, Strategy(5, 5, 1), "stationary")
    with pytest.raises(ValueError):
        BatchEngine(sim).run_batch([w])
    w = transformer("t", 12, 256, 64, Strategy(2, 2, 2, wafers=2),
                    "stationary")
    with pytest.raises(ValueError):
        BatchEngine(sim).run_batch([w])      # wafers > 1 on a single wafer


# --------------------------------------------------------------------------
# (b) structural twins
# --------------------------------------------------------------------------

def test_ring_structures_np_match_scalar_walk_seeded():
    rng = random.Random(2)
    for _ in range(300):
        rows, cols = rng.randint(1, 24), rng.randint(1, 24)
        stride = rng.randint(1, 16)
        count = rng.randint(2, 32)
        if (count - 1) * stride >= rows * cols:
            continue
        mesh = MeshFabric(rows=rows, cols=cols)
        group = strided_group(count, stride)
        ref = (max(mesh.ring_max_congestion([group]), 1),
               mesh._ring_hops(group))
        assert mesh.ring_structure(group) == ref
        got = _ring_structures_np(rows, cols, np.array([count]),
                                  np.array([stride]))[0]
        assert got == ref


def test_ring_structure_matches_reference_on_arbitrary_groups():
    rng = random.Random(3)
    for _ in range(200):
        rows, cols = rng.randint(1, 16), rng.randint(1, 16)
        n = rows * cols
        if n < 2:
            continue
        group = rng.sample(range(n), rng.randint(2, n))
        mesh = MeshFabric(rows=rows, cols=cols)
        ref = (max(mesh.ring_max_congestion([list(group)]), 1),
               mesh._ring_hops(list(group)))
        assert mesh.ring_structure(group) == ref


def test_span_structures_np_match_scalar_walk_seeded():
    rng = random.Random(4)
    for _ in range(300):
        gs, count, stride = (rng.randint(1, 16), rng.randint(2, 64),
                             rng.randint(1, 16))
        max_id = (count - 1) * stride
        fab = FredFabric(CONFIGS["FRED-C"], n_groups=max_id // gs + 1,
                         group_size=gs)
        ref = fab.span_structure(strided_group(count, stride))
        got = _span_structures_np(gs, np.array([count]),
                                  np.array([stride]))[0]
        assert got == ref


# --------------------------------------------------------------------------
# (c) 512-NPU exhaustive sweep golden
# --------------------------------------------------------------------------

def _front_rows(results):
    rows = []
    for r in sorted((r for r in results if r.pareto),
                    key=lambda r: (r.fabric, r.time_per_sample, r.shape,
                                   (r.strategy.mp, r.strategy.dp,
                                    r.strategy.pp))):
        rows.append({
            "fabric": r.fabric, "shape": list(r.shape),
            "mp": r.strategy.mp, "dp": r.strategy.dp, "pp": r.strategy.pp,
            "wafers": r.strategy.wafers,
            "time_per_sample": r.time_per_sample,
            "param_bytes_per_npu": r.param_bytes_per_npu})
    return rows


def test_sweep512_pareto_golden():
    """The scale the scalar engine cannot touch in CI: an exhaustive
    512-NPU single-wafer sweep (8×64 / 16×32-class FRED shapes), with the
    Pareto front pinned exactly — floats compared bit-for-bit via JSON
    round-trip.  Regenerate with
    ``PYTHONPATH=src:. python -m tests.gen_sweep512_golden`` after an
    *intentional* cost-model change."""
    res = transformer_17b_sweep(512, engine="batched")
    got = _front_rows(res)
    want = json.loads(GOLDEN.read_text())
    assert got == want


def test_sweep512_shapes_exhaustive():
    """The 512-NPU sweep covers the paper-scale FRED shapes exhaustively
    (no sampling): 8×64 and 16×32 among them."""
    res = transformer_17b_sweep(512, engine="batched",
                                fabrics=("FRED-C",))
    shapes = {r.shape for r in res}
    assert (8, 64) in shapes and (16, 32) in shapes
    front = [r for r in res if r.pareto]
    assert front
    # front undominated within the fabric (spot-check the invariant)
    for r in front:
        assert not any(
            o.time_per_sample <= r.time_per_sample and
            o.param_bytes_per_npu <= r.param_bytes_per_npu and
            (o.time_per_sample < r.time_per_sample or
             o.param_bytes_per_npu < r.param_bytes_per_npu)
            for o in res)


# --------------------------------------------------------------------------
# (d) satellite caches and packing
# --------------------------------------------------------------------------

def test_cached_placement_groups_match_uncached():
    for strat in (Strategy(3, 3, 2), Strategy(2, 4, 2),
                  Strategy(1, 20, 1)):
        ref = placement_groups(strat, fred_placement(strat, 20))
        assert cached_placement_groups(strat, 1, 20) == ref
    strat = Strategy(2, 4, 2, wafers=2)
    ref = placement_groups(strat, cluster_placement(strat, 2, 20))
    assert cached_placement_groups(strat, 2, 20) == ref
    with pytest.raises(ValueError):
        cached_placement_groups(Strategy(5, 5, 1), 1, 20)


def test_lru_cache_caps_and_refreshes():
    c = LRUCache(maxsize=3)
    for i in range(3):
        c[i] = i
    assert c.get(0) == 0                 # refresh 0 → 1 is now oldest
    c[3] = 3
    assert 1 not in c and c.get(0) == 0 and len(c) == 3
    c[4] = 4                             # evicts 2
    assert set(c) == {0, 3, 4}
    assert c.get("missing", "dflt") == "dflt"
    with pytest.raises(ValueError):
        LRUCache(maxsize=0)


def test_candidate_batch_take_and_concat():
    ws = [transformer("t", 12, 256, 64, Strategy(m, 1, 1), "stationary")
          for m in (1, 2, 3, 4)]
    pack = CandidateBatch(ws)
    sub = pack.take([1, 3])
    assert [w.strategy.mp for w in sub.workloads] == [2, 4]
    assert sub.mp.tolist() == [2, 4]
    fused = CandidateBatch.concat([sub, pack.take([0])])
    assert fused.mp.tolist() == [2, 4, 1]
    assert len(fused.workloads) == 3


def test_memory_batch_matches_scalar_on_paper_workloads():
    mem = MemoryModel()
    ws = paper_workloads()
    arr = memory_bytes_batch(ws, mem)
    for w, got in zip(ws, arr.tolist()):
        assert got == memory_bytes_per_npu(w, mem)


def test_fast_constructors_cover_every_dataclass_field():
    """sweep._emit and BatchEngine.run_batch build SweepResult/Breakdown
    via __new__ + a hand-written __dict__ (hot per-point paths).  If a
    field is ever added to either dataclass, the fast paths would
    silently produce instances missing it — pin that the constructed
    objects carry exactly the declared fields."""
    import dataclasses
    from repro.core.simulator import Breakdown
    from repro.core.sweep import SweepResult
    res = transformer_17b_sweep(16, engine="batched")
    assert res
    r = res[0]
    assert set(r.__dict__) == {f.name for f in dataclasses.fields(SweepResult)}
    assert set(r.breakdown.__dict__) == \
        {f.name for f in dataclasses.fields(Breakdown)}
