"""Checkpointing, data pipeline, weight streaming, serving, configs."""

import dataclasses
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, all_configs, cells, get_config
from repro.models import transformer as tfm
from repro.models.config import SHAPES, ParallelConfig, ShapeConfig
from repro.models.modules import split
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, PrefetchIterator, SyntheticLM
from repro.train.streaming import HostParams, stream_grads, stream_train_step

KEY = jax.random.PRNGKey(0)
PCFG = ParallelConfig(remat="none")


# --------------------------------------------------------------------------
# configs / registry
# --------------------------------------------------------------------------

def test_registry_complete():
    cfgs = all_configs()
    assert len(cfgs) == 10
    spec = {
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for name, (L, d, H, kv, ff, V) in spec.items():
        c = cfgs[name]
        assert (c.num_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), name


def test_cell_grid_is_40_with_7_skips():
    rows = list(cells())
    assert len(rows) == 40
    skipped = [(a, s.name) for a, _, s, ok, _ in rows if not ok]
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, _, s, ok, _ in rows
                     if ok and s.name == "long_500k"]
    assert sorted(runnable_long) == ["mamba2-1.3b", "mixtral-8x7b",
                                     "zamba2-2.7b"]


def test_vocab_padding_divisible_by_16():
    for c in all_configs().values():
        assert c.padded_vocab % 16 == 0
        assert c.padded_vocab >= c.vocab_size
        # flattened qkv dims divisible by 16 (TP over model=16)
        if c.n_heads:
            assert (c.n_heads * c.head_dim) % 16 == 0
            assert (c.n_kv_heads * c.head_dim) % 16 == 0
        if c.d_ff:
            assert c.d_ff % 16 == 0


def test_mesh_fred_device_order():
    from repro.launch.mesh import fred_device_order
    order = fred_device_order(24, mp=4, dp=3, pp=2)
    # MP-consecutive: devices of an MP group are contiguous
    for d in range(3):
        for p in range(2):
            ids = sorted(order[m, d, p] for m in range(4))
            assert ids == list(range(ids[0], ids[0] + 4))


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=3, extras={"step": 3})
        assert ckpt.latest_step(d) == 3
        # an uncommitted dir must be ignored
        fake = Path(d) / "step_00000009"
        fake.mkdir()
        assert ckpt.latest_step(d) == 3
        restored, extras = ckpt.restore(d, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert extras["step"] == 3


def test_checkpoint_crc_detects_corruption():
    tree = {"a": jnp.arange(100.0)}
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(d, tree, step=1)
        leaf = path / "leaf_00000.npy"
        raw = bytearray(leaf.read_bytes())
        raw[-1] ^= 0xFF
        leaf.write_bytes(bytes(raw))
        with pytest.raises(IOError):
            ckpt.restore(d, tree)


def test_async_checkpointer_and_gc():
    tree = {"a": jnp.ones(16)}
    with tempfile.TemporaryDirectory() as d:
        ac = ckpt.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ac.save(tree, step=s, extras={"step": s})
        ac.wait()
        ac._gc()
        assert ckpt.latest_step(d) == 4
        steps = sorted(int(p.name[5:]) for p in Path(d).iterdir()
                       if p.name.startswith("step_"))
        assert len(steps) <= 2


def test_retry_io_absorbs_transient_oserrors(monkeypatch):
    from repro.train.faults import FlakyIO
    sleeps = []
    monkeypatch.setattr(ckpt.time, "sleep", sleeps.append)
    # two transient faults < IO_RETRIES attempts: absorbed, with
    # exponential backoff between attempts
    fn = FlakyIO(lambda: "ok", failures=2)
    assert ckpt._retry_io(fn, "probe") == "ok"
    assert fn.calls == 3
    assert sleeps == [ckpt.IO_BACKOFF_S, ckpt.IO_BACKOFF_S * 2]
    # a persistent fault exhausts the budget and re-raises
    stuck = FlakyIO(lambda: "never", failures=100)
    with pytest.raises(OSError):
        ckpt._retry_io(stuck, "probe")
    assert stuck.calls == ckpt.IO_RETRIES


def test_checkpoint_save_and_restore_retry_flaky_io(monkeypatch):
    from repro.train.faults import FlakyIO
    monkeypatch.setattr(ckpt.time, "sleep", lambda _s: None)
    tree = {"a": jnp.arange(6.0), "b": jnp.ones(3, jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        flaky_save = FlakyIO(np.save, failures=2)
        monkeypatch.setattr(ckpt.np, "save", flaky_save)
        ckpt.save(d, tree, step=1, extras={"step": 1})
        monkeypatch.setattr(ckpt.np, "save", np.save)
        assert flaky_save.calls > 2          # retried through the faults
        assert ckpt.latest_step(d) == 1
        flaky_load = FlakyIO(np.load, failures=2)
        monkeypatch.setattr(ckpt.np, "load", flaky_load)
        restored, extras = ckpt.restore(d, tree)
        monkeypatch.setattr(ckpt.np, "load", np.load)
        assert flaky_load.calls > 2
        assert extras["step"] == 1
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cleanup_incomplete_idempotent_under_race(monkeypatch):
    """Two recoveries sweeping the same dir concurrently: the second
    rmtree of a dir the 'other' recovery already removed must be a
    no-op, not an error — and the count reflects dirs gone."""
    import shutil as _shutil
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        ckpt.save(d, {"a": jnp.ones(2)}, step=1)
        d1 = root / "step_00000002.tmp"
        d2 = root / "step_00000003.tmp"
        d1.mkdir()
        d2.mkdir()
        real_rmtree = _shutil.rmtree
        state = {"first": True}

        def racing_rmtree(path, **kw):
            # the interleave: while this recovery handles its first
            # debris dir, the other recovery sweeps the rest
            if state["first"]:
                state["first"] = False
                real_rmtree(d2, ignore_errors=True)
            real_rmtree(path, **kw)

        monkeypatch.setattr(ckpt.shutil, "rmtree", racing_rmtree)
        assert ckpt.cleanup_incomplete(d) == 2       # both dirs gone
        monkeypatch.setattr(ckpt.shutil, "rmtree", real_rmtree)
        assert not d1.exists() and not d2.exists()
        assert ckpt.latest_step(d) == 1              # commits untouched
        assert ckpt.cleanup_incomplete(d) == 0       # second sweep no-op
    # root vanished entirely (recovery racing a teardown): still a no-op
    assert ckpt.cleanup_incomplete(d) == 0


def test_torn_save_leaves_sweepable_debris():
    from repro.train.faults import TornWrite, torn_save
    tree = {"a": jnp.arange(4.0), "b": jnp.ones(2)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=1, extras={"step": 1})
        with pytest.raises(TornWrite):
            torn_save(d, tree, step=2)
        debris = Path(d) / "step_00000002.tmp"
        assert debris.exists()                       # partial leaves only
        assert not (debris / "COMMIT").exists()
        assert not (debris / "MANIFEST.json").exists()
        assert ckpt.latest_step(d) == 1              # torn step invisible
        assert ckpt.cleanup_incomplete(d) == 1
        restored, extras = ckpt.restore(d, tree)
        assert extras["step"] == 1


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4)
    src = SyntheticLM(cfg)
    b0 = src.batch(5)
    b1 = src.batch(5)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    it = PrefetchIterator(src, start_step=5)
    got = next(it)
    it.close()
    np.testing.assert_array_equal(got["tokens"], b0["tokens"])
    assert it.state()["step"] == 6


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=8)
    b = SyntheticLM(cfg).batch(0)
    toks = b["tokens"]
    match = (toks[:, 7:] == toks[:, :-7]).mean()
    assert match > 0.2          # injected n-gram structure present


# --------------------------------------------------------------------------
# weight streaming
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_streaming_grads_match_monolithic(arch):
    cfg = get_config(arch).reduced()
    params, _ = split(tfm.init(KEY, cfg))
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.fold_in(KEY, 1),
                                          (2, 16), 0, cfg.vocab_size)}
    loss_ref, grads_ref = jax.value_and_grad(
        lambda p: tfm.loss_fn(p, batch, cfg, PCFG)[0])(params)
    hp = HostParams(params, cfg.num_layers)
    loss_s, g_top, layer_grads = stream_grads(hp, batch, cfg, PCFG)
    assert float(loss_s) == pytest.approx(float(loss_ref), rel=1e-5)
    for i in range(cfg.num_layers):
        ref_i = jax.tree.map(lambda a: np.asarray(a[i]), grads_ref["blocks"])
        for a, b in zip(jax.tree.leaves(ref_i),
                        jax.tree.leaves(layer_grads[i])):
            np.testing.assert_allclose(np.asarray(a), b, atol=5e-6, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(grads_ref["embed"]),
                               np.asarray(g_top["embed"]), atol=5e-6,
                               rtol=1e-4)


@pytest.mark.slow
def test_streaming_training_decreases_loss():
    cfg = get_config("llama3.2-1b").reduced()
    params, _ = split(tfm.init(KEY, cfg))
    hp = HostParams(params, cfg.num_layers)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.fold_in(KEY, 1),
                                          (2, 16), 0, cfg.vocab_size)}
    losses = [stream_train_step(hp, batch, cfg, PCFG, lr=5e-3)
              for _ in range(4)]
    assert losses[-1] < losses[0]


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------

def test_engine_serves_batch_greedy_matches_decode():
    from repro.serve.engine import Engine, EngineConfig, Request
    cfg = get_config("llama3.2-1b").reduced()
    params, _ = split(tfm.init(KEY, cfg))
    eng = Engine(params, cfg, ecfg=EngineConfig(max_batch=4, cache_len=64))
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8]]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    done = eng.run_batch(reqs)
    for r in done:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    # greedy decode is deterministic
    reqs2 = [Request(uid=i, prompt=p, max_new_tokens=6)
             for i, p in enumerate(prompts)]
    done2 = eng.run_batch(reqs2)
    assert [r.output for r in done] == [r.output for r in done2]


# --------------------------------------------------------------------------
# trainer loop (fast end-to-end: init → train → checkpoint → resume)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_runs_and_resumes():
    from repro.launch.mesh import make_mesh
    from repro.train.train_loop import Trainer, TrainerConfig
    cfg = get_config("llama3.2-1b").reduced()
    shape = ShapeConfig("t", "train", 32, 4)
    mesh = make_mesh((1, 1), ("data", "model"))
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(steps=6, log_every=3, checkpoint_every=3,
                             checkpoint_dir=d)
        tr = Trainer(cfg, shape, mesh, PCFG, tcfg=tcfg)
        tr.run()
        assert ckpt.latest_step(d) == 6
        losses = [h["loss"] for h in tr.history]
        assert losses[-1] < losses[0] + 0.1
        # resume continues from step 6
        tcfg2 = TrainerConfig(steps=8, log_every=2, checkpoint_every=100,
                              checkpoint_dir=d)
        tr2 = Trainer(cfg, shape, mesh, PCFG, tcfg=tcfg2)
        tr2.run()
        assert tr2.step == 8
