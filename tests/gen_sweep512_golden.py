"""Regenerate tests/goldens/sweep512_pareto.json — the pinned Pareto
front of the exhaustive 512-NPU single-wafer Transformer-17B sweep
(batched engine).  Run after an *intentional* cost-model change:

    PYTHONPATH=src python -m tests.gen_sweep512_golden

``--check`` regenerates in memory only and exits non-zero if the fresh
front differs from the committed file — the nightly golden-drift gate
(catches env-dependent float drift, e.g. a numpy or libm change on the
CI image, before it surfaces as a confusing PR failure).
"""

import argparse
import json
import sys


def main() -> int:
    from repro.core.sweep import transformer_17b_sweep
    from tests.test_batch_engine import GOLDEN, _front_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff the regenerated front against the "
                         "committed golden instead of overwriting it; "
                         "exit 1 on drift")
    args = ap.parse_args()
    res = transformer_17b_sweep(512, engine="batched")
    rows = _front_rows(res)
    if args.check:
        want = json.loads(GOLDEN.read_text())
        if rows != want:
            changed = sum(1 for a, b in zip(rows, want) if a != b) + \
                abs(len(rows) - len(want))
            print(f"golden drift: regenerated 512-NPU Pareto front "
                  f"differs from {GOLDEN} ({changed} row(s); "
                  f"{len(rows)} fresh vs {len(want)} committed).\n"
                  f"If a cost-model change is intended, regenerate with "
                  f"`python -m tests.gen_sweep512_golden`; otherwise the "
                  f"environment introduced float drift.", file=sys.stderr)
            return 1
        print(f"golden check OK: {len(rows)} Pareto points bit-identical "
              f"to {GOLDEN}")
        return 0
    GOLDEN.write_text(json.dumps(rows, indent=1) + "\n")
    print(f"wrote {GOLDEN} ({len(rows)} Pareto points over "
          f"{len(res)} sweep points)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
