"""Regenerate tests/goldens/sweep512_pareto.json — the pinned Pareto
front of the exhaustive 512-NPU single-wafer Transformer-17B sweep
(batched engine).  Run after an *intentional* cost-model change:

    PYTHONPATH=src python -m tests.gen_sweep512_golden
"""

import json
from pathlib import Path


def main() -> None:
    from repro.core.sweep import transformer_17b_sweep
    from tests.test_batch_engine import GOLDEN, _front_rows
    res = transformer_17b_sweep(512, engine="batched")
    rows = _front_rows(res)
    GOLDEN.write_text(json.dumps(rows, indent=1) + "\n")
    print(f"wrote {GOLDEN} ({len(rows)} Pareto points over "
          f"{len(res)} sweep points)")


if __name__ == "__main__":
    main()
