"""Multi-device integration tests.

These spawn subprocesses with ``xla_force_host_platform_device_count`` so
the main pytest process keeps its single-device view (required by the
task spec: smoke tests see 1 device).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    return proc.stdout


@pytest.mark.slow
def test_pipeline_matches_sequential():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_fn, sequential_reference
        mesh = make_mesh((4,), ("pipe",))
        S, M, B, D = 4, 6, 3, 8
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (S, D, D)) * 0.3,
                  "b": jax.random.normal(key, (S, D)) * 0.1}
        x = jax.random.normal(key, (M, B, D))
        stage = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
        pipe = pipeline_fn(stage, S, M, mesh)
        with mesh:
            y = pipe(params, x)
            g1 = jax.grad(lambda p: jnp.sum(pipe(p, x)**2))(params)
        ref = sequential_reference(stage, params, x, S)
        g2 = jax.grad(lambda p: jnp.sum(
            sequential_reference(stage, p, x, S)**2))(params)
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-5
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4
        print("PIPELINE_OK")
    """)


def test_fred_collectives_equal_flat():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.collectives import build_sync, init_error_feedback
        mesh = make_mesh((2, 4), ("pod", "data"))
        R = 8
        base = {"a": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
                "b": jnp.linspace(-1, 1, 7)}
        locals_ = jax.tree.map(
            lambda g: jnp.stack([g * (1.0 + i) for i in range(R)]), base)
        expect = jax.tree.map(lambda g: g * np.mean(1.0 + np.arange(R)), base)
        with mesh:
            flat = build_sync(mesh, "flat", "data", "pod")(locals_)
            hier = build_sync(mesh, "hierarchical", "data", "pod")(locals_)
            errs = init_error_feedback(jax.tree.map(
                lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), locals_),
                mesh)
            comp, new_errs = build_sync(mesh, "compressed", "data", "pod")(
                locals_, errs)
        for k in base:
            assert float(jnp.max(jnp.abs(flat[k] - expect[k]))) < 1e-4
            assert float(jnp.max(jnp.abs(flat[k] - hier[k]))) < 1e-4
            rel = float(jnp.max(jnp.abs(flat[k] - comp[k])) /
                        (jnp.max(jnp.abs(flat[k])) + 1e-9))
            assert rel < 0.02, rel
        print("COLLECTIVES_OK")
    """)


def test_moe_ep_all_to_all_matches_dense_gather():
    """Expert-parallel grounding (ISSUE 8): the explicit shard_map
    All-to-All dispatch (``moe_ffn_ep``) reproduces the dense-gather
    reference (``moe_ffn`` with one dispatch group per EP rank) on 4
    host devices (the reduced config keeps 4 experts), and its compiled
    HLO contains the dispatch + combine all-to-all pair the analytical
    cost model charges for."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_mesh
        from repro.models import moe as m
        from repro.models.modules import Box

        cfg = get_config("mixtral-8x7b").reduced()
        n = 4
        mesh = make_mesh((n,), ("data",))
        B, S, d = n, 16, cfg.d_model
        params = jax.tree.map(m._v, m.init_moe(jax.random.PRNGKey(0), cfg),
                              is_leaf=lambda p: isinstance(p, Box))
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

        ep = jax.jit(lambda p, x: m.moe_ffn_ep(p, x, cfg, mesh=mesh,
                                               ep_axis="data"))
        with mesh:
            got, aux = ep(params, x)
        ref, aux_ref = m.moe_ffn(params, x, cfg, n_groups=n)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-5, err
        assert abs(float(aux) - float(aux_ref)) < 1e-6

        hlo = ep.lower(params, x).compile().as_text()
        n_a2a = hlo.count(" all-to-all")
        assert n_a2a >= 2, f"expected dispatch+combine all-to-all, {n_a2a}"
        print("MOE_EP_OK", err)
    """, n=4)


def test_moe_ep_ffn_fn_requires_ep_axis():
    """EP is a decision (StrategyDecision.ep > 1), never a silent
    fallback: binding the A2A dispatch without a valid EP axis raises."""
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_mesh
    from repro.models.config import ParallelConfig
    from repro.parallel.sharding import Ruleset
    from repro.parallel.steps import moe_ep_ffn_fn

    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_mesh((1,), ("data",))
    rs = Ruleset(mesh, cfg, ParallelConfig())      # moe_ep_axis unset
    assert rs.ep_axis is None
    with pytest.raises(ValueError, match="moe_ep_axis"):
        moe_ep_ffn_fn(rs, cfg)
    # with the axis set the Ruleset activates EP sharding and the bound
    # fn matches the gather reference even at ep-degree 1
    import jax
    from repro.models import moe as m
    from repro.models.modules import Box
    rs = Ruleset(mesh, cfg, ParallelConfig(moe_ep_axis="data"))
    assert rs.ep_axis == "data"
    fn = moe_ep_ffn_fn(rs, cfg)
    params = jax.tree.map(m._v, m.init_moe(jax.random.PRNGKey(0), cfg),
                          is_leaf=lambda p: isinstance(p, Box))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got, _ = fn(params, x)
    ref, _ = m.moe_ffn(params, x, cfg, n_groups=1)
    assert float(jax.numpy.max(jax.numpy.abs(got - ref))) < 1e-5


@pytest.mark.slow
def test_error_feedback_reduces_bias_over_steps():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.collectives import build_sync, init_error_feedback
        mesh = make_mesh((2, 4), ("pod", "data"))
        R = 8
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (R, 1024)) * 0.1
        sync = build_sync(mesh, "compressed", "data", "pod")
        errs = init_error_feedback({"g": jax.ShapeDtypeStruct((1024,),
                                                              jnp.float32)},
                                   mesh)
        exact = jnp.mean(g, axis=0)
        acc_c = jnp.zeros(1024)
        acc_e = jnp.zeros(1024)
        with mesh:
            for step in range(20):
                out, errs = sync({"g": g}, {"g": errs["g"]})
                acc_c = acc_c + out["g"]
                acc_e = acc_e + exact
        # accumulated compressed sum tracks the exact sum (EF property)
        rel = float(jnp.linalg.norm(acc_c - acc_e) / jnp.linalg.norm(acc_e))
        assert rel < 5e-3, rel
        print("EF_OK", rel)
    """)


@pytest.mark.slow
def test_elastic_restart_8_to_4_devices():
    run_with_devices("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.config import ShapeConfig, ParallelConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.steps import make_train_setup
        from repro.train import checkpoint as ckpt
        from repro.train.elastic import resume_on_mesh
        from repro.train.optim import OptimConfig, init_adam
        from repro.models import transformer as tfm
        from repro.models.modules import split

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        pcfg = ParallelConfig(remat="none")
        ocfg = OptimConfig(warmup_steps=0)
        mesh8 = make_mesh((4, 2), ("data", "model"))
        setup8 = make_train_setup(cfg, shape, mesh8, pcfg, ocfg)
        with mesh8:
            state = jax.jit(
                lambda k: __import__("repro.parallel.steps",
                                     fromlist=["TrainState"]).TrainState(
                    params=split(tfm.init(k, cfg))[0],
                    opt=init_adam(split(tfm.init(k, cfg))[0], ocfg)),
                out_shardings=setup8.state_shardings)(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            state, m = setup8.step_fn(state, batch)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, state, step=1, extras={"step": 1})
            # resume on a 4-device mesh (elastic shrink)
            mesh4 = make_mesh((2, 2), ("data", "model"))
            setup4, state4, step = resume_on_mesh(d, cfg, shape, mesh4,
                                                  pcfg, ocfg)
            assert step == 1
            with mesh4:
                state4, m4 = setup4.step_fn(state4, batch)
            # same logical params → same loss trajectory on both meshes
            with mesh8:
                state8, m8 = setup8.step_fn(state, batch)
        np.testing.assert_allclose(float(m4["loss"]), float(m8["loss"]),
                                   rtol=2e-2)
        print("ELASTIC_OK")
    """)


@pytest.mark.slow
def test_simulated_failure_shrinks_dp_and_resumes():
    """A 'wafer' (2 of 8 devices) dies mid-run: the async checkpointer's
    interrupted save leaves .tmp debris, resume_after_failure sweeps it,
    shrinks (data=4, model=2) to the largest batch-divisible survivor
    mesh (data=2, model=2 — DP degree drops 4→2), re-shards the last
    committed checkpoint onto it, and the loss trajectory continues."""
    run_with_devices("""
        import pathlib, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.config import ShapeConfig, ParallelConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.steps import make_train_setup
        from repro.train import checkpoint as ckpt
        from repro.train.elastic import (plan_shrink, resume_after_failure,
                                         shrink_mesh)
        from repro.train.optim import OptimConfig, init_adam
        from repro.models import transformer as tfm
        from repro.models.modules import split

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        pcfg = ParallelConfig(remat="none")
        ocfg = OptimConfig(warmup_steps=0)
        mesh8 = make_mesh((4, 2), ("data", "model"))
        setup8 = make_train_setup(cfg, shape, mesh8, pcfg, ocfg)
        with mesh8:
            state = jax.jit(
                lambda k: __import__("repro.parallel.steps",
                                     fromlist=["TrainState"]).TrainState(
                    params=split(tfm.init(k, cfg))[0],
                    opt=init_adam(split(tfm.init(k, cfg))[0], ocfg)),
                out_shardings=setup8.state_shardings)(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            state, m = setup8.step_fn(state, batch)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, state, step=1, extras={"step": 1})
            # the failure interrupts the NEXT save: committed step 1 plus
            # half-written step-2 debris is what recovery actually sees
            debris = pathlib.Path(d) / "step_00000002.tmp"
            debris.mkdir()
            (debris / "leaf_00000.npy").write_bytes(b"torn write")

            # kill the last two devices — one dead "wafer" of the cluster
            failed = list(mesh8.devices.flat)[-2:]
            assert plan_shrink(6, 2, shape.global_batch) == (2, 2)
            setup4, state4, step, mesh4 = resume_after_failure(
                d, cfg, shape, mesh8, failed, pcfg, ocfg)
            assert step == 1
            assert dict(mesh4.shape) == {"data": 2, "model": 2}
            alive_ids = {dev.id for dev in mesh4.devices.flat}
            assert not alive_ids & {dev.id for dev in failed}
            assert not debris.exists()          # swept before restore
            with mesh4:
                state4, m4 = setup4.step_fn(state4, batch)
            # the degraded mesh continues the same logical trajectory
            with mesh8:
                state8, m8 = setup8.step_fn(state, batch)
        np.testing.assert_allclose(float(m4["loss"]), float(m8["loss"]),
                                   rtol=2e-2)
        print("FAILOVER_OK")
    """)


def test_plan_shrink_replans_tp_over_divisors():
    """``n_alive < tp`` re-plans the model axis over head/FFN-divisible
    divisors (largest first) instead of raising — the cost-model story
    (``lifetime._elastic_reachable``) made real."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.train.elastic import plan_shrink

    cfg = get_config("llama3.2-1b")
    # survivors still host tp: only the DP degree flexes
    assert plan_shrink(6, 2, 32) == (2, 2)
    # tp-eating failure: 3 < 4, largest divisor 2 divides 32 heads /
    # 8 KV heads / 8192 FFN
    assert plan_shrink(3, 4, 4096, model_cfg=cfg) == (1, 2)
    # head-divisibility filter: 6 heads reject tp=4, land on tp=2
    odd = dataclasses.replace(cfg, n_heads=6, n_kv_heads=6, d_ff=36)
    assert plan_shrink(5, 8, 32, model_cfg=odd) == (2, 2)
    # attention-free (SSM): 0 % k == 0, nothing to reject
    ssm = get_config("mamba2-1.3b")
    assert (ssm.n_heads, ssm.d_ff) == (0, 0)
    assert plan_shrink(3, 4, 32, model_cfg=ssm) == (1, 2)
    # memory gate: a candidate that no longer fits per-NPU HBM is
    # rejected with the reason in the error detail
    from repro.models.config import SHAPES_BY_NAME
    shape = SHAPES_BY_NAME["train_4k"]
    assert plan_shrink(3, 4, shape.global_batch, model_cfg=cfg,
                       shape=shape, npu_hbm_bytes=64 * 2**30) == (1, 2)
    with pytest.raises(ValueError, match="exceeds per-NPU memory"):
        plan_shrink(3, 4, shape.global_batch, model_cfg=cfg,
                    shape=shape, npu_hbm_bytes=1e6)
    # error contracts
    with pytest.raises(ValueError, match="model axis must be ≥ 1"):
        plan_shrink(4, 0, 32)
    with pytest.raises(ValueError, match="no surviving devices"):
        plan_shrink(0, 2, 32)
    with pytest.raises(ValueError, match="pass model_cfg"):
        plan_shrink(1, 2, 32)


def test_shrink_mesh_dedupes_duplicate_failure_reports():
    """A doubly-reported dead device is one failure: duplicated ids in
    ``failed`` must not shrink the survivor set twice, and the survivor
    order stays the original mesh order (minimal re-sharding)."""
    run_with_devices("""
        import jax
        from repro.configs.registry import get_config
        from repro.models.config import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.train.elastic import shrink_mesh

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        mesh8 = make_mesh((4, 2), ("data", "model"))
        devs = list(mesh8.devices.flat)
        dead = devs[-2:]
        # each dead device reported twice, once by object and once by id
        failed = [dead[0], dead[0].id, dead[1], dead[1].id]
        mesh = shrink_mesh(mesh8, failed, shape, cfg=cfg)
        # 6 survivors host tp=2 → (data=2, model=2) after batch fit
        assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh.shape
        kept = [d.id for d in mesh.devices.flat]
        alive = [d.id for d in devs if d.id not in {x.id for x in dead}]
        # survivors keep original mesh order (prefix of the alive list)
        assert kept == alive[:len(kept)], (kept, alive)
        print("DEDUPE_OK")
    """)


@pytest.mark.slow
def test_fault_injection_tp_eating_failure_replans_model_axis():
    """The full lifetime story against the real runtime (train/faults.py):
    a checkpoint save is torn mid-write, 5 of 8 devices die — more than
    the DP axis can absorb (3 survivors < tp=4) — and recovery re-plans
    the model axis onto the largest head/FFN-divisible divisor (tp=2),
    sweeps the debris, restores the last *committed* step, and the loss
    trajectory continues within re-sharding tolerance."""
    run_with_devices("""
        import pathlib, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.config import ShapeConfig, ParallelConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.steps import make_train_setup, TrainState
        from repro.train import checkpoint as ckpt
        from repro.train import faults
        from repro.train.optim import OptimConfig, init_adam
        from repro.models import transformer as tfm
        from repro.models.modules import split

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        pcfg = ParallelConfig(remat="none")
        ocfg = OptimConfig(warmup_steps=0)
        mesh8 = make_mesh((2, 4), ("data", "model"))
        setup8 = make_train_setup(cfg, shape, mesh8, pcfg, ocfg)
        with mesh8:
            state = jax.jit(
                lambda k: TrainState(
                    params=split(tfm.init(k, cfg))[0],
                    opt=init_adam(split(tfm.init(k, cfg))[0], ocfg)),
                out_shardings=setup8.state_shardings)(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            state, m = setup8.step_fn(state, batch)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, state, step=1, extras={"step": 1})
            rec = faults.crash_and_recover(d, cfg, shape, mesh8, state,
                                           torn_step=2, n_failed=5,
                                           seed=0, pcfg=pcfg, ocfg=ocfg)
            # survivors (3) can't host tp=4: re-planned to (data=1,
            # model=2), resumed from the committed step, debris swept
            assert rec.plan == {"data": 1, "model": 2}, rec.plan
            assert rec.resumed_step == 1
            assert not (pathlib.Path(d) / "step_00000002.tmp").exists()
            alive_ids = {dev.id for dev in rec.mesh.devices.flat}
            assert not alive_ids & {dev.id for dev in rec.failed}
            with rec.mesh:
                st2, m2 = rec.setup.step_fn(rec.state, batch)
            with mesh8:
                st8, m8 = setup8.step_fn(state, batch)
        np.testing.assert_allclose(float(m2["loss"]), float(m8["loss"]),
                                   rtol=2e-2)
        print("TP_REPLAN_OK")
    """)


@pytest.mark.slow
def test_mini_dryrun_on_8_devices():
    """End-to-end dry-run plumbing (lower+compile+roofline record) on a
    small mesh with reduced-size shapes, for one arch per family."""
    run_with_devices("""
        import jax
        from repro.configs.registry import get_config
        from repro.models.config import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.steps import make_setup
        from repro.launch.roofline import collective_bytes_from_hlo
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ("llama3.2-1b", "mixtral-8x7b", "mamba2-1.3b"):
            cfg = get_config(arch).reduced()
            for shape in (ShapeConfig("t", "train", 64, 4),
                          ShapeConfig("d", "decode", 64, 4)):
                setup = make_setup(cfg, shape, mesh)
                with mesh:
                    compiled = setup.step_fn.lower(
                        *setup.example_args).compile()
                mem = compiled.memory_analysis()
                colls = collective_bytes_from_hlo(compiled.as_text())
                assert mem.temp_size_in_bytes >= 0
                assert colls["total_bytes"] >= 0
                print(arch, shape.kind, "OK",
                      colls["per_kind_bytes"])
        print("MINI_DRYRUN_OK")
    """, n=8, timeout=900)
