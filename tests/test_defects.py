"""Defect-mask tests (PR 6): mask semantics, defect routing, the
zero-defect bit pin, degraded sweeps, and the yield-study API.

JAX-free — runs in the core CI lane.  Hypothesis deepens the routing
property when available; the seeded-random versions keep the coverage
without it.
"""

import json
import random

import pytest

from repro.core.batch_engine import BatchEngine
from repro.core.defects import (DefectMask, masks_from_json, masks_to_json,
                                mesh_connected, mesh_links, normalize,
                                sample_mask)
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy
from repro.core.simulator import Simulator
from repro.core.specs import ClusterSpec, FabricSpec
from repro.core.sweep import sweep, to_csv_rows, transformer_17b, CSV_HEADER
from repro.core.workloads import MemoryModel, transformer
from repro.core.yield_study import (pick_winner, yield_csv_rows,
                                    yield_study, YIELD_CSV_HEADER)

# --------------------------------------------------------------------------
# DefectMask semantics
# --------------------------------------------------------------------------


def test_mask_json_round_trip():
    m = DefectMask(n_npus=20, dead_npus=(3, 7), dead_links=((0, 1), (5, 9)),
                   dead_uplinks=((2, 1),), seed=42)
    back = DefectMask.from_json(m.to_json())
    assert back == m
    assert back.seed == 42 and back.n_healthy == 18
    # canonicalization survives the trip: unordered input, flipped links
    m2 = DefectMask(n_npus=20, dead_npus=(7, 3, 3), dead_links=((9, 5),
                                                                (1, 0)))
    assert m2.dead_npus == (3, 7)
    assert m2.dead_links == ((0, 1), (5, 9))
    assert DefectMask.from_json(m2.to_json()) == m2


def test_per_wafer_masks_json_round_trip():
    masks = (None,
             DefectMask(n_npus=20, dead_npus=(5, 6), seed=13),
             DefectMask(n_npus=20))              # empty → None on reload
    text = masks_to_json(masks)
    back = masks_from_json(text)
    assert back == (None, masks[1], None)
    assert json.loads(text)[0] is None           # pristine wafer is null
    # stable on-disk form: a second trip is byte-identical
    assert masks_to_json(back[:2] + (None,)) == masks_to_json(back)


def test_mask_validation_and_queries():
    with pytest.raises(ValueError):
        DefectMask(n_npus=4, dead_npus=(0, 1, 2, 3))
    with pytest.raises(ValueError):
        DefectMask(n_npus=4, dead_npus=(4,))
    m = DefectMask(n_npus=6, dead_npus=(2,), dead_links=((0, 1),))
    assert m.healthy() == (0, 1, 3, 4, 5)
    assert m.npu_dead(2) and not m.npu_dead(3)
    assert m.link_dead(0, 1) and m.link_dead(1, 0)
    assert m.link_dead(2, 3)            # dead NPU kills its links
    assert not m.link_dead(3, 4)
    assert m.dead_npu_rate == pytest.approx(1 / 6)


def test_normalize_empty_mask():
    assert normalize(None) is None
    assert normalize(DefectMask(n_npus=8)) is None
    m = DefectMask(n_npus=8, dead_npus=(1,))
    assert normalize(m) is m


def test_sample_mask_deterministic_and_connected():
    kw = dict(dead_npu_rate=0.15, dead_link_rate=0.1, mesh_shape=(5, 4))
    a = sample_mask(20, seed=7, **kw)
    b = sample_mask(20, seed=7, **kw)
    assert a == b and a.seed == 7
    for seed in range(40):
        m = sample_mask(20, seed=seed, **kw)
        assert m.n_healthy >= 1
        assert mesh_connected(m, 5, 4)


def test_sample_mask_uplinks_leave_one_alive():
    m = sample_mask(20, dead_uplink_rate=0.9, seed=3, n_groups=5,
                    uplinks_per_l1=3)
    for l1, n_dead in m.dead_uplinks:
        assert 1 <= n_dead <= 2          # ≥1 of 3 uplinks survives


def test_mesh_connected_is_shape_dependent():
    # dead NPU 1 cuts a 1×4 line in two, but a 2×2 square stays connected
    m = DefectMask(n_npus=4, dead_npus=(1,))
    assert not mesh_connected(m, 1, 4)
    assert mesh_connected(m, 2, 2)


# --------------------------------------------------------------------------
# defect routing: never cross a dead link / dead NPU
# --------------------------------------------------------------------------


def _assert_routes_avoid_defects(rows, cols, mask):
    mesh = MeshFabric(rows=rows, cols=cols, defects=mask)
    healthy = mask.healthy() if mask else tuple(range(rows * cols))
    rng = random.Random(rows * 1000 + cols)
    pairs = [(rng.choice(healthy), rng.choice(healthy)) for _ in range(30)]
    for src, dst in pairs:
        if src == dst:
            continue
        path = mesh.route_links(src, dst)    # [((r, c), (r', c')), ...]
        nodes = [src] + [r * cols + c for _a, (r, c) in path]
        assert nodes[-1] == dst
        for nid in nodes:
            assert not mask.npu_dead(nid), (src, dst, path)
        for a, b in zip(nodes, nodes[1:]):
            assert not mask.link_dead(a, b), (src, dst, path)


def test_routing_avoids_defects_seeded():
    for seed in range(25):
        rows, cols = random.Random(seed).choice(
            [(5, 4), (4, 4), (6, 3), (2, 10), (3, 3)])
        mask = sample_mask(rows * cols, dead_npu_rate=0.15,
                           dead_link_rate=0.12, seed=seed,
                           mesh_shape=(rows, cols))
        mask = normalize(mask)
        if mask is None:
            continue
        _assert_routes_avoid_defects(rows, cols, mask)


def test_route_raises_on_dead_endpoint():
    mask = DefectMask(n_npus=20, dead_npus=(7,))
    mesh = MeshFabric(rows=5, cols=4, defects=mask)
    with pytest.raises(ValueError, match="dead"):
        mesh.route_links(0, 7)


def test_ring_structure_detours_and_stays_finite():
    # kill the straight-line link of a row ring: congestion/hops must
    # reflect the detour, not the dead edge
    mask = DefectMask(n_npus=20, dead_links=((1, 2),))
    healthy = MeshFabric(rows=5, cols=4)
    broken = MeshFabric(rows=5, cols=4, defects=mask)
    group = [0, 1, 2, 3]
    cong_h, hops_h = healthy.ring_structure(group)
    cong_b, hops_b = broken.ring_structure(group)
    assert hops_b > hops_h               # the detour is longer
    assert cong_b >= cong_h >= 1


try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(min_value=0, max_value=10_000),
           shape=st.sampled_from([(5, 4), (4, 4), (6, 3), (3, 3), (2, 8)]))
    @settings(deadline=None)
    def test_routing_avoids_defects_property(seed, shape):
        rows, cols = shape
        mask = normalize(sample_mask(
            rows * cols, dead_npu_rate=0.2, dead_link_rate=0.15,
            seed=seed, mesh_shape=shape))
        if mask is None:
            return
        _assert_routes_avoid_defects(rows, cols, mask)
except ImportError:                       # pragma: no cover
    pass


# --------------------------------------------------------------------------
# the zero-defect bit pin: all-healthy mask ≡ no mask
# --------------------------------------------------------------------------


def _breakdown_bits(br):
    import dataclasses
    return dataclasses.astuple(br)


def test_all_healthy_mask_is_bit_identical():
    empty = DefectMask(n_npus=20)
    for fabric, shape in (("baseline", (5, 4)), ("FRED-D", (5, 4))):
        kw = dict(mesh_shape=shape) if fabric == "baseline" \
            else dict(fred_shape=shape)
        sim_none = Simulator(fabric, spec=FabricSpec(n_io=18, **kw))
        sim_mask = Simulator(fabric, spec=FabricSpec(n_io=18, defects=empty,
                                                     **kw))
        assert sim_mask.defects is None   # normalized away at the boundary
        w = transformer_17b(Strategy(mp=4, dp=5, pp=1))
        assert _breakdown_bits(sim_none.run(w)) == \
            _breakdown_bits(sim_mask.run(w))


def test_sweep_with_empty_mask_bit_identical():
    plain = sweep(transformer_17b, 20, n_layers=78)
    masked = sweep(transformer_17b, 20, n_layers=78,
                   defects=DefectMask(n_npus=20))
    assert to_csv_rows(plain) == to_csv_rows(masked)
    for r in plain:
        assert (r.defect_rate, r.defect_seed, r.degraded_time_s) == \
            (0.0, -1, 0.0)


# --------------------------------------------------------------------------
# degraded sweeps
# --------------------------------------------------------------------------


def test_masked_sweep_respects_capacity_and_tags_rows():
    mask = sample_mask(20, dead_npu_rate=0.1, seed=1, mesh_shape=(5, 4))
    assert not mask.is_empty
    res = sweep(transformer_17b, 20, n_layers=78, min_utilization=0.5,
                defects=mask)
    assert res, "masked sweep found no candidates"
    for r in res:
        st_ = r.strategy
        per_wafer = st_.mp * st_.pp * (st_.dp // max(st_.wafers, 1))
        assert per_wafer <= mask.n_healthy
        assert r.defect_rate == pytest.approx(mask.dead_npu_rate)
        assert r.defect_seed == mask.seed
        assert r.degraded_time_s == r.breakdown.total > 0.0
        if r.fabric == "baseline":
            assert mesh_connected(mask, *r.shape)


def test_masked_sweep_batched_matches_scalar():
    mask = sample_mask(20, dead_npu_rate=0.1, dead_link_rate=0.05,
                       seed=5, mesh_shape=(5, 4))
    assert not mask.is_empty
    kw = dict(n_layers=78, min_utilization=0.5, defects=mask)
    batched = sweep(transformer_17b, 20, engine="batched", **kw)
    scalar = sweep(transformer_17b, 20, engine="scalar", **kw)
    assert to_csv_rows(batched) == to_csv_rows(scalar)


def test_mask_wrong_wafer_size_rejected():
    with pytest.raises(ValueError, match="covers"):
        sweep(transformer_17b, 20, n_layers=78,
              defects=DefectMask(n_npus=16, dead_npus=(0,)))


def test_dead_uplinks_slow_spanning_collectives():
    # severing half the uplinks of two L1s halves the spine share of the
    # DP groups spanning them (mp=4, dp=5: each DP group strides across
    # all five L1 groups) — the degraded time must reflect it on both the
    # endpoint (FRED-C) and in-network (FRED-D) configs
    mask = DefectMask(n_npus=20, dead_uplinks=((0, 2), (1, 2)))
    w = transformer_17b(Strategy(mp=4, dp=5, pp=1))
    spec_kw = dict(fred_shape=(5, 4), n_io=18)
    for fabric in ("FRED-C", "FRED-D"):
        sim_ok = Simulator(fabric, spec=FabricSpec(**spec_kw))
        sim_cut = Simulator(fabric, spec=FabricSpec(defects=mask, **spec_kw))
        assert sim_cut.run(w).total > sim_ok.run(w).total, fabric


def test_csv_header_has_defect_columns():
    cols = CSV_HEADER.split(",")
    assert cols[-3:] == ["defect_rate", "defect_seed", "degraded_time_s"]
    rows = to_csv_rows(sweep(transformer_17b, 20, n_layers=78)[:3])
    assert all(len(r.split(",")) == len(cols) for r in rows)


# --------------------------------------------------------------------------
# per-wafer masks (ClusterSpec.wafer_defects, PR-6 residual)
# --------------------------------------------------------------------------


def _cluster_sim(fabric, *, defects=None, wafer_defects=None):
    kw = dict(mesh_shape=(4, 4)) if fabric == "baseline" \
        else dict(fred_shape=(4, 4))
    return Simulator(fabric, spec=FabricSpec(defects=defects, **kw),
                     cluster_spec=ClusterSpec(n_wafers=2,
                                              wafer_defects=wafer_defects))


def test_per_wafer_masks_cluster_semantics():
    mask = sample_mask(16, dead_npu_rate=0.12, seed=3, mesh_shape=(4, 4))
    assert not mask.is_empty
    w = transformer("T17B", 78, 4256, 1024, Strategy(4, 4, 1, wafers=2),
                    "stationary")
    pristine = _cluster_sim("baseline").run(w).total
    hetero = _cluster_sim(
        "baseline", wafer_defects=(None, mask)).run(w).total
    uniform = _cluster_sim(
        "baseline", wafer_defects=(mask, mask)).run(w).total
    # a dead NPU forces mesh detours: one masked wafer already slows the
    # cluster, masking both slows it at least as much
    assert pristine < hetero <= uniform
    # a uniform per-wafer list is bit-identical to the single
    # FabricSpec.defects mask applied to every wafer
    assert uniform == _cluster_sim("baseline", defects=mask).run(w).total
    # all-pristine list normalizes away entirely
    sim = _cluster_sim("baseline",
                       wafer_defects=(None, DefectMask(n_npus=16)))
    assert sim.wafer_defects is None
    assert sim.run(w).total == pristine
    # FRED fabrics take per-wafer masks too; severed uplinks on one
    # wafer slow the spanning collectives (dead NPUs alone compact away
    # on the reduction tree)
    umask = DefectMask(n_npus=16, dead_uplinks=((0, 2), (1, 2)))
    assert _cluster_sim("FRED-D", wafer_defects=(None, umask)).run(w).total \
        > _cluster_sim("FRED-D").run(w).total
    # capacity gates per wafer: 16 NPUs/wafer needed, the masked wafer
    # has fewer healthy
    big = transformer("T17B", 78, 4256, 1024, Strategy(4, 8, 1, wafers=2),
                      "stationary")
    with pytest.raises(ValueError, match="healthy NPUs on wafer"):
        _cluster_sim("baseline", wafer_defects=(None, mask)).run(big)


def test_per_wafer_masks_validation():
    mask = DefectMask(n_npus=16, dead_npus=(3,))
    # mutually exclusive with the uniform FabricSpec mask
    with pytest.raises(ValueError, match="mutually exclusive"):
        _cluster_sim("baseline", defects=mask, wafer_defects=(None, mask))
    # meaningless on a single wafer — use FabricSpec.defects there
    with pytest.raises(ValueError, match="multi-wafer"):
        Simulator("baseline", spec=FabricSpec(mesh_shape=(4, 4)),
                  cluster_spec=ClusterSpec(n_wafers=1,
                                           wafer_defects=(mask,)))
    # the batched engine only models the uniform mask
    with pytest.raises(NotImplementedError, match="per-wafer"):
        BatchEngine(_cluster_sim("baseline", wafer_defects=(None, mask)))


# --------------------------------------------------------------------------
# yield study
# --------------------------------------------------------------------------


def test_yield_study_transformer_17b():
    rep = yield_study(transformer_17b, 20, n_layers=78, n_masks=16,
                      dead_npu_rate=0.02, seed0=0)
    assert rep.n_masks == 16
    assert 0.0 <= rep.survival_rate <= 1.0
    # the 17B winner packs the full wafer, so any dead NPU kills it and
    # the study must produce a fallback decision for every killing draw
    dead = [o for o in rep.outcomes if not o.survived]
    assert dead, "expected at least one killing draw at 2% over 16 masks"
    for o in dead:
        assert o.reason
        assert o.fallback is not None
        st_ = o.fallback.strategy
        per_wafer = st_.mp * st_.pp * (st_.dp // max(st_.wafers, 1))
        assert per_wafer <= 20 - o.n_dead
    for o in rep.outcomes:
        if o.survived:
            assert o.degraded_time_s > 0 and o.slowdown >= 1.0
    g = rep.golden()
    assert set(g) == {"winner", "survived", "fallbacks"}
    assert g["winner"]["mp"] == rep.winner.strategy.mp
    json.dumps(g)                        # golden must be JSON-serializable
    rows = yield_csv_rows(rep)
    n_cols = len(YIELD_CSV_HEADER.split(","))
    assert len(rows) == 16
    assert all(len(r.split(",")) == n_cols for r in rows)


def test_yield_study_deterministic():
    kw = dict(n_layers=78, n_masks=6, dead_npu_rate=0.05, seed0=11)
    a = yield_study(transformer_17b, 20, **kw)
    b = yield_study(transformer_17b, 20, **kw)
    assert a.golden() == b.golden()
    assert yield_csv_rows(a) == yield_csv_rows(b)


def test_yield_study_infeasible_fallback_reports_dead_not_raise():
    # 16 GiB HBM: the healthy 20-NPU sweep still has feasible points, but
    # with 5 NPUs dead *nothing* fits — the masked re-sweep is empty and
    # the study must report DEAD with a reason, not raise out of
    # pick_winner
    mem = MemoryModel(npu_hbm_bytes=16 * 2**30)
    mask = DefectMask(n_npus=20, dead_npus=tuple(range(5)), seed=77)
    rep = yield_study(transformer_17b, 20, n_layers=78, memory=mem,
                      masks=[mask], fallback=True)
    o = rep.outcomes[0]
    assert not o.survived
    assert o.reason and "capacity" in o.reason
    assert o.fallback is None
    assert rep.survival_rate == 0.0
    assert "no feasible fallback" in rep.summary()
    assert rep.golden()["survived"] == "0/1"


def test_yield_study_explicit_masks_and_pick_winner():
    res = sweep(transformer_17b, 20, n_layers=78)
    w = pick_winner(res)
    assert w.pareto
    masks = [DefectMask(n_npus=20),                      # healthy draw
             DefectMask(n_npus=20, dead_npus=(0,), seed=99)]
    rep = yield_study(transformer_17b, 20, n_layers=78, masks=masks)
    assert rep.n_masks == 2
    assert rep.outcomes[0].survived
    assert rep.outcomes[0].slowdown == 1.0
    assert rep.outcomes[1].seed == 99
