"""Hypothesis property tests for the batched sweep engine (ISSUE 4).

The acceptance property: batched and scalar engines agree bit-identically
on ``Breakdown.total`` (in fact every field) and on ``pareto_front``
membership across random (workload, fabric, shape, wafers, strategy)
draws.  Deterministic seeded-random versions of the same invariants live
in tests/test_batch_engine.py so coverage survives without hypothesis;
this module skips wholesale when hypothesis is absent.
"""

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core.batch_engine import (BatchEngine, _ring_structures_np,
                                     _span_structures_np, feasible_batch)
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy, strided_group
from repro.core.simulator import Simulator
from repro.core.specs import ClusterSpec, FabricSpec
from repro.core.sweep import sweep
from repro.core.workloads import (MemoryModel, Workload,
                                  memory_bytes_per_npu, transformer)
from tests.test_batch_engine import (ALL_FABRICS,
                                     assert_sweeps_bit_identical)


@st.composite
def sim_cases(draw):
    """(Simulator, Workload) with a random fabric, shape, wafer count,
    inter-wafer topology, hierarchy stacking and strategy — every branch
    of the cost model reachable."""
    from repro.core.cluster import INTER_TOPOLOGIES
    from repro.core.sweep import hierarchy_specs
    fabric = draw(st.sampled_from(ALL_FABRICS))
    a = draw(st.integers(min_value=1, max_value=8))
    b = draw(st.integers(min_value=1, max_value=8))
    npw = a * b
    n_wafers = draw(st.sampled_from((1, 2, 3, 4, 6)))
    wafers = draw(st.integers(min_value=1, max_value=n_wafers))
    mp = draw(st.integers(min_value=1, max_value=4))
    pp = draw(st.integers(min_value=1, max_value=3))
    dpw = draw(st.integers(min_value=1, max_value=4))
    assume(mp * pp * dpw <= npw)
    ep = draw(st.sampled_from([d for d in (1, 2, 3, 4) if dpw % d == 0]))
    sp = draw(st.sampled_from([d for d in (1, 2, 3) if mp % d == 0]))
    strategy = Strategy(mp, dpw * wafers, pp, wafers=wafers, ep=ep, sp=sp)
    fin = dict(allow_nan=False, allow_infinity=False)
    w = Workload(
        name="rand", n_layers=draw(st.integers(min_value=pp, max_value=60)),
        params_per_layer=draw(st.floats(1e3, 1e10, **fin)),
        flops_fwd_per_sample_layer=draw(st.floats(1e3, 1e12, **fin)),
        act_bytes_per_sample=draw(st.floats(1.0, 1e7, **fin)),
        strategy=strategy,
        execution=draw(st.sampled_from(("stationary", "streaming"))),
        mp_allreduce_per_layer=draw(st.integers(min_value=0, max_value=2)),
        samples_per_dp=draw(st.integers(min_value=1, max_value=64)),
        seq=draw(st.integers(min_value=1, max_value=64)),
        kv_bytes_per_sample_layer=draw(st.floats(0.0, 1e5, **fin)),
        a2a_bytes_per_sample_layer=draw(st.one_of(
            st.just(0.0), st.floats(1.0, 1e6, **fin))),
        expert_param_fraction=draw(st.floats(0.0, 0.95, **fin)),
    )
    cspec = None
    if n_wafers > 1:
        cspec = ClusterSpec(n_wafers=n_wafers,
                            inter_wafer_links=draw(st.integers(1, 64)),
                            inter_wafer_bw=draw(st.floats(1e9, 1e12, **fin)),
                            inter_topology=draw(
                                st.sampled_from(INTER_TOPOLOGIES)),
                            hierarchy=draw(st.sampled_from(
                                hierarchy_specs(n_wafers, 2))))
    sim = Simulator(fabric,
                    comm_overlap_fraction=draw(st.one_of(
                        st.just(0.0),
                        st.floats(0.0, 1.0, allow_nan=False,
                                  allow_infinity=False))),
                    spec=FabricSpec(
                        mesh_shape=(a, b), fred_shape=(a, b),
                        n_io=draw(st.integers(min_value=1, max_value=32))),
                    cluster_spec=cspec)
    return sim, w


@st.composite
def memory_models(draw):
    fin = dict(allow_nan=False, allow_infinity=False)
    return MemoryModel(
        npu_hbm_bytes=draw(st.floats(2**28, 2**36, **fin)),
        master=draw(st.booleans()),
        moments_dtype=draw(st.sampled_from(("float32", "bfloat16", "int8"))),
        remat=draw(st.sampled_from(("none", "block", "full"))),
        training=draw(st.booleans()))


@settings(deadline=None)
@given(case=sim_cases())
def test_batched_breakdown_bit_identical_to_scalar(case):
    sim, w = case
    scalar = sim.run(w)
    batched = BatchEngine(sim).run_batch([w])[0]
    assert batched.as_dict() == scalar.as_dict()   # exact, not approx
    assert batched.dp_levels == scalar.dp_levels


@settings(deadline=None)
@given(case=sim_cases(), mem=memory_models())
def test_memory_batch_bit_identical_to_scalar(case, mem):
    _sim, w = case
    scalar = memory_bytes_per_npu(w, mem)
    arr, feas = feasible_batch([w], mem)
    assert float(arr[0]) == scalar
    assert bool(feas[0]) == (scalar <= mem.npu_hbm_bytes)


@st.composite
def sweep_cases(draw):
    from repro.core.cluster import INTER_TOPOLOGIES
    n_npus = draw(st.sampled_from((8, 12, 16, 20)))
    max_wafers = draw(st.integers(min_value=1, max_value=4))
    fabrics = tuple(draw(st.sets(st.sampled_from(ALL_FABRICS),
                                 min_size=1, max_size=3)))
    n_layers = draw(st.sampled_from((12, 24, 78)))
    seq = draw(st.sampled_from((64, 1024)))
    execution = draw(st.sampled_from(("stationary", "streaming")))
    mem = draw(st.one_of(st.none(), memory_models()))
    prune = draw(st.booleans())
    topos = tuple(draw(st.sets(st.sampled_from(INTER_TOPOLOGIES),
                               min_size=1, max_size=3)))
    max_levels = draw(st.integers(min_value=1, max_value=2))
    a2a = draw(st.sampled_from((0.0, 8192.0)))
    ep_candidates = draw(st.sampled_from(((1,), (1, 2), (1, 2, 4))))
    sp_candidates = draw(st.sampled_from(((1,), (1, 2))))
    overlap = draw(st.sampled_from((0.0, 0.3)))

    def workload_fn(strat):
        import dataclasses
        w = transformer("rand", n_layers, 1024, seq, strat, execution)
        return dataclasses.replace(
            w, a2a_bytes_per_sample_layer=a2a,
            expert_param_fraction=0.8 if a2a else 0.0)

    return dict(workload_fn=workload_fn, n_npus=n_npus, fabrics=fabrics,
                n_layers=n_layers, max_wafers=max_wafers, memory=mem,
                prune_symmetric=prune, inter_topologies=topos,
                max_levels=max_levels, ep_candidates=ep_candidates,
                sp_candidates=sp_candidates, comm_overlap_fraction=overlap)


@settings(deadline=None, max_examples=20)
@given(kw=sweep_cases())
def test_sweep_engines_agree_on_totals_and_pareto(kw):
    """The tentpole acceptance property, full-sweep form."""
    a = sweep(engine="scalar", **kw)
    b = sweep(engine="batched", **kw)
    assert_sweeps_bit_identical(a, b)


# --------------------------------------------------------------------------
# expert-parallel / overlap properties (ISSUE 8)
# --------------------------------------------------------------------------

@settings(deadline=None)
@given(n=st.integers(2, 64),
       d=st.floats(1.0, 1e12, allow_nan=False, allow_infinity=False))
def test_a2a_traffic_never_exceeds_all_gather(n, d):
    """At equal payload an All-to-All moves no more wire bytes per NPU
    than an All-Gather — every member keeps its own shard in both."""
    from repro.core.flows import (endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    for fn in (endpoint_traffic_bytes, innetwork_traffic_bytes):
        assert fn("all_to_all", n, d) <= fn("all_gather", n, d)


@settings(deadline=None)
@given(case=sim_cases())
def test_exposed_comm_bounded_by_comm_phases(case):
    """exposed_comm_s is exactly the post-overlap mp + ep time, hence
    bounded by the sum of every blocking comm phase."""
    sim, w = case
    br = sim.run(w)
    assert br.exposed_comm_s == br.mp + br.ep_s
    assert br.exposed_comm_s <= br.mp + br.ep_s + br.dp
    assert br.ep_s >= 0.0 and br.exposed_comm_s >= 0.0


@settings(deadline=None)
@given(case=sim_cases())
def test_ep_sp_defaults_bit_identical_to_dense_model(case):
    """ep=1 / sp=1 / overlap=0 reproduce the pre-EP cost and memory model
    bit-for-bit, regardless of expert-traffic annotations."""
    import dataclasses
    sim, w = case
    w0 = dataclasses.replace(
        w, strategy=dataclasses.replace(w.strategy, ep=1, sp=1))
    dense = dataclasses.replace(w0, a2a_bytes_per_sample_layer=0.0,
                                expert_param_fraction=0.0)
    sim0 = Simulator(sim.fabric_name, spec=sim.spec,
                     cluster_spec=sim.cluster_spec,
                     comm_overlap_fraction=0.0)
    a, b = sim0.run(w0), sim0.run(dense)
    assert a.as_dict() == b.as_dict()
    assert a.ep_s == 0.0 and a.exposed_comm_s == a.mp
    mem = MemoryModel()
    assert memory_bytes_per_npu(w0, mem) == memory_bytes_per_npu(dense, mem)


@settings(deadline=None)
@given(rows=st.integers(1, 24), cols=st.integers(1, 24),
       count=st.integers(2, 32), stride=st.integers(1, 16))
def test_ring_structures_np_match_scalar_walk(rows, cols, count, stride):
    assume((count - 1) * stride < rows * cols)
    mesh = MeshFabric(rows=rows, cols=cols)
    group = strided_group(count, stride)
    ref = (max(mesh.ring_max_congestion([group]), 1),
           mesh._ring_hops(group))
    assert mesh.ring_structure(group) == ref
    got = _ring_structures_np(rows, cols, np.array([count]),
                              np.array([stride]))[0]
    assert got == ref


@settings(deadline=None)
@given(gs=st.integers(1, 16), count=st.integers(2, 64),
       stride=st.integers(1, 16))
def test_span_structures_np_match_scalar_walk(gs, count, stride):
    max_id = (count - 1) * stride
    fab = FredFabric(CONFIGS["FRED-C"], n_groups=max_id // gs + 1,
                     group_size=gs)
    ref = fab.span_structure(strided_group(count, stride))
    got = _span_structures_np(gs, np.array([count]), np.array([stride]))[0]
    assert got == ref
