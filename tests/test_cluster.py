"""Multi-wafer scale-out (ISSUE 2 tentpole) + satellite bugfixes.

Covers: (a) WaferCluster hierarchical collectives and cluster placement,
(b) the hard constraint that ``n_wafers=1`` stays bit-identical to the
single-wafer model, (c) the acceptance 2-wafer Transformer-17B sweep with
cross-wafer DP strategies on the Pareto front and per-level DP time in the
breakdown, (d) the layer-truncation and shape-aware-routability bugfixes,
(e) sort-based ``pareto_front`` property tests.
"""

import dataclasses

import pytest

from repro.core.cluster import WaferCluster, WaferLink
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import (Strategy, cluster_placement,
                                  fred_placement, placement_groups)
from repro.core.simulator import Simulator
from repro.core.specs import ClusterSpec
from repro.core.sweep import (CSV_HEADER, cluster_shapes, fred_shapes,
                              mesh_shapes, pareto_front, strategy_space,
                              sweep, to_csv_rows, transformer_17b,
                              transformer_17b_sweep)
from repro.core.workloads import paper_workloads, transformer


# --------------------------------------------------------------------------
# (a) cluster fabric + placement
# --------------------------------------------------------------------------

def test_cluster_id_space_and_io():
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]), 3)
    assert cl.npus_per_wafer == 20 and cl.n_npus == 60
    assert cl.wafer_of(41) == 2 and cl.local_id(41) == 1
    assert cl.wafer_io_rate() == FredFabric(CONFIGS["FRED-C"]).io_stream_rate()
    # MeshFabric wafers work too (n_npus alias)
    assert WaferCluster(MeshFabric(), 2).n_npus == 40


def test_cluster_invalid_shapes():
    with pytest.raises(ValueError):
        WaferCluster(MeshFabric(), 0)
    with pytest.raises(ValueError):
        WaferLink(n_links=0)


def test_cluster_placement_dp_across_wafers_mp_pp_within():
    st = Strategy(2, 4, 2, wafers=2)
    pl = cluster_placement(st, 2, 20)
    groups = placement_groups(st, pl)
    wafer = lambda nid: nid // 20
    # every MP and PP group lives inside one wafer
    for g in groups["mp"] + groups["pp"]:
        assert len({wafer(n) for n in g}) == 1
    # every DP group spans both wafers, evenly
    for g in groups["dp"]:
        spans = [wafer(n) for n in g]
        assert sorted(set(spans)) == [0, 1]
        assert spans.count(0) == spans.count(1) == st.dp // 2


def test_cluster_placement_single_wafer_matches_fred_placement():
    st = Strategy(3, 3, 2)
    assert cluster_placement(st, 1, 20) == fred_placement(st, 20)


def test_cluster_placement_rejections():
    with pytest.raises(ValueError):           # dp not divisible by wafers
        cluster_placement(Strategy(2, 3, 1, wafers=2), 2, 20)
    with pytest.raises(ValueError):           # per-wafer overflow
        cluster_placement(Strategy(4, 4, 2, wafers=2), 2, 10)
    with pytest.raises(ValueError):           # more wafers than cluster has
        cluster_placement(Strategy(1, 4, 1, wafers=4), 2, 20)


def test_hierarchical_collective_parts():
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]), 2)
    D = 1e8
    # group inside one wafer: pure intra
    intra, inter = cl.collective_time_parts("all_reduce", [0, 1, 2, 3], D)
    assert intra > 0 and inter == 0.0
    # group spanning wafers: both levels
    span = [0, 1, 20, 21]
    intra, inter = cl.collective_time_parts("all_reduce", span, D)
    assert intra > 0 and inter > 0
    # one member per wafer: no local reduce-scatter possible — pure inter
    intra, inter = cl.collective_time_parts("all_reduce", [0, 20], D)
    assert intra == 0.0 and inter > 0
    # only All-Reduce and All-to-All cross wafers (MP/PP stay within one)
    with pytest.raises(NotImplementedError):
        cl.collective_time_parts("all_gather", span, D)


def test_hierarchical_all_to_all_parts():
    """Cross-wafer expert All-to-All (ISSUE 8): wafer-local exchange of
    the k/n payload share + the full payload over each spanned level —
    no RS/AG sandwich (nothing to reduce)."""
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]), 2)
    wafer = FredFabric(CONFIGS["FRED-C"])
    D = 1e8
    # contained in one wafer: pure intra, identical to the wafer fabric
    intra, inter = cl.collective_time_parts("all_to_all", [0, 1, 2, 3], D)
    assert inter == 0.0
    assert intra == wafer.collective_time("all_to_all", [0, 1, 2, 3], D)
    # spanning both wafers: 2 members per wafer exchange D·k/n = D/2
    # locally, the full D crosses the wafer level
    intra_s, inter_s = cl.collective_time_parts("all_to_all",
                                                [0, 1, 20, 21], D)
    assert inter_s > 0
    assert intra_s == wafer.collective_time("all_to_all", [0, 1], D * 2 / 4)
    # one member per wafer: nothing to exchange locally — pure inter
    intra_1, inter_1 = cl.collective_time_parts("all_to_all", [0, 20], D)
    assert intra_1 == 0.0 and inter_1 > 0


def test_inter_wafer_ring_scales_with_link_budget():
    fast = WaferCluster(MeshFabric(), 2, WaferLink(n_links=32))
    slow = WaferCluster(MeshFabric(), 2, WaferLink(n_links=8))
    D = 1e9
    assert slow.inter_allreduce_time(2, D) > fast.inter_allreduce_time(2, D)
    # more wafers → more ring steps → more time
    assert fast.inter_allreduce_time(4, D) > fast.inter_allreduce_time(2, D)


# --------------------------------------------------------------------------
# (b) n_wafers=1 bit-identical, cluster simulation sane
# --------------------------------------------------------------------------

def test_single_wafer_cluster_params_are_bit_identical():
    for w in paper_workloads():
        for fab in ("baseline", "FRED-A", "FRED-C", "FRED-D"):
            a = Simulator(fab).run(w).as_dict()
            b = Simulator(fab,
                          cluster_spec=ClusterSpec(n_wafers=1)).run(w).as_dict()
            assert a == b, (fab, w.name)


def test_sweep_max_wafers_one_is_bit_identical():
    a = transformer_17b_sweep(16)
    b = transformer_17b_sweep(16, max_wafers=1)
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert (ra.fabric, ra.shape, ra.strategy) == \
            (rb.fabric, rb.shape, rb.strategy)
        assert ra.total == rb.total and ra.pareto == rb.pareto
        assert rb.n_wafers == 1 and rb.inter_wafer_bw == 0.0


def test_two_wafer_dp_beats_single_wafer_throughput():
    """Doubling wafers doubles the minibatch; the hierarchical DP exchange
    must cost less than the throughput it buys at the default link budget."""
    st1 = Strategy(2, 5, 2)
    st2 = Strategy(2, 10, 2, wafers=2)
    t1 = Simulator("FRED-C").run(
        transformer("T17B", 78, 4256, 1024, st1, "stationary"))
    t2 = Simulator("FRED-C", cluster_spec=ClusterSpec(n_wafers=2)).run(
        transformer("T17B", 78, 4256, 1024, st2, "stationary"))
    assert t2.dp_inter > 0 and t2.dp_intra > 0
    assert t2.total / (10 * 16) < t1.total / (5 * 16)


def test_simulator_rejects_bad_wafer_counts():
    with pytest.raises(ValueError):
        Simulator("FRED-C", cluster_spec=ClusterSpec(n_wafers=0))
    w = transformer("T17B", 78, 4256, 1024, Strategy(2, 4, 2, wafers=4),
                    "stationary")
    with pytest.raises(ValueError):           # strategy spans 4, cluster has 2
        Simulator("FRED-C", cluster_spec=ClusterSpec(n_wafers=2)).run(w)
    w2 = transformer("T17B", 78, 4256, 1024, Strategy(2, 4, 2, wafers=2),
                     "stationary")
    with pytest.raises(ValueError):           # wafer split on a single wafer
        Simulator("FRED-C").run(w2)


def test_inter_wafer_traffic_independent_of_local_fanin():
    """The k per-member shard rings share the wafer↔wafer links, so a DP
    group's inter-wafer time is set by its full payload, not payload/k."""
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]), 2)
    D = 1e9
    _, inter_k1 = cl.collective_time_parts("all_reduce", [0, 20], D)
    _, inter_k4 = cl.collective_time_parts(
        "all_reduce", [0, 1, 2, 3, 20, 21, 22, 23], D)
    assert inter_k4 == pytest.approx(inter_k1)


# --------------------------------------------------------------------------
# (c) the acceptance sweep
# --------------------------------------------------------------------------

def test_two_wafer_t17b_sweep_acceptance():
    res = transformer_17b_sweep(20, max_wafers=2)
    # the w=1 slice is exactly the single-wafer sweep
    single = {(r.fabric, r.shape, r.strategy): r.total
              for r in transformer_17b_sweep(20)}
    for r in res:
        if r.n_wafers == 1:
            assert single[(r.fabric, r.shape, r.strategy)] == r.total
    # at least one cross-wafer DP strategy on the Pareto front, with
    # per-level DP time in its breakdown
    cross = [r for r in res if r.pareto and r.strategy.wafers > 1]
    assert cross
    assert any(r.breakdown.dp_inter > 0 for r in cross)
    assert all(r.strategy.dp % r.strategy.wafers == 0 for r in cross)


def test_explicit_wafer_strategies_always_run():
    """Explicitly passed strategies widen max_wafers instead of being
    silently dropped."""
    sts = [Strategy(2, 5, 2), Strategy(2, 10, 2, wafers=2)]
    res = sweep(transformer_17b, 20, fabrics=("FRED-C",), strategies=sts)
    by_wafers = {r.strategy.wafers for r in res}
    assert by_wafers == {1, 2}


def test_cluster_shapes_enumeration():
    assert cluster_shapes(20, 1) == [(1, s) for s in fred_shapes(20)]
    cs = cluster_shapes(20, 3, mesh_shapes)
    assert (2, (5, 4)) in cs and (3, (5, 4)) in cs
    assert len(cs) == 3 * len(mesh_shapes(20))
    with pytest.raises(ValueError):
        cluster_shapes(20, 0)


def test_strategy_space_wafer_axis():
    sts = strategy_space(40, n_layers=78, n_wafers=2)
    assert any(st.wafers == 2 for st in sts)
    for st in sts:
        if st.wafers == 2:
            assert st.dp % 2 == 0
    # wafer axis off by default
    assert all(st.wafers == 1 for st in strategy_space(40, n_layers=78))


def test_sweep_csv_has_wafer_columns():
    res = transformer_17b_sweep(16, max_wafers=2,
                                fabrics=("baseline", "FRED-C"))
    header = CSV_HEADER.split(",")
    for col in ("n_wafers", "inter_wafer_bw", "dp_intra_s", "dp_inter_s"):
        assert col in header
    rows = to_csv_rows(res)
    assert all(len(r.split(",")) == len(header) for r in rows)
    iw = header.index("n_wafers")
    assert {r.split(",")[iw] for r in rows} == {"1", "2"}
    # total NPUs column scales with the wafer count
    inpus = header.index("n_npus")
    for r, row in zip(res, rows):
        assert int(row.split(",")[inpus]) == \
            r.shape[0] * r.shape[1] * r.n_wafers


# --------------------------------------------------------------------------
# (d) satellite bugfixes
# --------------------------------------------------------------------------

def test_uneven_pipeline_stages_not_truncated():
    """78 layers over pp=5 used to silently model 15·5 = 75 layers; the
    bottleneck stage now has ceil(78/5) = 16."""
    st_even = Strategy(2, 1, 6)     # 13 layers/stage exactly
    st_odd = Strategy(2, 1, 5)      # 78 = 5·15 + 3 → ceil 16
    mk = lambda st: transformer("T17B", 78, 4256, 1024, st, "stationary")
    sim = Simulator("FRED-C")
    even, odd = sim.run(mk(st_even)), sim.run(mk(st_odd))
    # per-stage compute at 16 layers exceeds the truncated 15-layer model:
    # compute / bubble / layers gives the per-layer time, equal across runs
    even_layer = even.compute / ((8 + 6 - 1) / 8) / 13
    odd_layer = odd.compute / ((8 + 5 - 1) / 8) / 16
    assert even_layer == pytest.approx(odd_layer)
    with pytest.raises(ValueError):           # pp > n_layers is meaningless
        sim.run(transformer("tiny", 4, 64, 8, Strategy(1, 1, 6),
                            "stationary"))


def test_route_memo_is_shape_aware():
    """Routability differs per (n_groups, group_size) shape — the sweep
    memo must not reuse one shape's verdict for another."""
    from repro.core.routing import strategy_routable
    res = sweep(transformer_17b, 16, fabrics=("FRED-C",), n_layers=78,
                check_routing=True)
    up = FredFabric(CONFIGS["FRED-C"]).uplinks_per_l1()
    for r in res:
        st = r.strategy if r.strategy.wafers == 1 else dataclasses.replace(
            r.strategy, dp=r.strategy.dp_per_wafer, wafers=1)
        assert r.routable == strategy_routable(st, r.shape, uplinks=up), \
            (r.strategy, r.shape)


def test_shape_aware_routability_depends_on_uplinks():
    """A strided-DP phase puts one flow per local NPU on each L1 uplink;
    with a single uplink port those flows exceed m=3 colors, with the
    FRED-C wafer's 4 uplinks they route."""
    from repro.core.routing import strategy_routable
    st = Strategy(4, 5, 1)                    # 4 DP groups span all 5 L1s
    assert strategy_routable(st, (5, 4), uplinks=4)
    assert not strategy_routable(st, (5, 4), uplinks=1)


def test_fred_bisection_consistent_with_mesh_definition():
    """Pinned values for the fixed bisection-cut formula (the seed's
    `/ 2 * 2` canceled and over-counted odd group counts)."""
    cfg = CONFIGS["FRED-C"]
    for g, expect_links in ((2, 1), (4, 2), (5, 2), (8, 4)):
        fab = FredFabric(cfg, n_groups=g, group_size=4)
        assert fab.bisection == 2 * expect_links * cfg.l1_l2_bw
        assert fab.bisection_bw() == fab.bisection


# --------------------------------------------------------------------------
# (e) pareto_front properties (sort-based O(n log n) pass)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Point:
    time_per_sample: float
    param_bytes_per_npu: float


def _brute_force_front(points):
    def dominated(p):
        return any(o.time_per_sample <= p.time_per_sample and
                   o.param_bytes_per_npu <= p.param_bytes_per_npu and
                   (o.time_per_sample < p.time_per_sample or
                    o.param_bytes_per_npu < p.param_bytes_per_npu)
                   for o in points)
    return [p for p in points if not dominated(p)]


def test_pareto_front_matches_brute_force_on_sweep():
    res = transformer_17b_sweep(16, fabrics=("FRED-C",))
    fast = pareto_front(res)
    slow = _brute_force_front(res)
    assert [id(r) for r in fast] == [id(r) for r in slow]


def test_pareto_front_duplicates_survive_together():
    pts = [_Point(1.0, 2.0), _Point(1.0, 2.0), _Point(2.0, 1.0),
           _Point(2.0, 2.0)]
    front = pareto_front(pts)
    assert front == [pts[0], pts[1], pts[2]]


def test_pareto_front_empty_and_single():
    assert pareto_front([]) == []
    p = _Point(1.0, 1.0)
    assert pareto_front([p]) == [p]


def test_pareto_front_hypothesis_properties():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hst

    coords = hst.integers(min_value=0, max_value=6).map(float)
    points = hst.lists(hst.tuples(coords, coords), max_size=40)

    @settings(deadline=None)
    @given(points)
    def check(raw):
        pts = [_Point(a, b) for a, b in raw]
        front = pareto_front(pts)
        # matches the O(n²) reference, in input order
        assert [id(p) for p in front] == \
            [id(p) for p in _brute_force_front(pts)]
        # no survivor is dominated by any point
        for f in front:
            assert not any(
                o.time_per_sample <= f.time_per_sample and
                o.param_bytes_per_npu <= f.param_bytes_per_npu and
                (o.time_per_sample < f.time_per_sample or
                 o.param_bytes_per_npu < f.param_bytes_per_npu)
                for o in pts)
        # idempotence: the front of the front is itself
        assert pareto_front(front) == front
        # non-empty input keeps at least one point
        assert front or not pts

    check()
