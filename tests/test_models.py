"""Per-architecture smoke tests (reduced configs, CPU) + model invariants.

Required by the task spec: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step asserting output shapes
and no NaNs.  Full configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as tfm
from repro.models.attention import (apply_rope, chunked_attention,
                                    dense_attention, repeat_kv)
from repro.models.config import ParallelConfig
from repro.models.modules import split
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.models.whisper import encode

PCFG = ParallelConfig(remat="none")
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


def enc_fn_for(cfg):
    if cfg.family != "audio":
        return None
    return lambda p, b: encode(p, b, cfg, PCFG)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params, axes = split(tfm.init(KEY, cfg))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: tfm.loss_fn(p, b, cfg, PCFG, enc_fn=enc_fn_for(cfg))
    )(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    assert float(loss) == pytest.approx(np.log(cfg.vocab_size), rel=0.15)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b",
                                  "mamba2-1.3b", "zamba2-2.7b"])
def test_arch_smoke_train_step(arch):
    """One full optimizer step decreases loss on a repeated batch."""
    from repro.train.optim import OptimConfig, adam_update, init_adam
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = split(tfm.init(KEY, cfg))
    batch = make_batch(cfg)
    ocfg = OptimConfig(lr=5e-3, warmup_steps=0, weight_decay=0.0)
    opt = init_adam(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(p, batch, cfg, PCFG), has_aux=True)(params)
        params, opt, _ = adam_update(params, grads, opt, ocfg)
        return params, opt, loss

    losses = []
    for _ in range(5):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:  # capacity dropping differs between runs — disable
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params, _ = split(tfm.init(KEY, cfg))
    B, S, S0, CACHE = 2, 20, 16, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = make_batch(cfg, B, S0)
    batch["tokens"] = toks[:, :S0]
    enc = enc_fn_for(cfg)
    logits, state = tfm.prefill(params, batch, cfg, PCFG, CACHE, enc_fn=enc)
    outs = [logits]
    for t in range(S0, S):
        lg, state = tfm.decode_step(params, toks[:, t:t + 1], state, cfg, PCFG)
        outs.append(lg)
    for t, lg in zip(range(S0, S + 1), outs):
        b2 = dict(batch)
        b2["tokens"] = toks[:, :t]
        ref, _ = tfm.prefill(params, b2, cfg, PCFG, CACHE, enc_fn=enc)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   atol=2e-3, rtol=2e-2)


# --------------------------------------------------------------------------
# attention invariants
# --------------------------------------------------------------------------

def test_chunked_matches_dense():
    B, S, H, hd = 2, 100, 3, 16
    q = jax.random.normal(KEY, (B, S, H, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    for causal in (True, False):
        for window in (0, 17):
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    q_chunk=32, k_chunk=16)
            ref = dense_attention(q, k, v, causal=causal, window=window)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5)


def test_gqa_repeat_equivalence():
    """GQA with repeated KV == MHA with shared heads."""
    B, S, Hq, Hkv, hd = 2, 24, 4, 2, 8
    q = jax.random.normal(KEY, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, hd))
    out = dense_attention(q, k, v)
    out2 = dense_attention(q, repeat_kv(k, 2), repeat_kv(v, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 16, 2, 32
    x = jax.random.normal(KEY, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relativity: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 1, 1, hd))
    def dot_at(p, d):
        pq = jnp.full((1, 1), p)
        pk = jnp.full((1, 1), p + d)
        return float(jnp.sum(apply_rope(q, pq, 1e4) * apply_rope(k, pk, 1e4)))
    assert dot_at(0, 3) == pytest.approx(dot_at(7, 3), abs=1e-4)


def test_swa_masks_out_of_window():
    B, S, H, hd = 1, 32, 1, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd))
    v0 = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    # perturbing v outside the window must not change the last query's out
    w = 8
    v1 = v0.at[:, : S - w].add(100.0)
    o0 = dense_attention(q, k, v0, causal=True, window=w)
    o1 = dense_attention(q, k, v1, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o0[:, -1]), np.asarray(o1[:, -1]),
                               atol=1e-5)


# --------------------------------------------------------------------------
# SSD invariants
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_ssd_chunked_matches_reference():
    B, S, H, hd, G, N = 2, 50, 4, 8, 2, 6
    x = jax.random.normal(KEY, (B, S, H, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, N)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, G, N)) * 0.4
    for chunk in (8, 16, 64):
        y = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        yr = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.slow
def test_ssd_state_carry():
    """Running two halves with carried state == one full run."""
    B, S, H, hd, G, N = 1, 40, 2, 8, 1, 4
    x = jax.random.normal(KEY, (B, S, H, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, N)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, G, N)) * 0.4
    full, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8, return_state=True)
    h = S // 2
    y1, st = ssd_chunked(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h],
                         chunk=8, return_state=True)
    y2 = ssd_chunked(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:],
                     chunk=8, initial_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=2e-4, rtol=1e-3)
