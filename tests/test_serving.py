"""Serving cost model tests (ISSUE 10 tentpole): phase rooflines, the
M/D/c queueing closed form vs the seeded traffic simulator, serving-cell
candidate enumeration, the unified choose(DeploymentRequest) API, and
the legacy choose_strategy shim's bit-identity.

JAX-free — runs in the core CI lane.  The structural pins:

  * the closed-form queueing stats agree with the discrete-event
    simulator to <1 % on mean TTFT (the lifetime.py
    estimate-vs-simulate contract), and exactly recover the
    Pollaczek–Khinchine M/D/1 mean wait at c=1;
  * disaggregated serving never loses raw capacity to co-located at
    equal hardware (per-phase optima over a superset, by construction);
  * the batched decode-step engine is bit-identical to the scalar
    oracle;
  * the legacy ``choose_strategy(**kwargs)`` shim warns and resolves to
    a decision bit-identical to ``choose(DeploymentRequest(...))``.
"""

import json
import math
import warnings

import numpy as np
import pytest

from repro.core.autostrategy import (SERVESWEEP_ARCHS, SERVE_OBJECTIVE,
                                     SERVE_SWEEP_KW, check_serving_goldens,
                                     choose, choose_serving_strategy,
                                     choose_strategy,
                                     serving_decision_table)
from repro.core.serving import (BATCH_CANDIDATES, CellCandidate,
                                InfeasibleServingError, ModelTerms,
                                NPU_HBM_BW, RequestProfile, SLOT_POOL_CAP,
                                decide_serving, decode_step_terms,
                                decode_step_terms_batch, erlang_c,
                                model_terms, pareto_indices,
                                prefill_time_s, queue_stats,
                                serving_candidates,
                                serving_memory_bytes_per_npu,
                                simulate_traffic, slo_capacity_rps)
from repro.core.specs import DeploymentRequest, Objective
from repro.core.workloads import DEFAULT_NPU_HBM_BYTES

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover
    HAVE_HYPOTHESIS = False

GOLDEN_PATH = "tests/goldens/servesweep.json"


def _cfg(arch="qwen3-32b"):
    from repro.configs.registry import get_config
    return get_config(arch)


# --------------------------------------------------------------------------
# phase rooflines
# --------------------------------------------------------------------------

def test_model_terms_qwen():
    terms = model_terms(_cfg(), RequestProfile(1024, 256))
    # 32B-class param count at 2 bytes each
    assert 25e9 < terms.param_bytes_total / 2 < 40e9
    # GQA KV: 2 · d_kv · 2 bytes · 64 layers = 2·1024·2·64
    assert terms.kv_bytes_per_token == 2 * 1024 * 2 * 64
    assert terms.n_layers == 64 and terms.mp_allreduce_per_layer == 2


def test_decode_step_hbm_bound():
    # tiny compute, huge weights: the step must sit on the HBM roofline
    step = decode_step_terms(1e3, 1e9, 1e5, 0.0, 8, 1e15)
    assert step == pytest.approx((1e9 + 8 * 1e5) / NPU_HBM_BW)


def test_prefill_compute_bound():
    terms = model_terms(_cfg(), RequestProfile(1024, 256))
    eff = 1000e12 * 0.45
    t = prefill_time_s(terms, RequestProfile(1024, 256), 16, 0.0, eff)
    compute = 1024 * terms.prefill_flops_per_token / 16 / eff
    assert t == pytest.approx(compute)   # prompt FLOPs dominate one read


def test_decode_batch_matches_scalar_bitwise():
    terms = model_terms(_cfg(), RequestProfile(1024, 256))
    eff = 1000e12 * 0.45
    batches = np.array(BATCH_CANDIDATES, dtype=np.float64)
    coll = np.linspace(1e-5, 3e-4, len(batches))
    got = decode_step_terms_batch(
        terms.decode_flops_per_token / 16, terms.param_bytes_total / 16,
        1280 * terms.kv_bytes_per_token / 16, coll, batches, eff, 0.3)
    for i, b in enumerate(BATCH_CANDIDATES):
        want = decode_step_terms(
            terms.decode_flops_per_token / 16,
            terms.param_bytes_total / 16,
            1280 * terms.kv_bytes_per_token / 16, float(coll[i]), b,
            eff, 0.3)
        assert got[i] == want            # bitwise, not approx


def test_serving_memory_monotone_in_batch():
    mems = [serving_memory_bytes_per_npu(_cfg(), RequestProfile(1024, 256),
                                         16, b, DEFAULT_NPU_HBM_BYTES)
            for b in (1, 8, 64)]
    assert mems[0] < mems[1] < mems[2]


# --------------------------------------------------------------------------
# queueing: closed form vs discrete-event simulation
# --------------------------------------------------------------------------

def test_erlang_c_bounds():
    assert erlang_c(4, 0.0) == 0.0
    assert erlang_c(4, 4.0) == 1.0
    assert 0.0 < erlang_c(4, 2.0) < 1.0


def test_queue_stats_md1_pollaczek_khinchine():
    # at c=1 the approximation is exact M/D/1: W = rho·D / (2(1−rho))
    lam, D = 0.6, 1.0
    stats = queue_stats(lam, D, 1)
    rho = lam * D
    assert stats.mean_wait_s == pytest.approx(rho * D / (2 * (1 - rho)))


def test_queue_stats_unstable():
    stats = queue_stats(2.0, 1.0, 1)
    assert math.isinf(stats.mean_wait_s)
    assert math.isinf(stats.p99_wait_s)


@pytest.mark.parametrize("slots,util", [(1, 0.6), (1, 0.75), (64, 0.8),
                                        (SLOT_POOL_CAP, 0.9)])
def test_estimate_vs_simulate_under_1pct(slots, util):
    """The <1 % contract at the regimes decisions operate in: c=1 (the
    closed form is exact Pollaczek–Khinchine) and pooled-slot cells
    (where the Erlang-C wait is a small correction on the base TTFT —
    exactly how every servesweep decision lands)."""
    service_s = 0.5
    lam = util * slots / service_s
    base = 0.05
    est = base + queue_stats(lam, service_s, slots).mean_wait_s
    sim = simulate_traffic(lam, service_s, slots, base_latency_s=base,
                           seed=0)
    assert abs(est - sim["mean_ttft_s"]) / sim["mean_ttft_s"] < 0.01


def test_simulate_traffic_seeded_deterministic():
    a = simulate_traffic(10.0, 0.5, 8, seed=7, n_requests=20_000)
    b = simulate_traffic(10.0, 0.5, 8, seed=7, n_requests=20_000)
    assert a == b
    c = simulate_traffic(10.0, 0.5, 8, seed=8, n_requests=20_000)
    assert a != c


if HAVE_HYPOTHESIS:

    @given(util=st.floats(0.05, 0.95), slots=st.integers(1, 256),
           service_ms=st.floats(1.0, 5000.0))
    @settings(deadline=None)
    def test_wait_monotone_in_arrival_rate(util, slots, service_ms):
        """p99 TTFT is monotone non-decreasing in the arrival rate —
        the property the SLO-capacity bisection relies on."""
        service_s = service_ms / 1e3
        hi = util * slots / service_s
        lo = 0.5 * hi
        s_lo, s_hi = (queue_stats(r, service_s, slots) for r in (lo, hi))
        assert s_lo.mean_wait_s <= s_hi.mean_wait_s + 1e-12
        assert s_lo.p99_wait_s <= s_hi.p99_wait_s + 1e-12

    @given(lam=st.floats(0.1, 50.0), slots=st.integers(1, 64),
           service_s=st.floats(0.01, 2.0))
    @settings(deadline=None)
    def test_queue_stats_quantiles_ordered(lam, slots, service_s):
        stats = queue_stats(lam, service_s, slots)
        if math.isfinite(stats.mean_wait_s):
            assert 0.0 <= stats.p50_wait_s <= stats.p99_wait_s


# --------------------------------------------------------------------------
# cell candidates + decisions
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen_candidates():
    profile = RequestProfile(prompt_tokens=SERVE_OBJECTIVE.prompt_tokens,
                             output_tokens=SERVE_OBJECTIVE.output_tokens)
    return serving_candidates(_cfg(), profile, **SERVE_SWEEP_KW)


def test_candidates_memory_feasible(qwen_candidates):
    assert qwen_candidates
    for c in qwen_candidates:
        assert c.memory_bytes_per_npu <= DEFAULT_NPU_HBM_BYTES
        assert c.capacity_rps > 0.0 and c.slots > 0


def test_disaggregated_never_below_colocated(qwen_candidates):
    """The satellite property: disaggregated ≥ co-located raw capacity
    at equal hardware, for every wafer count — never violated."""
    for w in range(1, SERVE_SWEEP_KW["max_wafers"] + 1):
        coloc = max(c.capacity_rps for c in qwen_candidates
                    if c.placement == "colocated" and c.wafers == w)
        disagg = max(c.capacity_rps for c in qwen_candidates
                     if c.placement == "disaggregated" and c.wafers == w)
        assert disagg >= coloc


def test_slo_capacity_within_slo(qwen_candidates):
    target_s = 0.2
    checked = 0
    for c in qwen_candidates[:40]:
        cap = slo_capacity_rps(c, target_s)
        if cap > 0.0:
            assert c.ttft_p99_s(cap) <= target_s * (1 + 1e-9)
            checked += 1
    assert checked


def test_pareto_indices_basic():
    pts = [(1.0, 1.0), (2.0, 2.0), (0.5, 3.0), (1.0, 1.0)]
    front = pareto_indices(pts)
    assert 1 not in front                 # dominated by (1,1)
    assert 0 in front and 2 in front and 3 in front  # ties both kept


def test_decide_serving_qwen_north_star():
    """The ROADMAP question: wafers for 1M concurrent qwen3-32b users at
    a 200 ms p99 — pinned against the servesweep golden."""
    d = decide_serving(_cfg(), SERVE_OBJECTIVE, **SERVE_SWEEP_KW)
    golden = json.load(open(GOLDEN_PATH))["qwen3-32b"]
    assert d.golden() == golden
    assert d.total_wafers == golden["total_wafers"]
    assert d.ttft_p99_ms <= SERVE_OBJECTIVE.target_p99_ms
    assert d.arrival_rate_rps == pytest.approx(1_000_000 / 60.0)


def test_decide_serving_infeasible_slo():
    with pytest.raises(InfeasibleServingError):
        decide_serving(_cfg(), Objective.serving(
            target_p99_ms=1e-3, arrival_rate_rps=10.0), **SERVE_SWEEP_KW)


def test_decide_serving_needs_traffic():
    with pytest.raises(ValueError):
        decide_serving(_cfg(), Objective.serving(target_p99_ms=200.0),
                       **SERVE_SWEEP_KW)


def test_serving_table_matches_golden():
    decisions = serving_decision_table()
    assert [d.arch for d in decisions] == list(SERVESWEEP_ARCHS)
    assert check_serving_goldens(decisions, GOLDEN_PATH) == []


# --------------------------------------------------------------------------
# unified API + legacy shim bit-identity
# --------------------------------------------------------------------------

def test_objective_kind_validated():
    with pytest.raises(ValueError):
        Objective(kind="latency")


def test_choose_requires_shape_for_training():
    with pytest.raises(ValueError):
        choose(DeploymentRequest(model=_cfg("llama3.2-1b")))


def test_choose_serving_dispatch():
    d = choose(DeploymentRequest(model=_cfg(), objective=SERVE_OBJECTIVE,
                                 **SERVE_SWEEP_KW))
    assert d.golden() == json.load(open(GOLDEN_PATH))["qwen3-32b"]
    assert choose_serving_strategy(_cfg()).golden() == d.golden()


def test_choose_serving_strategy_rejects_training_objective():
    with pytest.raises(ValueError):
        choose_serving_strategy(_cfg(), Objective.time())


def test_legacy_shim_warns_and_is_bit_identical():
    from repro.models.config import SHAPES_BY_NAME
    cfg = _cfg("llama3.2-1b")
    shape = SHAPES_BY_NAME["train_4k"]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = choose_strategy(cfg, shape, n_npus=20, max_wafers=1)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    new = choose(DeploymentRequest(model=cfg, shape=shape, n_npus=20,
                                   max_wafers=1))
    # bit-identical decision, not just the same golden signature
    assert old.strategy == new.strategy
    assert old.time_per_sample_s == new.time_per_sample_s
    assert old.memory_bytes_per_npu == new.memory_bytes_per_npu
    assert old.golden() == new.golden()


def test_legacy_shim_goodput_objective_kwargs():
    from repro.models.config import SHAPES_BY_NAME
    cfg = _cfg("llama3.2-1b")
    shape = SHAPES_BY_NAME["train_4k"]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = choose_strategy(cfg, shape, n_npus=20, max_wafers=1,
                              objective="goodput", mtbf_npu_hours=2000.0)
    new = choose(DeploymentRequest(
        model=cfg, shape=shape, n_npus=20, max_wafers=1,
        objective=Objective.goodput(mtbf_npu_hours=2000.0)))
    assert old.strategy == new.strategy
    assert old.goodput_samples_per_s == new.goodput_samples_per_s
    assert old.golden() == new.golden()
