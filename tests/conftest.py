"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device (the dry-run sets its own flags; multi-device
tests spawn subprocesses)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
