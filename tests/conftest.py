"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device (the dry-run sets its own flags; multi-device
tests spawn subprocesses)."""
import os

try:
    # the CI `core` matrix lane runs the analytical cost-model tests on a
    # JAX-free interpreter (requirements-core.txt) — only the jax-lane
    # test files use the ``rng`` fixture
    import jax
except ImportError:
    jax = None
import pytest

try:
    # Example counts live in profiles (the @settings decorators only set
    # deadline) so CI can cap hypothesis work: 50 examples is the right
    # depth locally but too slow for the PR gate.  The ci profile is
    # activated by CI=true (set by GitHub Actions) or HYPOTHESIS_PROFILE.
    from hypothesis import settings as _hsettings
    _hsettings.register_profile("dev", max_examples=50)
    _hsettings.register_profile("ci", max_examples=15, deadline=None)
    _hsettings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE",
                       "ci" if os.environ.get("CI") else "dev"))
except ImportError:                  # hypothesis is an extra; tests skip
    pass


@pytest.fixture(scope="session")
def rng():
    if jax is None:
        pytest.skip("jax not installed (core lane)")
    return jax.random.PRNGKey(0)
