"""Regenerate tests/goldens/servesweep.json — the pinned serving-cell
decisions (``repro.core.autostrategy.SERVESWEEP_ARCHS`` under the
production ``SERVE_OBJECTIVE``: 1M concurrent users / 60 s think time /
200 ms p99 TTFT).  Run after an *intentional* cost-model change:

    PYTHONPATH=src python -m tests.gen_servesweep_golden

``--check`` regenerates in memory only and exits non-zero if the fresh
decisions differ from the committed file — the nightly golden-drift gate
(catches env-dependent float drift before it surfaces as a confusing PR
failure), mirroring tests/gen_lifetime_golden.py.

The generator refuses to write a vacuous golden: qwen3-32b (the
ROADMAP's north-star "how many wafers serve 1M concurrent users at a
200 ms p99" question) must be present with a multi-wafer answer, and
the serving model must be exercising real queueing (every pinned p99
must be positive and within the SLO).
"""

import argparse
import json
import sys
from pathlib import Path

GOLDEN = Path(__file__).parent / "goldens" / "servesweep.json"


def fresh_goldens() -> dict:
    from repro.core.autostrategy import (SERVE_OBJECTIVE,
                                         serving_decision_table)
    decisions = serving_decision_table()
    out = {d.arch: d.golden() for d in decisions}
    star = out.get("qwen3-32b")
    if star is None or star["total_wafers"] < 2:
        sys.exit(f"refusing to write {GOLDEN}: qwen3-32b is missing or "
                 f"answers the 1M-user question with <2 wafers — the "
                 f"servesweep gate would not pin the north-star answer "
                 f"(fix core/serving.py first)")
    slo = SERVE_OBJECTIVE.target_p99_ms
    bad = [a for a, v in out.items()
           if not 0.0 < v["ttft_p99_ms"] <= slo]
    if bad:
        sys.exit(f"refusing to write {GOLDEN}: {', '.join(bad)} pin a "
                 f"p99 outside (0, {slo}] ms — the decided operating "
                 f"points no longer meet the SLO they were elected "
                 f"under (fix core/serving.py first)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff the regenerated decisions against the "
                         "committed golden instead of overwriting it; "
                         "exit 1 on drift")
    args = ap.parse_args()
    got = fresh_goldens()
    if args.check:
        want = json.loads(GOLDEN.read_text())
        if got != want:
            diffs = [k for k in sorted(set(got) | set(want))
                     if got.get(k) != want.get(k)]
            print(f"golden drift: regenerated serving decisions differ "
                  f"from {GOLDEN} ({', '.join(diffs)}).\n"
                  f"If a cost-model change is intended, regenerate with "
                  f"`python -m tests.gen_servesweep_golden`; otherwise "
                  f"the environment introduced float drift.",
                  file=sys.stderr)
            print(json.dumps(got, indent=1, sort_keys=True),
                  file=sys.stderr)
            return 1
        print(f"golden check OK: {len(got)} serving decisions identical "
              f"to {GOLDEN}")
        return 0
    GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
    star = got["qwen3-32b"]
    print(f"wrote {GOLDEN} ({len(got)} decisions; qwen3-32b 1M-user "
          f"answer: {star['total_wafers']} wafers, {star['placement']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
