"""MoE dispatch invariants (hypothesis) + optimizer correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")    # extra dep: degrade to skip, not error
from hypothesis import given, settings, strategies as st

from repro.models.moe import _dispatch_indices, _route, moe_ffn, init_moe
from repro.models.modules import split
from repro.train.optim import (AdamState, OptimConfig, QTensor, _dequantize,
                               _quantize, adam_update, init_adam, lr_schedule)

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# dispatch properties
# --------------------------------------------------------------------------

@settings(deadline=None)
@given(T=st.integers(4, 64), E=st.integers(2, 8), k=st.integers(1, 2),
       cap=st.integers(2, 16), seed=st.integers(0, 1000))
def test_dispatch_slots(T, E, k, cap, seed):
    k = min(k, E)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, k), 0, E)
    slot = np.asarray(_dispatch_indices(idx, E, cap))
    kept = slot[slot >= 0]
    # slots unique
    assert len(np.unique(kept)) == len(kept)
    # every slot within its expert's bucket & capacity respected
    experts = kept // cap
    pos = kept % cap
    assert (pos < cap).all()
    np.testing.assert_array_equal(np.sort(experts),
                                  np.sort(np.asarray(idx).reshape(-1)[slot.reshape(-1) >= 0]))
    # per-expert counts ≤ capacity
    for e in range(E):
        assert (experts == e).sum() <= cap


@settings(deadline=None)
@given(seed=st.integers(0, 100))
def test_dropless_moe_equals_dense_expert_sum(seed):
    """With huge capacity, MoE == explicit top-k expert mixture."""
    from repro.configs.registry import get_config
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              capacity_factor=32.0)
    params, _ = split(init_moe(jax.random.PRNGKey(seed), cfg) if False else
                      jax.tree.map(lambda x: x, init_moe(jax.random.PRNGKey(seed), cfg)))
    params, _ = split(init_moe(jax.random.PRNGKey(seed), cfg))
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model)) * 0.3
    out, aux = moe_ffn(params, x, cfg)

    # dense reference: route every token through its top-k experts directly
    from repro.models.modules import swiglu
    x2d = np.asarray(x.reshape(-1, cfg.d_model))
    eidx, cw, _ = _route(jnp.asarray(x2d), params["router"],
                         cfg.n_experts, cfg.top_k)
    eidx, cw = np.asarray(eidx), np.asarray(cw)
    ref = np.zeros_like(x2d)
    wg, wu, wd = (np.asarray(params["w_gate"]), np.asarray(params["w_up"]),
                  np.asarray(params["w_down"]))
    for t in range(x2d.shape[0]):
        for j in range(cfg.top_k):
            e = eidx[t, j]
            h = np.asarray(swiglu(jnp.asarray(x2d[t] @ wg[e]),
                                  jnp.asarray(x2d[t] @ wu[e])))
            ref[t] += cw[t, j] * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               ref, atol=2e-4, rtol=2e-3)


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux ≈ 1 (Switch normalization)."""
    T, E = 4096, 8
    x = jax.random.normal(KEY, (T, 16))
    w = jnp.zeros((16, E))   # uniform logits → uniform probs
    _, _, aux = _route(x, w, E, 2)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adam_matches_manual_reference():
    ocfg = OptimConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                       grad_clip=0.0, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = init_adam(p, ocfg)
    newp, state, _ = adam_update(p, g, state, ocfg)
    # manual
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    ref = np.asarray(p["w"]) - 0.1 * upd
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


def test_grad_clip_caps_global_norm():
    ocfg = OptimConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                       weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}   # norm 200
    state = init_adam(p, ocfg)
    _, state2, metrics = adam_update(p, g, state, ocfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # effective m update used clipped grads: m = (1-b1)·g·(1/200)
    mref = 0.1 * 100.0 / 200.0
    np.testing.assert_allclose(np.asarray(state2.m["w"]),
                               np.full(4, mref), rtol=1e-4)


@pytest.mark.parametrize("mdtype", ["float32", "bfloat16", "int8"])
def test_adam_converges_quadratic(mdtype):
    """min ||w - w*||² under each moments mode."""
    ocfg = OptimConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                       warmup_steps=0, total_steps=10**9,
                       master=(mdtype != "int8"), moments_dtype=mdtype)
    target = jnp.array([1.0, -0.5, 2.0, 0.25] * 64)
    p = {"w": jnp.zeros(256)}
    state = init_adam(p, ocfg)

    @jax.jit
    def step(p, state):
        g = {"w": 2 * (p["w"] - target)}
        return adam_update(p, g, state, ocfg)

    for _ in range(400):
        p, state, _ = step(p, state)
    err = float(jnp.max(jnp.abs(p["w"] - target)))
    assert err < (0.05 if mdtype == "int8" else 0.01), f"{mdtype}: {err}"


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (64, 256)) * 3.0
    q = _quantize(x, signed=True)
    err = jnp.max(jnp.abs(_dequantize(q) - x))
    # per-row scale: ≤ half a quantum + the bf16 pre-cast rounding
    bound = float(jnp.max(q.scale)) * 0.51 + 0.01 * float(jnp.max(jnp.abs(x)))
    assert float(err) <= bound


def test_lr_schedule_shape():
    ocfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), ocfg)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)
