"""Lifetime-goodput tests (ISSUE 9 tentpole): checkpoint math, the
elastic degradation chain, closed-form vs event-simulated missions, and
the autostrategy goodput objective.

JAX-free — runs in the core CI lane.  The two structural pins:

  * at ``mtbf = ∞`` the goodput objective is *bit-identical* to the
    time objective (useful fraction exactly 1.0, goodput exactly
    ``1/time``) — this is what keeps every pre-lifetime golden
    byte-stable;
  * at the gate's pinned MTBF the objective genuinely flips decisions
    (zamba2-2.7b trades MP(4) down to an elastic-reachable MP(3) plan),
    spot-checked against ``tests/goldens/lifetimesweep.json``.
"""

import json
import math

import pytest

from repro.core.autostrategy import (LIFETIME_ARCHS, LIFETIME_MTBF_NPU_HOURS,
                                     LIFETIME_SWEEP_KW, _strategy_signature,
                                     check_lifetime_goldens,
                                     lifetime_decision_pairs, lifetime_golden)
from repro.core.lifetime import (FailureModel, HOUR_S, LifetimePoint,
                                 _elastic_reachable, checkpoint_state_bytes,
                                 checkpoint_write_s, degradation_chain,
                                 estimate_lifetime, optimal_interval,
                                 simulate_lifetime, time_fractions,
                                 useful_fraction, young_daly_interval)
from repro.core.sweep import sweep, transformer_17b
from repro.core.workloads import (BYTES, MemoryModel,
                                  optimizer_bytes_per_param)

GOLDEN_PATH = "tests/goldens/lifetimesweep.json"


# --------------------------------------------------------------------------
# failure model + checkpoint cost
# --------------------------------------------------------------------------


def test_system_mtbf_composes_npu_and_wafer_clocks():
    fm = FailureModel()
    assert math.isinf(fm.system_mtbf_s(20))
    fm = FailureModel(mtbf_npu_hours=2000.0)
    assert fm.system_mtbf_s(20) == pytest.approx(2000.0 * HOUR_S / 20)
    # a wafer clock adds failure rate: the system MTBF must drop
    both = FailureModel(mtbf_npu_hours=2000.0, mtbf_wafer_hours=8000.0)
    assert both.system_mtbf_s(20, 2) < fm.system_mtbf_s(20)
    rate = 20 / (2000.0 * HOUR_S) + 2 / (8000.0 * HOUR_S)
    assert both.system_mtbf_s(20, 2) == pytest.approx(1.0 / rate)


def test_checkpoint_cost_tracks_state_bytes_and_io_rate():
    st = sweep(transformer_17b, 20, n_layers=78)[0].strategy
    w = transformer_17b(st)
    train = MemoryModel()
    serve = MemoryModel(training=False)
    params = w.params_per_layer * w.n_layers
    per_param = BYTES + optimizer_bytes_per_param(train.master,
                                                  train.moments_dtype)
    assert checkpoint_state_bytes(w, train) == pytest.approx(
        params * per_param)
    # no optimizer state to commit when not training
    assert checkpoint_state_bytes(w, serve) == pytest.approx(params * BYTES)
    assert checkpoint_state_bytes(w, serve) < checkpoint_state_bytes(w, train)
    # write time = bytes / (io rate × wafers the strategy spans)
    assert checkpoint_write_s(w, train, 1e12) == pytest.approx(
        checkpoint_state_bytes(w, train) / (1e12 * max(st.wafers, 1)))
    assert checkpoint_write_s(w, train, 2e12) == pytest.approx(
        checkpoint_write_s(w, train, 1e12) / 2)


# --------------------------------------------------------------------------
# Young–Daly / useful-fraction closed form
# --------------------------------------------------------------------------


def test_young_daly_interval():
    assert young_daly_interval(30.0, 50_000.0) == pytest.approx(
        math.sqrt(2.0 * 30.0 * 50_000.0))
    assert math.isinf(young_daly_interval(30.0, math.inf))
    assert young_daly_interval(0.0, 50_000.0) == 0.0


def test_useful_fraction_edges_and_shape():
    # never fails + free checkpoints: exactly 1.0 (the bit-identity pin)
    assert useful_fraction(100.0, 0.0, 60.0, math.inf) == 1.0
    # never fails: pure write amortization τ/(τ+δ)
    assert useful_fraction(100.0, 25.0, 60.0, math.inf) == \
        pytest.approx(100.0 / 125.0)
    with pytest.raises(ValueError, match="interval"):
        useful_fraction(0.0, 25.0, 60.0, 50_000.0)
    # finite mtbf always costs something, and a costlier checkpoint or a
    # flakier system costs more
    base = useful_fraction(1000.0, 30.0, 60.0, 50_000.0)
    assert 0.0 < base < 1.0
    assert useful_fraction(1000.0, 60.0, 60.0, 50_000.0) < base
    assert useful_fraction(1000.0, 30.0, 60.0, 25_000.0) < base


def test_optimal_interval_maximizes_useful_fraction():
    ckpt, restart, mtbf = 30.0, 60.0, 50_000.0
    tau = optimal_interval(ckpt, restart, mtbf)
    best = useful_fraction(tau, ckpt, restart, mtbf)
    # near the Young–Daly seed, and better than any bracketing interval
    assert 0.25 * young_daly_interval(ckpt, mtbf) < tau \
        < 4.0 * young_daly_interval(ckpt, mtbf)
    for other in (tau / 4, tau / 2, tau * 2, tau * 4):
        assert best >= useful_fraction(other, ckpt, restart, mtbf)
    assert math.isinf(optimal_interval(ckpt, restart, math.inf))
    assert optimal_interval(0.0, restart, mtbf) == 1.0   # min_interval_s


def test_time_fractions_decompose_exactly():
    for mtbf in (30_000.0, 50_000.0, math.inf):
        fr = time_fractions(1500.0, 30.0, 60.0, mtbf)
        assert set(fr) == {"useful", "checkpoint", "lost", "recovery"}
        assert sum(fr.values()) == pytest.approx(1.0, abs=1e-12)
        assert all(0.0 <= v <= 1.0 for v in fr.values())
    assert time_fractions(1500.0, 0.0, 60.0, math.inf) == \
        {"useful": 1.0, "checkpoint": 0.0, "lost": 0.0, "recovery": 0.0}


# --------------------------------------------------------------------------
# mission estimate vs event simulation
# --------------------------------------------------------------------------

_HEALTHY = [LifetimePoint(n_failed=0, alive=True, time_per_sample_s=0.02,
                          source="winner")]


def test_estimate_at_infinite_mtbf_is_exact_inverse_time():
    est = estimate_lifetime(_HEALTHY, ckpt_write_s=30.0, restart_s=60.0,
                            mtbf_s=math.inf, mission_s=3.6e6)
    assert est.fractions["useful"] == 1.0            # exactly, not approx
    assert est.goodput_samples_per_s == 1.0 / 0.02   # bit-identical
    assert est.n_expected_failures == 0
    assert math.isinf(est.interval_s)                # never checkpoint
    assert est.survives_mission
    assert est.samples_total == est.goodput_samples_per_s * 3.6e6


def test_simulation_agrees_with_closed_form():
    kw = dict(ckpt_write_s=30.0, restart_s=60.0, mtbf_s=50_000.0,
              mission_s=5_000_000.0)
    est = estimate_lifetime(_HEALTHY, **kw)
    for seed in range(3):
        sim = simulate_lifetime(_HEALTHY, seed=seed, **kw)
        total = sum(sim[k] for k in ("useful_s", "checkpoint_s", "lost_s",
                                     "recovery_s"))
        assert sim["useful_s"] / total == pytest.approx(
            est.fractions["useful"], rel=2e-2)
        assert sim["samples"] / kw["mission_s"] == pytest.approx(
            est.goodput_samples_per_s, rel=2e-2)
        # ~mission/mtbf failures actually fired
        assert 50 <= sim["n_failures"] <= 150


def test_dead_chain_forfeits_remaining_mission():
    chain = [_HEALTHY[0],
             LifetimePoint(n_failed=1, alive=False, time_per_sample_s=0.0,
                           source="dead", reason="capacity")]
    kw = dict(ckpt_write_s=30.0, restart_s=60.0, mtbf_s=500_000.0,
              mission_s=5_000_000.0)
    est = estimate_lifetime(chain, **kw)
    assert not est.survives_mission
    healthy = estimate_lifetime(_HEALTHY, **kw)
    # one expected state before death ⇒ ~1/10 of the healthy mission
    assert est.goodput_samples_per_s < 0.2 * healthy.goodput_samples_per_s
    sim = simulate_lifetime(chain, seed=0, **kw)
    assert sim["samples"] / kw["mission_s"] < \
        0.3 * healthy.goodput_samples_per_s


# --------------------------------------------------------------------------
# elastic degradation chain
# --------------------------------------------------------------------------


def test_degradation_chain_fallbacks_are_elastic_reachable():
    mem = MemoryModel(npu_hbm_bytes=64 * 2**30)
    kw = dict(n_layers=78, memory=mem, min_utilization=0.5)
    feas = [r for r in sweep(transformer_17b, 20, **kw) if r.feasible]
    # a full-wafer deployment: the first death forces a re-plan
    winner = min((r for r in feas
                  if r.strategy.mp >= 4 and r.strategy.pp == 1
                  and r.strategy.mp * r.strategy.dp == 20),
                 key=lambda r: r.time_per_sample)
    chain = degradation_chain(transformer_17b, winner, 20, n_states=3,
                              seed=0, sweep_kw=kw)
    assert chain[0].source == "winner"
    assert chain[0].time_per_sample_s == winner.time_per_sample
    assert [p.n_failed for p in chain] == list(range(len(chain)))
    fallbacks = [p for p in chain if p.fallback is not None]
    assert fallbacks, "full-wafer winner must re-plan after a death"
    for p in fallbacks:
        assert p.alive and p.source == "fallback" and p.reason
        assert p.time_per_sample_s == p.fallback.time_per_sample \
            > winner.time_per_sample
        fs, ws = p.fallback.strategy, winner.strategy
        # the re-plan is plan_shrink-shaped: same hardware, frozen
        # pp/ep/sp/wafers, mp kept or folded onto a divisor
        assert _elastic_reachable(p.fallback, winner)
        assert (p.fallback.fabric, p.fallback.shape) == \
            (winner.fabric, winner.shape)
        assert (fs.pp, fs.ep, fs.sp, fs.wafers) == \
            (ws.pp, ws.ep, ws.sp, ws.wafers)
        assert fs.mp <= ws.mp and ws.mp % fs.mp == 0


def test_degradation_chain_dies_when_no_fold_fits_memory():
    # at 16 GiB/NPU the 17B model only fits with mp·pp ≥ 16 — folding
    # MP(20) onto a divisor (10, 5, ...) is memory-infeasible, so the
    # first death is terminal and the chain must end there
    mem = MemoryModel(npu_hbm_bytes=16 * 2**30)
    kw = dict(n_layers=78, memory=mem, min_utilization=0.5)
    feas = [r for r in sweep(transformer_17b, 20, **kw) if r.feasible]
    winner = min((r for r in feas if r.strategy.mp == 20),
                 key=lambda r: r.time_per_sample)
    chain = degradation_chain(transformer_17b, winner, 20, n_states=3,
                              seed=0, sweep_kw=kw)
    assert len(chain) == 2
    dead = chain[-1]
    assert not dead.alive and dead.source == "dead"
    assert dead.time_per_sample_s == 0.0
    assert "capacity" in dead.reason


def test_elastic_reachability_predicate():
    mem = MemoryModel(npu_hbm_bytes=64 * 2**30)
    res = sweep(transformer_17b, 20, n_layers=78, memory=mem,
                min_utilization=0.5)
    by_axes = {}
    for r in res:
        s = r.strategy
        by_axes.setdefault((s.mp, s.pp), r)
    a, b = by_axes.get((2, 1)), by_axes.get((4, 1))
    assert a is not None and b is not None
    assert _elastic_reachable(a, b)       # mp 4 → 2 is a divisor fold
    assert not _elastic_reachable(b, a)   # mp can never grow mid-run
    assert _elastic_reachable(b, b)       # staying put is always legal
    c = by_axes.get((2, 2))
    if c is not None:
        assert not _elastic_reachable(c, b)   # pp is frozen


# --------------------------------------------------------------------------
# the autostrategy goodput objective (golden spot checks)
# --------------------------------------------------------------------------


def test_goodput_flips_zamba2_and_not_llama():
    with open(GOLDEN_PATH) as fh:
        goldens = json.load(fh)
    assert set(goldens) == {f"{a}/train_4k" for a in LIFETIME_ARCHS}
    pairs = lifetime_decision_pairs(archs=("zamba2-2.7b", "llama3.2-1b"))
    by_arch = {p[0].arch: p for p in pairs}
    # zamba2 flips: the goodput pick trades healthy time for an
    # elastic-reachable (smaller-MP) plan that keeps running
    z = lifetime_golden(by_arch["zamba2-2.7b"])
    assert z["flip"]
    assert z == goldens["zamba2-2.7b/train_4k"]
    zt, zg = by_arch["zamba2-2.7b"]
    assert zg.objective == "goodput"
    assert zg.mtbf_npu_hours == LIFETIME_MTBF_NPU_HOURS
    assert zg.strategy != zt.strategy
    assert zg.strategy.mp < zt.strategy.mp
    assert 0.0 < zg.useful_fraction < 1.0
    assert 0.0 < zg.ckpt_write_s < zg.ckpt_interval_s < math.inf
    # llama's winner is already robust: no flip, same strategy both ways
    l = lifetime_golden(by_arch["llama3.2-1b"])
    assert not l["flip"]
    assert l == goldens["llama3.2-1b/train_4k"]


def test_goodput_at_infinite_mtbf_is_bit_identical_to_time():
    pairs = lifetime_decision_pairs(archs=("zamba2-2.7b",),
                                    mtbf_npu_hours=math.inf)
    t, g = pairs[0]
    assert _strategy_signature(t) == _strategy_signature(g)
    assert g.useful_fraction == 1.0
    assert g.goodput_samples_per_s == 1.0 / g.time_per_sample_s
    assert math.isinf(g.ckpt_interval_s)


def test_check_lifetime_goldens_contract(tmp_path):
    pairs = lifetime_decision_pairs(archs=("llama3.2-1b",))
    key = "llama3.2-1b/train_4k"
    good = {key: lifetime_golden(pairs[0])}
    p = tmp_path / "golden.json"
    p.write_text(json.dumps(good))
    assert check_lifetime_goldens(pairs, str(p)) == []
    # a flipped decision fails
    bad = {key: dict(good[key], flip=not good[key]["flip"])}
    p.write_text(json.dumps(bad))
    errors = check_lifetime_goldens(pairs, str(p))
    assert len(errors) == 1 and key in errors[0]
    # an orphaned golden entry fails too (coverage loss)
    p.write_text(json.dumps({**good, "ghost/train_4k": good[key]}))
    errors = check_lifetime_goldens(pairs, str(p))
    assert len(errors) == 1 and "ghost" in errors[0]
    # a missing entry fails
    p.write_text(json.dumps({}))
    errors = check_lifetime_goldens(pairs, str(p))
    assert len(errors) == 1 and "no golden entry" in errors[0]
