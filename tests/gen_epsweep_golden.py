"""Regenerate tests/goldens/epsweep.json — the pinned MoE auto-strategy
decisions with the expert/sequence-parallel axes searchable
(``repro.core.autostrategy.MOE_ARCHS`` × ``EP_SWEEP_KW``).  Run after an
*intentional* cost-model change:

    PYTHONPATH=src python -m tests.gen_epsweep_golden

``--check`` regenerates in memory only and exits non-zero if the fresh
decisions differ from the committed file — the nightly golden-drift gate
(catches env-dependent float drift before it surfaces as a confusing PR
failure), mirroring tests/gen_sweep512_golden.py.

The generator refuses to write a golden in which any MoE arch chose
``ep = 1``: the epsweep CI gate pins ``ep > 1`` for every entry, so such
a golden would be born red.
"""

import argparse
import json
import sys
from pathlib import Path

GOLDEN = Path(__file__).parent / "goldens" / "epsweep.json"


def fresh_goldens() -> dict:
    from repro.core.autostrategy import EP_SWEEP_KW, MOE_ARCHS, decision_table
    decisions = decision_table(MOE_ARCHS, **EP_SWEEP_KW)
    no_ep = [d.arch for d in decisions if d.ep <= 1]
    if no_ep:
        sys.exit(f"refusing to write {GOLDEN}: {', '.join(no_ep)} chose "
                 f"ep=1 — the epsweep gate requires every MoE arch to "
                 f"elect expert parallelism (fix the EP cost/memory model "
                 f"first)")
    return {f"{d.arch}/{d.shape}": d.golden() for d in decisions}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="diff the regenerated decisions against the "
                         "committed golden instead of overwriting it; "
                         "exit 1 on drift")
    args = ap.parse_args()
    got = fresh_goldens()
    if args.check:
        want = json.loads(GOLDEN.read_text())
        if got != want:
            diffs = [k for k in sorted(set(got) | set(want))
                     if got.get(k) != want.get(k)]
            print(f"golden drift: regenerated MoE EP decisions differ "
                  f"from {GOLDEN} ({', '.join(diffs)}).\n"
                  f"If a cost-model change is intended, regenerate with "
                  f"`python -m tests.gen_epsweep_golden`; otherwise the "
                  f"environment introduced float drift.", file=sys.stderr)
            print(json.dumps(got, indent=1, sort_keys=True),
                  file=sys.stderr)
            return 1
        print(f"golden check OK: {len(got)} MoE EP decisions identical "
              f"to {GOLDEN}")
        return 0
    GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
    print(f"wrote {GOLDEN} ({len(got)} decisions)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
