"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant8 import dequantize as p_dq, quantize as p_q
from repro.kernels.reduce_tree import ref_reduce, tree_reduce
from repro.kernels.ssd_scan import ssd_scan
from repro.models.attention import dense_attention
from repro.models.ssm import ssd_reference
from repro.parallel.compress import dequantize as j_dq, quantize as j_q

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,hd", [
    (1, 64, 1, 64), (2, 128, 4, 64), (1, 200, 2, 80), (2, 96, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, hd, dtype, causal):
    q = (jax.random.normal(KEY, (B, S, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd)) * 0.5
         ).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (B, S, H, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("S,chunk", [(64, 16), (100, 32), (96, 96)])
@pytest.mark.parametrize("G", [1, 2])
def test_ssd_scan_sweep(S, chunk, G):
    B, H, hd, N = 2, 4, 16, 8
    x = jax.random.normal(KEY, (B, S, H, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, N)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, G, N)) * 0.4
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("n,L,block", [(2, 100, 64), (7, 1000, 256),
                                       (16, 4096, 1024), (33, 513, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_reduce_sweep(n, L, block, dtype):
    shards = (jax.random.normal(KEY, (n, L)) * 2).astype(dtype)
    out = tree_reduce(shards, block=block)
    ref = ref_reduce(shards)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("n,block", [(100, 64), (5000, 512), (4096, 1024)])
def test_quant8_matches_jnp(n, block):
    x = jax.random.normal(KEY, (n,)) * 5.0
    q1, s1 = p_q(x, block)
    q2, s2 = j_q(x, block)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    x1 = p_dq(q1, s1, block)
    x2 = j_dq(q2, s2, block)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-6)
    # quantization error bounded by half a quantum per block
    assert float(jnp.max(jnp.abs(x1 - x))) <= float(jnp.max(s1)) * 0.51


def test_ops_dispatch():
    from repro.kernels import ops
    B, S, H, hd = 1, 64, 2, 32
    q = jax.random.normal(KEY, (B, S, H, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, 1, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, 1, hd))
    a1 = ops.attention(q, k, v, use_pallas=False)
    a2 = ops.attention(q, k, v, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               atol=2e-5, rtol=1e-4)
