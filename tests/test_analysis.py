"""repro.analysis — the static invariant checker suite (ISSUE 7).

Fixture-driven positive/negative/suppressed cases per rule, engine-level
baseline semantics, the two acceptance mutations (a ``CandidateBatch``
packed field deleted / a dummy ``Strategy`` field added must fail the
PARITY checker), and the live-repo self-test: the working tree must pass
with the committed (empty) baseline.

Everything here is stdlib-only — this file runs on the JAX-free CI core
lane.
"""

import json
import shutil
from pathlib import Path

import pytest

from repro.analysis import run_checks
from repro.analysis.__main__ import DEFAULT_BASELINE
from repro.analysis.__main__ import main as cli_main
from repro.analysis.engine import (Finding, SourceFile, load_baseline,
                                   split_baselined, write_baseline)

REPO_ROOT = Path(__file__).resolve().parents[1]

PARITY_FILES = (
    "src/repro/core/placement.py", "src/repro/core/simulator.py",
    "src/repro/core/batch_engine.py", "src/repro/core/workloads.py",
    "src/repro/core/specs.py", "src/repro/core/sweep.py")


def make_tree(root: Path, files: dict) -> Path:
    """Write a fixture repo: {relpath: source} + a requirements-core.txt
    (the layering checker derives its allowed set from it)."""
    files = {"requirements-core.txt": "numpy>=1.24\npytest>=7.0\n", **files}
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return root


def rules_of(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------
# engine: suppressions, unit declarations, baseline
# --------------------------------------------------------------------------

def test_suppression_and_unit_comment_parsing():
    sf = SourceFile("x.py", "\n".join([
        "a = 1  # repro: ignore[UNITS]",
        "b = 2  # repro: ignore[UNITS, DETERMINISM]",
        "c = 3  # repro: ignore[*]",
        "d: float = 4.0  # repro: unit[s]",
        "e = 5",
    ]))
    assert sf.is_suppressed("UNITS", 1)
    assert not sf.is_suppressed("PARITY", 1)
    assert sf.is_suppressed("DETERMINISM", 2)
    assert sf.is_suppressed("PARITY", 3)          # wildcard
    assert sf.declared_unit(4) == "s"
    assert not sf.is_suppressed("UNITS", 5)
    assert sf.declared_unit(5) is None


def test_baseline_roundtrip_and_stale_detection(tmp_path):
    f1 = Finding("UNITS", "a.py", 3, "msg one")
    f2 = Finding("PARITY", "b.py", 9, "msg two")
    path = tmp_path / "baseline.json"
    write_baseline(path, [f1, f2])
    baseline = load_baseline(path)
    assert baseline == {f1.baseline_key(), f2.baseline_key()}
    # f1 still fires (at a *different* line — identity ignores lines),
    # f2 no longer fires (stale), f3 is new
    f1_moved = Finding("UNITS", "a.py", 30, "msg one")
    f3 = Finding("UNITS", "c.py", 1, "brand new")
    new, old, stale = split_baselined([f1_moved, f3], baseline)
    assert new == [f3]
    assert old == [f1_moved]
    assert stale == [f2.baseline_key()]
    assert load_baseline(tmp_path / "absent.json") == set()


def test_syntax_error_is_a_finding(tmp_path):
    make_tree(tmp_path, {"src/repro/core/broken.py": "def f(:\n"})
    findings, _ = run_checks(tmp_path, rules=("UNITS",))
    assert any("syntax error" in f.message for f in findings)


# --------------------------------------------------------------------------
# LAYERING
# --------------------------------------------------------------------------

def test_layering_flags_jax_reachable_from_core(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/model.py": "import numpy as np\nimport jax\n",
    })
    findings, _ = run_checks(tmp_path, rules=("LAYERING",))
    assert len(findings) == 1
    f = findings[0]
    assert f.path == "src/repro/core/model.py" and f.line == 2
    assert "'jax'" in f.message and "repro.core.model" in f.message


def test_layering_flags_transitive_edge_with_chain(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/model.py": "from repro.train.optim import OptimConfig\n",
        "src/repro/train/optim.py": "import flax\nOptimConfig = object\n",
    })
    findings, _ = run_checks(tmp_path, rules=("LAYERING",))
    assert len(findings) == 1
    f = findings[0]
    assert f.path == "src/repro/train/optim.py"
    assert "repro.train.optim <- repro.core.model" in f.message


def test_layering_allows_sanctioned_gating(tmp_path):
    make_tree(tmp_path, {
        # lazy (function-level), try/ImportError-guarded, and
        # TYPE_CHECKING imports are the sanctioned jax gating patterns
        "src/repro/core/model.py": "\n".join([
            "from typing import TYPE_CHECKING",
            "try:",
            "    import jax",
            "except ImportError:",
            "    jax = None",
            "if TYPE_CHECKING:",
            "    import flax",
            "def f():",
            "    import torch",
            "import numpy as np",
        ]) + "\n",
    })
    findings, _ = run_checks(tmp_path, rules=("LAYERING",))
    assert findings == []


def test_layering_suppression(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/model.py":
            "import jax  # repro: ignore[LAYERING]\n",
    })
    findings, suppressed = run_checks(tmp_path, rules=("LAYERING",))
    assert findings == []
    assert rules_of(suppressed) == ["LAYERING"]


def test_layering_flags_runtime_importing_analysis(tmp_path):
    make_tree(tmp_path, {
        "src/repro/train/loop.py": "from repro.analysis import Finding\n",
        # parallel/serve/kernels likewise; core itself may (it is a root)
        "src/repro/serve/engine.py": "import repro.analysis.engine\n",
    })
    findings, _ = run_checks(tmp_path, rules=("LAYERING",))
    assert sorted(f.path for f in findings) == [
        "src/repro/serve/engine.py", "src/repro/train/loop.py"]
    assert all("must not depend on the static checkers" in f.message
               for f in findings)


def test_layering_missing_requirements_core_is_a_finding(tmp_path):
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/core/x.py").write_text("import numpy\n")
    findings, _ = run_checks(tmp_path, rules=("LAYERING",))
    assert any("requirements-core.txt is missing" in f.message
               for f in findings)


# --------------------------------------------------------------------------
# PARITY — run against copies of the real core files, then mutate them
# --------------------------------------------------------------------------

def copy_core(tmp_path: Path) -> Path:
    for rel in PARITY_FILES + ("requirements-core.txt",):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO_ROOT / rel, dst)
    return tmp_path


def test_parity_passes_on_live_core_copy(tmp_path):
    copy_core(tmp_path)
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert findings == []


def test_parity_fails_when_packed_field_deleted(tmp_path):
    """Acceptance: deleting any one CandidateBatch packed field fails."""
    copy_core(tmp_path)
    be = tmp_path / "src/repro/core/batch_engine.py"
    text = be.read_text()
    assert '"seq", ' in text
    be.write_text(text.replace('"seq", ', "", 1))
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert any("'seq'" in f.message and "no longer packed" in f.message
               for f in findings)


def test_parity_fails_when_strategy_grows_dummy_field(tmp_path):
    """Acceptance: a Strategy axis batch_engine doesn't pack fails —
    the guard that forced the PR-8 ep/sp axes through PACK_CONTRACT."""
    copy_core(tmp_path)
    pl = tmp_path / "src/repro/core/placement.py"
    text = pl.read_text()
    anchor = "    wafers: int = 1"
    assert anchor in text
    pl.write_text(text.replace(anchor, "    cp: int = 1\n" + anchor, 1))
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert any("Strategy.cp has no packed counterpart" in f.message
               for f in findings)


def test_parity_fails_when_breakdown_field_not_packed(tmp_path):
    copy_core(tmp_path)
    sim = tmp_path / "src/repro/core/simulator.py"
    text = sim.read_text()
    anchor = "    dp_inter: float = 0.0             # repro: unit[s]\n"
    assert anchor in text
    sim.write_text(text.replace(
        anchor, anchor + "    dp_exposed: float = 0.0  # repro: unit[s]\n", 1))
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert any("Breakdown.dp_exposed" in f.message for f in findings)
    # ... and the as_dict coverage rule fires too (float field)
    assert any("missing from as_dict()" in f.message for f in findings)


def test_parity_fails_on_unpacked_workload_read(tmp_path):
    copy_core(tmp_path)
    wl = tmp_path / "src/repro/core/workloads.py"
    text = wl.read_text()
    anchor = "    layers_per_stage = -(-w.n_layers // st.pp)"
    assert anchor in text
    wl.write_text(text.replace(
        anchor, "    _ = w.router_topk\n" + anchor, 1))
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert any("w.router_topk" in f.message for f in findings)


def test_parity_missing_module_is_a_finding(tmp_path):
    copy_core(tmp_path)
    (tmp_path / "src/repro/core/batch_engine.py").unlink()
    findings, _ = run_checks(tmp_path, rules=("PARITY",))
    assert any("expected core module missing" in f.message
               for f in findings)


# --------------------------------------------------------------------------
# UNITS
# --------------------------------------------------------------------------

UNITS_FIXTURE = """\
import dataclasses

@dataclasses.dataclass
class Timing:
    decode_time: float          {v1}
    prefill_time_s: float = 0.0
    hbm: float = 0.0            # repro: unit[bytes]
    efficiency: float = 1.0
    n_requests: int = 0
"""


def test_units_flags_suffixless_float_field(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/timing.py": UNITS_FIXTURE.format(v1="")})
    findings, _ = run_checks(tmp_path, rules=("UNITS",))
    assert len(findings) == 1
    assert "Timing.decode_time" in findings[0].message
    assert findings[0].line == 5


def test_units_accepts_declaration_and_suppression(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/a.py": UNITS_FIXTURE.format(v1="# repro: unit[s]"),
        "src/repro/core/b.py":
            UNITS_FIXTURE.format(v1="# repro: ignore[UNITS]"),
    })
    findings, suppressed = run_checks(tmp_path, rules=("UNITS",))
    assert findings == []
    assert len(suppressed) == 1 and suppressed[0].path == "src/repro/core/b.py"


def test_units_flags_csv_header_token(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/rows.py":
            'CSV_HEADER = "workload,mp,decode_time,total_s"\n'})
    findings, _ = run_checks(tmp_path, rules=("UNITS",))
    assert len(findings) == 1
    assert "'decode_time'" in findings[0].message


def test_units_flags_mixed_unit_arithmetic(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/mix.py": "\n".join([
            "def f(t_s, n_bytes, u_s, link_bw):",
            "    bad = t_s + n_bytes",            # s + bytes: flagged
            "    ok = t_s + u_s",                 # same unit
            "    ok2 = t_s + n_bytes / link_bw",  # division converts
            "    return bad, ok, ok2",
        ]) + "\n"})
    findings, _ = run_checks(tmp_path, rules=("UNITS",))
    assert len(findings) == 1
    assert findings[0].line == 2 and "s vs bytes" in findings[0].message


def test_units_only_applies_to_core(tmp_path):
    make_tree(tmp_path, {
        "src/repro/serve/timing.py": UNITS_FIXTURE.format(v1="")})
    findings, _ = run_checks(tmp_path, rules=("UNITS",))
    assert findings == []


# --------------------------------------------------------------------------
# DETERMINISM
# --------------------------------------------------------------------------

def test_determinism_flags_unseeded_rng(tmp_path):
    make_tree(tmp_path, {
        "examples/demo.py": "\n".join([
            "import random",
            "import numpy as np",
            "x = random.random()",                 # global RNG
            "r = random.Random()",                 # unseeded instance
            "g = np.random.default_rng()",         # unseeded generator
            "y = np.random.rand(3)",               # legacy global API
            "ok = random.Random(0)",
            "ok2 = np.random.default_rng(1234)",
        ]) + "\n"})
    findings, _ = run_checks(tmp_path, rules=("DETERMINISM",))
    assert sorted(f.line for f in findings) == [3, 4, 5, 6]


def test_determinism_wall_clock_only_in_core(tmp_path):
    src = "import time\nt = time.perf_counter()\n"
    make_tree(tmp_path, {
        "src/repro/core/model.py": src,
        "benchmarks/bench.py": src,     # instrumentation outside core: fine
    })
    findings, _ = run_checks(tmp_path, rules=("DETERMINISM",))
    assert [f.path for f in findings] == ["src/repro/core/model.py"]


def test_determinism_flags_set_iteration(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/rows.py": "\n".join([
            "def rows(results):",
            "    for fabric in set(r.fabric for r in results):",  # flagged
            "        pass",
            "    for fabric in dict.fromkeys(r.fabric for r in results):",
            "        pass",
            "    for fabric in sorted(set(r.fabric for r in results)):",
            "        pass",
            "    return [x for x in {1, 2}]",                     # flagged
        ]) + "\n"})
    findings, _ = run_checks(tmp_path, rules=("DETERMINISM",))
    assert sorted(f.line for f in findings) == [2, 8]


def test_determinism_suppression(tmp_path):
    make_tree(tmp_path, {
        "src/repro/core/model.py":
            "import time\n"
            "t = time.perf_counter()  # repro: ignore[DETERMINISM]\n"})
    findings, suppressed = run_checks(tmp_path, rules=("DETERMINISM",))
    assert findings == [] and len(suppressed) == 1


# --------------------------------------------------------------------------
# DEPRECATION
# --------------------------------------------------------------------------

def test_deprecation_flags_legacy_simulator_kwargs(tmp_path):
    make_tree(tmp_path, {
        "examples/demo.py": "\n".join([
            "from repro.core.simulator import Simulator",
            "from repro.core.specs import FabricSpec",
            "bad = Simulator('FRED-A', mesh_shape=(5, 4), n_wafers=2)",
            "ok = Simulator('FRED-A', spec=FabricSpec(fred_shape=(4, 5)))",
        ]) + "\n"})
    findings, _ = run_checks(tmp_path, rules=("DEPRECATION",))
    assert len(findings) == 2        # one per legacy kwarg on line 3
    assert all(f.line == 3 for f in findings)
    kwargs = {f.message.split("(")[1].split("=")[0] for f in findings}
    assert kwargs == {"mesh_shape", "n_wafers"}


def test_deprecation_flags_bare_strategy_tuple(tmp_path):
    make_tree(tmp_path, {
        "src/repro/parallel/wire.py": "\n".join([
            "def f(pcfg, decision):",
            "    a = pcfg.replace(auto_strategy=(2, 4, 1, 1, 'FRED-A'))",
            "    pcfg.auto_strategy = (2, 4, 1, 1, 'FRED-A')",
            "    b = pcfg.replace(auto_strategy=decision)",
            "    return a, b",
        ]) + "\n"})
    findings, _ = run_checks(tmp_path, rules=("DEPRECATION",))
    assert sorted(f.line for f in findings) == [2, 3]


def test_deprecation_suppression(tmp_path):
    make_tree(tmp_path, {
        "examples/demo.py":
            "from repro.core.simulator import Simulator\n"
            "s = Simulator('FRED-A', n_io=18)  # repro: ignore[DEPRECATION]\n"
    })
    findings, suppressed = run_checks(tmp_path, rules=("DEPRECATION",))
    assert findings == [] and len(suppressed) == 1


# --------------------------------------------------------------------------
# live repo self-test + CLI
# --------------------------------------------------------------------------

def test_live_repo_passes_with_committed_baseline():
    """The working tree must be clean under all five rules modulo the
    committed baseline — the same check CI runs."""
    findings, _ = run_checks(REPO_ROOT)
    baseline = load_baseline(REPO_ROOT / DEFAULT_BASELINE)
    new, _, _ = split_baselined(findings, baseline)
    assert new == [], "new invariant findings:\n" + "\n".join(
        f.render() for f in new)


def test_committed_baseline_is_empty():
    """ISSUE 7 ships with nothing grandfathered; keep it that way (fix or
    `# repro: ignore[...]` instead of baselining)."""
    data = json.loads((REPO_ROOT / DEFAULT_BASELINE).read_text())
    assert data["findings"] == []


def test_cli_exit_codes(tmp_path, capsys):
    root = make_tree(tmp_path, {
        "src/repro/core/model.py": "import jax\n"})
    args = ["--check", "--root", str(root), "--rules", "LAYERING"]
    assert cli_main(args) == 1
    out = capsys.readouterr().out
    assert "src/repro/core/model.py:1: LAYERING" in out
    # fix it -> exit 0; --json report written either way
    (root / "src/repro/core/model.py").write_text("import numpy\n")
    report = tmp_path / "report.json"
    assert cli_main(args + ["--json", str(report)]) == 0
    assert json.loads(report.read_text())["ok"] is True


def test_cli_regen_baseline_grandfathers_findings(tmp_path):
    root = make_tree(tmp_path, {
        "src/repro/core/model.py": "import jax\n"})
    baseline = root / "baseline.json"
    args = ["--check", "--root", str(root), "--rules", "LAYERING",
            "--baseline", str(baseline)]
    assert cli_main(args + ["--regen-baseline"]) == 0
    # grandfathered now -> clean exit; a *new* finding still fails
    assert cli_main(args) == 0
    (root / "src/repro/core/model.py").write_text("import jax\nimport flax\n")
    assert cli_main(args) == 1
