"""FRED switch structure + conflict-free routing (paper Sec. IV/V)."""

import itertools

import pytest

pytest.importorskip("hypothesis")    # extra dep: degrade to skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.flows import (Flow, all_gather, all_reduce, all_to_all,
                              endpoint_traffic_bytes,
                              innetwork_traffic_bytes, reduce_scatter)
from repro.core.placement import Strategy, fred_placement, placement_groups
from repro.core.routing import (RoutingConflict, color_graph, conflict_graph,
                                fig7j_flows, routable, route)
from repro.core.switch import FredSwitch, hw_overhead


# --------------------------------------------------------------------------
# switch structure
# --------------------------------------------------------------------------

@pytest.mark.parametrize("ports", [2, 3, 4, 5, 8, 11, 12, 16, 20])
@pytest.mark.parametrize("m", [2, 3])
def test_switch_builds(ports, m):
    sw = FredSwitch.build(ports, m)
    assert sw.ports == ports
    if not sw.is_base:
        assert len(sw.middles) == m
        r = ports // 2
        assert len(sw.input_switches) == r
        assert len(sw.output_switches) == r
        # every port maps into the middles
        for p in range(ports):
            assert 0 <= sw.middle_port_of(p) <= sw.middles[0].ports - 1


def test_microswitch_capabilities():
    sw = FredSwitch.build(8, 3)
    for s in sw.input_switches:
        assert s.can_reduce            # R-µswitches reduce on the way in
    for s in sw.output_switches:
        assert s.can_distribute        # D-µswitches broadcast on the way out


def test_base_cases():
    s2 = FredSwitch.build(2, 3)
    assert s2.is_base and s2.input_switches[0].kind == "RD"
    s3 = FredSwitch.build(3, 3)
    assert s3.is_base


def test_hw_overhead_near_table3():
    """Table III: FRED3(12)=685mm², FRED3(11)=678mm², FRED3(10)=814mm²
    (L2 has higher per-port BW hence more I/O area — we model the L1
    class).  Assert the L1-class numbers are within 15%."""
    a12 = hw_overhead(FredSwitch.build(12, 3))["area_mm2"]
    a11 = hw_overhead(FredSwitch.build(11, 3))["area_mm2"]
    assert abs(a12 - 685) / 685 < 0.15
    assert abs(a11 - 678) / 678 < 0.15


# --------------------------------------------------------------------------
# routing: the paper's exact examples
# --------------------------------------------------------------------------

def test_fig7h_two_concurrent_allreduces():
    sw = FredSwitch.build(8, 2)
    green = all_reduce([0, 1, 2])[0][0]
    orange = all_reduce([3, 4, 5])[0][0]
    asg = route(sw, [green, orange])
    assert set(asg.colors.values()) <= {0, 1}
    # reduction activates on input µswitch 2 (ports 4,5 of orange)
    assert any(sw_idx == 2 for sw_idx, f in asg.reduce_at if f is orange or
               f == orange)


def test_fig7j_conflict_m2_resolved_m3():
    flows = fig7j_flows()
    assert not routable(FredSwitch.build(8, 2), flows)   # paper Fig. 7(j)
    assert routable(FredSwitch.build(8, 3), flows)       # footnote 4


def test_coloring_valid():
    sw = FredSwitch.build(8, 3)
    flows = fig7j_flows()
    adj = conflict_graph(sw, flows)
    colors = color_graph(adj, 3)
    assert colors is not None
    for f, nbrs in adj.items():
        for nb in nbrs:
            assert colors[f] != colors[nb]


# --------------------------------------------------------------------------
# property: FRED_3 + MP-consecutive placement routes 3D-parallelism
# (the paper's Sec. V-C claim)
# --------------------------------------------------------------------------

@settings(deadline=None)
@given(mp=st.integers(1, 8), dp=st.integers(1, 8), pp=st.integers(1, 4))
def test_placement_routes_conflict_free(mp, dp, pp):
    n = mp * dp * pp
    if n < 2 or n > 24:
        return
    sw = FredSwitch.build(n, 3)
    strat = Strategy(mp, dp, pp)
    groups = placement_groups(strat, fred_placement(strat))
    # concurrent flows of ONE parallelism type at a time (they occur in
    # different phases of the training step — Sec. III Metric 4)
    for kind in ("mp", "dp", "pp"):
        flows = [all_reduce(g)[0][0] for g in groups[kind] if len(g) > 1]
        if flows:
            assert routable(sw, flows), \
                f"{strat} {kind} flows not routable with MP-consecutive placement"


@settings(deadline=None)
@given(st.data())
def test_random_disjoint_flows_route_on_m3(data):
    """Disjoint-port flow sets (what placement produces) route on m=3."""
    P = 12
    sw = FredSwitch.build(P, 3)
    ports = list(range(P))
    rnd = data.draw(st.randoms(use_true_random=False))
    rnd.shuffle(ports)
    flows = []
    i = 0
    while i + 2 <= P:
        size = rnd.choice([2, 3, 4])
        grp = sorted(ports[i:i + size])
        i += size
        flows.append(all_reduce(grp)[0][0])
    assert routable(sw, flows)


# --------------------------------------------------------------------------
# flows / Table I
# --------------------------------------------------------------------------

def test_traffic_formulas():
    D = 1000.0
    assert endpoint_traffic_bytes("all_reduce", 4, D) == pytest.approx(2 * 3 / 4 * D)
    assert innetwork_traffic_bytes("all_reduce", 4, D) == D
    # n=2: endpoint == in-network (the paper's MP(2) observation)
    assert endpoint_traffic_bytes("all_reduce", 2, D) == \
        innetwork_traffic_bytes("all_reduce", 2, D)


def test_all_to_all_decomposition_covers_all_pairs():
    peers = [0, 1, 2, 3]
    steps = all_to_all(peers, 4.0)
    pairs = set()
    for step in steps:
        seen_in, seen_out = set(), set()
        for f in step:
            (src,), (dst,) = tuple(f.ips), tuple(f.ops)
            assert src not in seen_in and dst not in seen_out  # parallel step
            seen_in.add(src)
            seen_out.add(dst)
            pairs.add((src, dst))
    assert pairs == {(a, b) for a in peers for b in peers}


def test_reduce_scatter_allgather_decomposition():
    peers = [0, 1, 2]
    rs = reduce_scatter(peers, 9.0)
    assert len(rs) == 3 and all(len(step) == 1 for step in rs)
    assert all(step[0].ips == frozenset(peers) for step in rs)
    assert {tuple(step[0].ops) for step in rs} == {(0,), (1,), (2,)}
    ag = all_gather(peers, 9.0)
    assert all(step[0].ops == frozenset(peers) for step in ag)
