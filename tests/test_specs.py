"""Consolidated-construction API tests (PR 6 satellite): FabricSpec /
ClusterSpec on the Simulator, the deprecated-kwarg shims, and the
StrategyDecision tuple-compatibility contract.  JAX-free."""

import dataclasses
import warnings

import pytest

from repro.core.defects import DefectMask
from repro.core.placement import Strategy
from repro.core.simulator import Simulator
from repro.core.specs import ClusterSpec, FabricSpec
from repro.core.sweep import transformer_17b
from repro.models.config import ParallelConfig, StrategyDecision


def _bits(br):
    return dataclasses.astuple(br)


# --------------------------------------------------------------------------
# FabricSpec / ClusterSpec
# --------------------------------------------------------------------------


def test_fabric_spec_normalizes_empty_mask():
    spec = FabricSpec(mesh_shape=(5, 4), defects=DefectMask(n_npus=20))
    assert spec.defects is None
    spec = FabricSpec(mesh_shape=(5, 4),
                      defects=DefectMask(n_npus=20, dead_npus=(3,)))
    assert spec.defects is not None


def test_spec_construction_matches_legacy_kwargs():
    w = transformer_17b(Strategy(mp=4, dp=5, pp=1))
    for fabric, kw in (("baseline", dict(mesh_shape=(5, 4), n_io=18)),
                       ("FRED-D", dict(fred_shape=(5, 4), n_io=18))):
        new = Simulator(fabric, spec=FabricSpec(**kw))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = Simulator(fabric, **kw)
        assert _bits(new.run(w)) == _bits(old.run(w))


def test_legacy_kwargs_warn_once_and_resolve():
    with pytest.warns(DeprecationWarning, match="FabricSpec"):
        sim = Simulator("baseline", mesh_shape=(4, 5), n_io=10)
    assert sim.mesh_shape == (4, 5) and sim.n_io == 10
    assert sim.spec.mesh_shape == (4, 5)
    # spec-only construction stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Simulator("baseline", spec=FabricSpec(mesh_shape=(4, 5), n_io=10))


def test_cluster_spec_matches_legacy_cluster_kwargs():
    w = transformer_17b(Strategy(mp=2, dp=20, pp=1, wafers=2))
    cspec = ClusterSpec(n_wafers=2, inter_topology="ring",
                        inter_wafer_links=16, inter_wafer_bw=200e9)
    new = Simulator("FRED-D", spec=FabricSpec(fred_shape=(5, 4), n_io=18),
                    cluster_spec=cspec)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = Simulator("FRED-D", fred_shape=(5, 4), n_io=18, n_wafers=2,
                        inter_topology="ring", inter_wafer_links=16,
                        inter_wafer_bw=200e9)
    assert _bits(new.run(w)) == _bits(old.run(w))
    assert new.n_wafers == old.n_wafers == 2


def test_specs_are_frozen_and_hashable():
    spec = FabricSpec(mesh_shape=(5, 4))
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.n_io = 3
    assert hash(spec) == hash(FabricSpec(mesh_shape=(5, 4)))
    assert hash(ClusterSpec(n_wafers=2)) == hash(ClusterSpec(n_wafers=2))


# --------------------------------------------------------------------------
# StrategyDecision
# --------------------------------------------------------------------------


def test_strategy_decision_tuple_protocol():
    d = StrategyDecision(2, 10, 1, 1, "ring")
    mp, dp, pp, wf, topo = d
    assert (mp, dp, pp, wf, topo) == (2, 10, 1, 1, "ring")
    assert len(d) == 5 and d[0] == 2 and d[4] == "ring"
    assert tuple(d) == (2, 10, 1, 1, "ring")
    assert d == (2, 10, 1, 1, "ring")
    assert (2, 10, 1, 1, "ring") == d            # reflected comparison
    assert d != (2, 10, 1, 1, "switch")
    assert hash(d) == hash(StrategyDecision(2, 10, 1, 1, "ring"))


def test_strategy_decision_new_axes_compare():
    base = StrategyDecision(2, 10, 1, 1, "ring")
    seeded = StrategyDecision(2, 10, 1, 1, "ring", defect_seed=7)
    assert base != seeded                        # named fields distinguish
    assert seeded == (2, 10, 1, 1, "ring")       # the tuple view does not
    assert seeded.ep == 1 and seeded.sp == 1 and seeded.defect_seed == 7


def test_strategy_decision_default_sentinel_and_coerce():
    p = ParallelConfig()
    assert p.auto_strategy == (0, 0, 0, 0, "")
    assert not p.auto_strategy.is_set
    assert StrategyDecision(1, 1, 1, 1, "").is_set
    legacy = (4, 2, 1, 1, "switch")
    d = StrategyDecision.coerce(legacy)
    assert isinstance(d, StrategyDecision) and d == legacy
    assert StrategyDecision.coerce(d) is d
    # a legacy tuple assigned straight onto the config still unpacks
    p2 = p.replace(auto_strategy=legacy)
    mp, dp, pp, wf, topo = p2.auto_strategy
    assert (mp, dp, pp, wf, topo) == legacy


def test_strategy_decision_json_friendly():
    d = StrategyDecision(2, 10, 1, 1, "ring", defect_seed=3)
    rec = dataclasses.asdict(d)
    assert rec == {"mp": 2, "dp": 10, "pp": 1, "wafers": 1,
                   "inter_topology": "ring", "ep": 1, "sp": 1,
                   "defect_seed": 3}
