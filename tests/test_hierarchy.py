"""Hierarchical scale-out v2 (ISSUE 5): inter-wafer topology choice
(ring / fully-connected / switch) + rack/pod levels.

Covers: (a) HierarchyLevel/WaferCluster construction and geometry,
(b) the inter-level topology models — fully-connected ≤ ring at equal
aggregate bandwidth, in-switch reduction halving the inter traffic (the
paper's ≈2× claim), hypothesis property versions of both, (c) 1-level /
2-level degeneracy back to the PR-2 numbers bit-for-bit, (d) the new
sweep axes (hierarchy specs, topology cross-product, CSV columns,
batched-vs-scalar parity — the CI ``hiersweep`` gate at test scale),
(e) the autostrategy inter-topology decision + policy stamping.
"""

import pytest

from repro.core.cluster import (INTER_TOPOLOGIES, HierarchyLevel,
                                WaferCluster, WaferLink, hierarchy_spans,
                                inter_traffic_bytes, level_collective_time)
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy, cluster_placement, placement_groups
from repro.core.simulator import Simulator
from repro.core.specs import ClusterSpec
from repro.core.sweep import (CSV_HEADER, hierarchy_configs, hierarchy_specs,
                              sweep, to_csv_rows, transformer_17b,
                              transformer_17b_sweep)
from repro.core.workloads import transformer


def t17b(st):
    return transformer("T17B", 78, 4256, 1024, st, "stationary")


# --------------------------------------------------------------------------
# (a) construction + geometry
# --------------------------------------------------------------------------

def test_hierarchy_level_validation():
    with pytest.raises(ValueError):
        HierarchyLevel("rack", 0)
    with pytest.raises(ValueError):
        HierarchyLevel("rack", 2, topology="torus")
    for t in INTER_TOPOLOGIES:
        assert HierarchyLevel("rack", 2, topology=t).topology == t


def test_cluster_levels_construction():
    fab = FredFabric(CONFIGS["FRED-C"])
    levels = (HierarchyLevel("rack", 2, "ring"),
              HierarchyLevel("pod", 3, "switch"))
    cl = WaferCluster(fab, levels=levels)
    assert cl.n_wafers == 6 and cl.hierarchy == (2, 3)
    assert cl.n_npus == 6 * 20
    # explicit but inconsistent wafer count is rejected
    with pytest.raises(ValueError):
        WaferCluster(fab, 4, levels=levels)
    # legacy constructor → one level with the given topology
    cl1 = WaferCluster(fab, 4, topology="fully_connected")
    assert cl1.hierarchy == (4,)
    assert cl1.levels[0].topology == "fully_connected"


def test_level_spans_and_hierarchy_spans_agree():
    fab = FredFabric(CONFIGS["FRED-C"])
    cl = WaferCluster(fab, levels=(HierarchyLevel("rack", 2),
                                   HierarchyLevel("pod", 4)))
    for w in range(1, 9):
        assert cl.spans_for(w) == hierarchy_spans(w, (2, 4)), w
    # 4 consecutive wafers under racks of 2: 2 per rack, 2 racks
    assert cl.spans_for(4) == [2, 2]
    # 2 wafers stay inside one rack
    assert cl.spans_for(2) == [2, 1]
    # non-consecutive wafer sets: widest rack counts
    assert cl.level_spans([0, 3]) == [1, 2]       # one wafer in each rack
    assert cl.level_spans([0, 1, 2]) == [2, 2]


def test_cluster_placement_dp_spans_deepest_levels():
    """DP replicas fill the innermost level first, then spill to the
    next — a 4-wafer DP split on a (2, 2) rack×pod stack spans both
    racks of the pod."""
    st = Strategy(2, 8, 1, wafers=4)
    groups = placement_groups(st, cluster_placement(st, 4, 20))
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]),
                      levels=(HierarchyLevel("rack", 2),
                              HierarchyLevel("pod", 2)))
    for g in groups["dp"]:
        wafers = {cl.wafer_of(n) for n in g}
        assert wafers == {0, 1, 2, 3}
        assert cl.level_spans(wafers) == [2, 2]
    for g in groups["mp"] + groups["pp"]:
        assert len({cl.wafer_of(n) for n in g}) == 1


# --------------------------------------------------------------------------
# (b) topology models
# --------------------------------------------------------------------------

def test_fully_connected_at_most_ring_fixed():
    for n in (2, 3, 4, 8, 16):
        ring = level_collective_time("ring", "all_reduce", n, 1e9,
                                     12.8e12, 5e-7)
        fc = level_collective_time("fully_connected", "all_reduce", n, 1e9,
                                   12.8e12, 5e-7)
        assert fc <= ring, n
        if n == 2:          # identical math at 2 units
            assert fc == ring
        else:               # fewer latency steps, same bandwidth term
            assert fc < ring


def test_switch_halves_inter_traffic_vs_ring():
    """The paper's ≈2× claim: in-switch reduction injects D per unit
    where the endpoint ring injects 2(n−1)/n·D."""
    D = 1e9
    for n in (2, 3, 4, 8, 64):
        ring_tr = inter_traffic_bytes("ring", n, D)
        sw_tr = inter_traffic_bytes("switch", n, D)
        assert ring_tr == 2.0 * (n - 1) / n * D
        assert sw_tr == D
        assert ring_tr / sw_tr == 2.0 * (n - 1) / n
    assert inter_traffic_bytes("ring", 64, D) / \
        inter_traffic_bytes("switch", 64, D) == pytest.approx(2.0, rel=0.02)
    # and the time model follows at zero latency
    ring_t = level_collective_time("ring", "all_reduce", 64, D, 1e12, 0.0)
    sw_t = level_collective_time("switch", "all_reduce", 64, D, 1e12, 0.0)
    assert ring_t / sw_t == pytest.approx(2.0, rel=0.02)
    with pytest.raises(ValueError):
        inter_traffic_bytes("torus", 4, D)
    with pytest.raises(ValueError):
        level_collective_time("torus", "all_reduce", 4, D, 1e12, 0.0)


def test_topology_properties_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hst

    # latency strictly positive: at n = 2 the three models are bitwise
    # equal, above it the ring's 2(n−2) extra latency steps dominate any
    # ULP noise between the (mathematically equal) bandwidth terms
    @settings(deadline=None)
    @given(n=hst.integers(2, 64),
           nbytes=hst.floats(1.0, 1e12),
           agg_bw=hst.floats(1e9, 1e14),
           latency=hst.floats(1e-9, 1e-5),
           conc=hst.integers(1, 64))
    def check(n, nbytes, agg_bw, latency, conc):
        args = (n, nbytes, agg_bw, latency, conc)
        ring = level_collective_time("ring", "all_reduce", *args)
        fc = level_collective_time("fully_connected", "all_reduce", *args)
        sw = level_collective_time("switch", "all_reduce", *args)
        # fully-connected ≤ ring at equal aggregate bandwidth (equal
        # wire-byte budget, strictly fewer serial latency steps)
        assert fc <= ring
        # in-switch reduction ≤ ring (half the traffic, 2 steps)
        assert sw <= ring
        # traffic claim holds for every n
        assert inter_traffic_bytes("switch", n, nbytes) <= \
            inter_traffic_bytes("ring", n, nbytes)
        # RS and AG are symmetric in every topology
        for topo in INTER_TOPOLOGIES:
            rs = level_collective_time(topo, "reduce_scatter", *args)
            ag = level_collective_time(topo, "all_gather", *args)
            assert rs == ag
            assert 0.0 <= rs <= level_collective_time(topo, "all_reduce",
                                                      *args)

    check()


# --------------------------------------------------------------------------
# (c) degeneracy back to PR-2, bit for bit
# --------------------------------------------------------------------------

def test_single_ring_level_bit_identical_to_pr2_cluster():
    """The generalized level model reproduces the PR-2 inter-wafer ring
    exactly: same inter_allreduce_time, same collective split."""
    fab = FredFabric(CONFIGS["FRED-C"])
    cl = WaferCluster(fab, 4)
    D = 1e9
    # closed-form PR-2 ring: steps · (traffic/steps / bw + latency)
    for w in (2, 3, 4):
        for conc in (1, 2, 5):
            traffic = 2.0 * (w - 1) / w * D
            steps = 2 * (w - 1)
            bw = cl.levels[0].link.agg_bw / max(conc, 1)
            want = steps * ((traffic / steps) / bw +
                            cl.levels[0].link.latency)
            assert cl.inter_allreduce_time(w, D, conc) == want
    # explicit 1-level construction matches the legacy constructor
    cl2 = WaferCluster(fab, levels=(HierarchyLevel("rack", 4, "ring",
                                                   WaferLink()),))
    group = [0, 1, 20, 21, 40, 41, 60, 61]
    assert cl.collective_time_levels("all_reduce", group, D) == \
        cl2.collective_time_levels("all_reduce", group, D)


def test_two_level_degenerates_to_one_level_bitwise():
    """A (w,) flat spec and a (w, 1)-padded spec are the same model, and
    a (2, 2) stack crossed by only 2 wafers equals the flat 2-wafer
    ring — per-level zeros aside, bit-for-bit."""
    fab = FredFabric(CONFIGS["FRED-C"])
    D = 7e8
    flat = WaferCluster(fab, levels=(HierarchyLevel("rack", 2),))
    padded = WaferCluster(fab, levels=(HierarchyLevel("rack", 2),
                                       HierarchyLevel("pod", 1)))
    racked = WaferCluster(fab, levels=(HierarchyLevel("rack", 2),
                                       HierarchyLevel("pod", 2)))
    group = [0, 1, 20, 21]                    # spans wafers 0-1 only
    i_flat, l_flat = flat.collective_time_levels("all_reduce", group, D)
    i_pad, l_pad = padded.collective_time_levels("all_reduce", group, D)
    i_rack, l_rack = racked.collective_time_levels("all_reduce", group, D)
    assert i_flat == i_pad == i_rack
    assert l_flat == (l_pad[0],) == (l_rack[0],)
    assert l_pad[1] == l_rack[1] == 0.0


def test_simulator_hierarchy_param_ring_bit_identical():
    """Simulator(hierarchy=(w,), inter_topology="ring") ≡ the PR-2
    Simulator(n_wafers=w) on every breakdown field."""
    st = Strategy(2, 8, 2, wafers=4)
    w = t17b(st)
    for fabric in ("baseline", "FRED-C", "FRED-D"):
        a = Simulator(fabric, cluster_spec=ClusterSpec(n_wafers=4)).run(w)
        b = Simulator(fabric, cluster_spec=ClusterSpec(
            hierarchy=(4,), inter_topology="ring")).run(w)
        assert a.as_dict() == b.as_dict(), fabric
        assert a.dp_levels == b.dp_levels == (a.dp_inter,)
        # derived wafer count must match an explicit one
        with pytest.raises(ValueError):
            Simulator(fabric, cluster_spec=ClusterSpec(
                n_wafers=3, hierarchy=(2, 2)))


def test_two_level_split_reported_and_sums_to_dp_inter():
    st = Strategy(2, 8, 2, wafers=4)
    br = Simulator("FRED-C", cluster_spec=ClusterSpec(
        hierarchy=(2, 2), inter_topology="switch")).run(t17b(st))
    assert len(br.dp_levels) == 2
    assert all(x > 0 for x in br.dp_levels)
    assert br.dp_inter == br.dp_levels[0] + br.dp_levels[1]
    # rack level pays RS+AG on the shard, pod level one AR — at equal
    # link budgets the 2-level stack costs at least the flat ring's pod
    flat = Simulator("FRED-C", cluster_spec=ClusterSpec(
        hierarchy=(4,), inter_topology="switch")).run(t17b(st))
    assert flat.dp_levels == (flat.dp_inter,)


def test_sweep_default_axes_bit_identical_to_pr2():
    """inter_topologies=("ring",) + max_levels=1 (the defaults) leave the
    PR-2 sweep untouched, row for row."""
    res = transformer_17b_sweep(16, max_wafers=2)
    assert {r.inter_topology for r in res} == {"", "ring"}
    assert {r.hierarchy for r in res} == {(1,), (2,)}
    for r in res:
        assert (r.inter_topology == "ring") == (r.n_wafers > 1)


# --------------------------------------------------------------------------
# (d) the new sweep axes
# --------------------------------------------------------------------------

def test_hierarchy_specs_enumeration():
    assert hierarchy_specs(1) == [(1,)]
    assert hierarchy_specs(4, 1) == [(4,)]
    assert hierarchy_specs(4, 2) == [(4,), (2, 2)]
    assert hierarchy_specs(8, 2) == [(8,), (2, 4), (4, 2)]
    assert hierarchy_specs(12, 2) == [(12,), (2, 6), (3, 4), (4, 3), (6, 2)]
    for w in (2, 3, 4, 6, 8, 12):
        for spec in hierarchy_specs(w, 2):
            prod = 1
            for c in spec:
                prod *= c
            assert prod == w and all(c >= 2 for c in spec)
    with pytest.raises(ValueError):
        hierarchy_specs(4, 0)


def test_hierarchy_configs_cross_product():
    cfgs = hierarchy_configs(16, 4, inter_topologies=("ring", "switch"),
                             max_levels=2)
    # single-wafer configs carry the degenerate axis values
    assert all(h == (1,) and t == "" for w, _s, h, t in cfgs if w == 1)
    four = {(h, t) for w, _s, h, t in cfgs if w == 4}
    assert four == {((4,), "ring"), ((4,), "switch"),
                    ((2, 2), "ring"), ((2, 2), "switch")}
    with pytest.raises(ValueError):
        hierarchy_configs(16, 2, inter_topologies=("torus",))


def test_sweep_rejects_bad_axis_values():
    with pytest.raises(ValueError):
        transformer_17b_sweep(16, max_wafers=2,
                              inter_topologies=("hypercube",))
    with pytest.raises(ValueError):
        transformer_17b_sweep(16, max_wafers=2, max_levels=3)


def test_hiersweep_batched_bit_identical_to_scalar():
    """The CI hiersweep gate at test scale: every (topology × hierarchy)
    combination batched-vs-scalar bit-identical, incl. the per-level
    split and Pareto membership."""
    kw = dict(n_layers=78, max_wafers=4, fabrics=("baseline", "FRED-C"),
              inter_topologies=INTER_TOPOLOGIES, max_levels=2)
    a = sweep(transformer_17b, 16, engine="scalar", **kw)
    b = sweep(transformer_17b, 16, engine="batched", **kw)
    assert len(a) == len(b)
    seen = set()
    for ra, rb in zip(a, b):
        assert (ra.fabric, ra.shape, ra.strategy, ra.n_wafers,
                ra.hierarchy, ra.inter_topology) == \
            (rb.fabric, rb.shape, rb.strategy, rb.n_wafers,
             rb.hierarchy, rb.inter_topology)
        assert ra.breakdown.as_dict() == rb.breakdown.as_dict()
        assert ra.breakdown.dp_levels == rb.breakdown.dp_levels
        assert ra.pareto == rb.pareto
        seen.add((ra.hierarchy, ra.inter_topology))
    assert ((2, 2), "switch") in seen and ((4,), "fully_connected") in seen


def test_sweep_topology_ordering_on_matching_points():
    """Across the sweep, fully-connected and switch never lose to the
    ring on the same (fabric, shape, strategy, hierarchy) point — equal
    aggregate link budget, cheaper collective models."""
    res = sweep(transformer_17b, 16, n_layers=78, max_wafers=4,
                fabrics=("FRED-C",), inter_topologies=INTER_TOPOLOGIES,
                max_levels=2)
    by = {}
    for r in res:
        key = (r.shape, r.strategy, r.hierarchy)
        by.setdefault(key, {})[r.inter_topology] = r.breakdown.dp_inter
    compared = 0
    for d in by.values():
        if "ring" in d and d["ring"] > 0:
            assert d["fully_connected"] <= d["ring"]
            assert d["switch"] <= d["ring"]
            compared += 1
    assert compared > 0


def test_sweep_csv_has_hierarchy_columns():
    res = sweep(transformer_17b, 16, n_layers=78, max_wafers=4,
                fabrics=("FRED-C",), inter_topologies=("ring", "switch"),
                max_levels=2)
    header = CSV_HEADER.split(",")
    for col in ("hierarchy", "inter_topology", "dp_level_1_s",
                "dp_level_2_s"):
        assert col in header
    rows = to_csv_rows(res)
    assert all(len(r.split(",")) == len(header) for r in rows)
    ih = header.index("hierarchy")
    it = header.index("inter_topology")
    hier_vals = {row.split(",")[ih] for row in rows}
    assert {"1", "2", "3", "4", "2x2"} <= hier_vals
    assert {row.split(",")[it] for row in rows} == {"", "ring", "switch"}
    # per-level columns sum to dp_inter_s on every row
    i1, i2 = header.index("dp_level_1_s"), header.index("dp_level_2_s")
    ii = header.index("dp_inter_s")
    for r, row in zip(res, rows):
        cells = row.split(",")
        assert float(cells[i1]) + float(cells[i2]) == \
            pytest.approx(float(cells[ii]))


def test_switch_hw_accounting_exposed():
    cl = WaferCluster(FredFabric(CONFIGS["FRED-C"]),
                      levels=(HierarchyLevel("rack", 4, "switch"),
                              HierarchyLevel("pod", 2, "ring")))
    hw = cl.inter_switch_hw()
    assert len(hw) == 1 and hw[0]["level"] == "rack"
    assert hw[0]["ports"] == 4 and hw[0]["area_mm2"] > 0
    assert WaferCluster(MeshFabric(), 4).inter_switch_hw() == []


# --------------------------------------------------------------------------
# (e) autostrategy + policy
# --------------------------------------------------------------------------

def test_autostrategy_stamps_inter_topology():
    from repro.configs.registry import get_config
    from repro.core.autostrategy import choose_strategy
    from repro.models.config import SHAPES_BY_NAME
    from repro.parallel.policy import cell_policy
    cfg = get_config("llama3.2-1b")
    shape = SHAPES_BY_NAME["train_4k"]
    d = choose_strategy(cfg, shape, fabrics=("FRED-C",), max_wafers=2)
    assert d.inter_topology in ("",) + INTER_TOPOLOGIES
    assert (d.inter_topology == "") == (d.wafers == 1)
    assert d.golden()["inter_topology"] == d.inter_topology
    pcfg, _ = cell_policy(cfg, shape, None, autostrategy=True, decision=d)
    assert pcfg.auto_strategy == (d.mp, d.dp, d.pp, d.wafers,
                                  d.inter_topology)


def test_autostrategy_topology_tiebreak_prefers_ring():
    """At 2 wafers all three topologies are time-equal (endpoint AR
    traffic 2(n−1)/n·D equals the in-network D at n = 2), so the
    deterministic tiebreak must pick the cheapest interconnect: ring."""
    from repro.configs.registry import get_config
    from repro.core.autostrategy import choose_strategy
    from repro.models.config import SHAPES_BY_NAME
    d = choose_strategy(get_config("llama3.2-1b"),
                        SHAPES_BY_NAME["train_4k"], max_wafers=2)
    if d.wafers == 2:
        assert d.inter_topology == "ring"
