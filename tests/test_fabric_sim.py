"""Mesh/FRED fabric models + end-to-end simulator vs the paper's numbers."""

import pytest

from repro.core.calibrate import CALIBRATED, PAPER_SPEEDUPS, simulate_speedups
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy, fred_placement, mesh_placement, placement_groups
from repro.core.simulator import Simulator, compare
from repro.core.workloads import (MemoryModel, Workload,
                                  memory_bytes_per_npu, paper_workloads,
                                  fig2_strategies)


# --------------------------------------------------------------------------
# mesh model (Sec. III / VI-B2)
# --------------------------------------------------------------------------

def test_mesh_io_controllers_is_18():
    assert MeshFabric().n_io_controllers() == 18   # Table IV baseline


def test_mesh_hotspot_formula():
    m = MeshFabric()
    assert m.io_hotspot_load() == 9                      # (2·5−1)
    assert m.io_linerate_factor() == pytest.approx(750 / 1152, rel=1e-3)


def test_xy_routing():
    m = MeshFabric()
    assert len(m.xy_links(0, 0)) == 0
    assert len(m.xy_links(0, 3)) == 3          # same row
    assert len(m.xy_links(0, 19)) == 3 + 4     # manhattan distance


def test_wafer_wide_bw_matches_paper():
    # Sec. VIII: corner NPUs limit wafer-wide AR to 2 links = 1.5 TB/s
    assert MeshFabric().wafer_wide_allreduce_bw() == pytest.approx(1.5e12)


# --------------------------------------------------------------------------
# FRED fabric (Sec. VIII microbenchmark numbers)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,expected", [
    ("FRED-A", 1.875e12),   # 375 + 4·375 GB/s hierarchical analysis
    ("FRED-B", 1.5e12),     # L1→L2 line rate, in-network
    ("FRED-C", 3e12),       # NPU-L1 line rate
    ("FRED-D", 3e12),
])
def test_wafer_wide_effective_bw(cfg, expected):
    fab = FredFabric(CONFIGS[cfg])
    group = list(range(20))
    assert fab.effective_npu_bw(group) == pytest.approx(expected, rel=1e-6)


def test_dp_stride_effective_bw_fred_a():
    """MP(2)-DP(5)-PP(2): DP peers land under different L1s; L1→L2 shared
    by 4 concurrent DP groups → FRED-A eff = 375 GB/s (Sec. VIII)."""
    fab = FredFabric(CONFIGS["FRED-A"])
    group = [0, 4, 8, 12, 16]
    assert fab.effective_npu_bw(group, concurrent_groups=4) == \
        pytest.approx(375e9, rel=1e-6)


def test_mp2_same_time_across_configs():
    """dim(MP)=2: endpoint and in-network traffic coincide, and peers are
    under one L1 — all FRED variants equal (Sec. VIII GPT-3 discussion)."""
    times = []
    for name in ("FRED-A", "FRED-B", "FRED-C", "FRED-D"):
        fab = FredFabric(CONFIGS[name])
        times.append(fab.collective_time("all_reduce", [0, 1], 1e6))
    assert max(times) == pytest.approx(min(times), rel=0.35)
    assert times[2] == pytest.approx(times[3], rel=1e-6)  # C == D exactly


def test_in_network_halves_traffic():
    from repro.core.flows import (endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    n, D = 20, 1e9
    ratio = endpoint_traffic_bytes("all_reduce", n, D) / \
        innetwork_traffic_bytes("all_reduce", n, D)
    assert ratio == pytest.approx(2 * (n - 1) / n)   # ≈2× (Abstract)


def test_fred_io_line_rate():
    assert FredFabric(CONFIGS["FRED-C"]).io_linerate_factor() == 1.0
    assert MeshFabric().io_linerate_factor() < 0.66


# --------------------------------------------------------------------------
# All-to-All (Table I) — pinned hand-computed cases per fabric
# --------------------------------------------------------------------------

def test_all_to_all_traffic_matches_table_i():
    from repro.core.flows import (all_to_all, endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    n, D = 8, 1e9
    # n serial steps, each a parallel set of n disjoint D/n unicasts
    steps = all_to_all(list(range(n)), D)
    assert len(steps) == n and all(len(s) == n for s in steps)
    assert all(f.bytes == D / n for s in steps for f in s)
    # step 0 is the identity permutation (self-delivery), so the wire
    # traffic is (n−1)/n·D — no reduction, so in-network buys nothing
    assert endpoint_traffic_bytes("all_to_all", n, D) == \
        innetwork_traffic_bytes("all_to_all", n, D) == (n - 1) / n * D


def test_unknown_collective_kind_rejected():
    from repro.core.flows import (endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    for fn in (endpoint_traffic_bytes, innetwork_traffic_bytes):
        with pytest.raises(ValueError, match="unknown collective kind"):
            fn("all_shuffle", 4, 1e6)


def test_all_to_all_pinned_mesh_wafer_wide():
    """Hand-computed: wafer-wide A2A on the 5×4 mesh — hierarchical 2D
    with half the All-Reduce step count (one pass, no reduce-back)."""
    m = MeshFabric()
    D = 1e9
    traffic = (m.n - 1) / m.n * D                       # 19/20 · D
    steps = (m.cols - 1) + (m.rows - 1)                 # 7
    per_step = (traffic / steps) / m.wafer_wide_allreduce_bw() \
        + m.latency_per_hop + m.step_overhead
    assert m.collective_time("all_to_all", list(range(m.n)), D) == \
        pytest.approx(steps * per_step, rel=1e-12)


@pytest.mark.parametrize("name,steps", [
    ("FRED-A", 3),   # endpoint: 2(n−1) ring steps halved — one direction
    ("FRED-B", 2),   # in-network, one L1: NPU→L1→NPU traversals
    ("FRED-C", 3),
    ("FRED-D", 2),
])
def test_all_to_all_pinned_fred_one_l1(name, steps):
    """Hand-computed per Table-IV config: 4 NPUs under one L1 exchange
    D = 4e8 B; traffic is (n−1)/n·D either way (no reduction to fuse)."""
    fab = FredFabric(CONFIGS[name])
    cfg = fab.config
    D = 4e8
    traffic = 3 / 4 * D
    per_step = (traffic / steps) / cfg.npu_l1_bw \
        + cfg.switch_latency + cfg.step_overhead
    assert fab.collective_time("all_to_all", [0, 1, 2, 3], D) == \
        pytest.approx(steps * per_step, rel=1e-12)


def test_all_to_all_pinned_fred_wafer_wide_in_network():
    """Spanning all five L1s: 4 traversals (NPU→L1→L2→L1→NPU), spine-
    limited on FRED-B (1.5 TB/s), NPU-link-limited on FRED-D (3 TB/s)."""
    D = 1e9
    group = list(range(20))
    traffic = 19 / 20 * D
    for name, bw in (("FRED-B", 1.5e12), ("FRED-D", 3e12)):
        fab = FredFabric(CONFIGS[name])
        cfg = fab.config
        per_step = (traffic / 4) / bw + cfg.switch_latency \
            + cfg.step_overhead
        assert fab.collective_time("all_to_all", group, D) == \
            pytest.approx(4 * per_step, rel=1e-12), name


# --------------------------------------------------------------------------
# expert / sequence parallelism + overlap (ISSUE 8 tentpole)
# --------------------------------------------------------------------------

def _moe_workload(st, a2a=4096.0, mp_ar=2):
    """Synthetic MoE workload: per-token expert dispatch traffic plus a
    dominant expert share of the parameters."""
    return Workload(name="moe", n_layers=12, params_per_layer=1e8,
                    flops_fwd_per_sample_layer=1e10,
                    act_bytes_per_sample=8192.0, strategy=st,
                    execution="stationary", mp_allreduce_per_layer=mp_ar,
                    samples_per_dp=4,
                    a2a_bytes_per_sample_layer=a2a,
                    expert_param_fraction=0.8)


def test_ep_must_divide_per_wafer_dp():
    with pytest.raises(ValueError, match="ep=3"):
        Simulator("FRED-C").run(_moe_workload(Strategy(2, 5, 2, ep=3)))


def test_sp_must_divide_mp():
    with pytest.raises(ValueError, match="sp=3"):
        Simulator("FRED-C").run(_moe_workload(Strategy(2, 5, 2, sp=3)))


def test_ep_replaces_one_mp_allreduce_and_adds_a2a():
    sim = Simulator("FRED-C")
    st1, st2 = Strategy(2, 4, 2), Strategy(2, 4, 2, ep=2)
    b1 = sim.run(_moe_workload(st1))
    b2 = sim.run(_moe_workload(st2))
    assert b1.ep_s == 0.0 and b2.ep_s > 0.0
    # the dispatch A2A subsumes the FFN All-Reduce: mp_ar 2 → 1 exactly
    assert b2.mp * 2 == b1.mp
    # overlap off: exposed comm is the full post-phase mp + ep time, and
    # ep_s is counted by total
    assert b2.exposed_comm_s == b2.mp + b2.ep_s
    assert b2.total == (b2.compute + b2.input_load + b2.mp + b2.dp +
                        b2.pp + b2.stream + b2.ep_s)
    # ep=1 ignores the expert-traffic annotations entirely (dense model)
    assert sim.run(_moe_workload(st1)).as_dict() == \
        sim.run(_moe_workload(st1, a2a=0.0)).as_dict()


def test_ep_and_sp_shard_memory():
    mem = MemoryModel()
    base = memory_bytes_per_npu(_moe_workload(Strategy(2, 4, 2)), mem)
    # EP shards the expert weights (resident scale (1−f) + f/ep < 1)
    ep = memory_bytes_per_npu(_moe_workload(Strategy(2, 4, 2, ep=2)), mem)
    # SP shards the resident activations a further sp-way
    sp = memory_bytes_per_npu(_moe_workload(Strategy(2, 4, 2, sp=2)), mem)
    assert ep < base and sp < base


def test_sp_shards_pp_boundary_traffic():
    sim = Simulator("FRED-C")
    b1 = sim.run(_moe_workload(Strategy(2, 4, 2)))
    b2 = sim.run(_moe_workload(Strategy(2, 4, 2, sp=2)))
    assert b2.pp * 2 == b1.pp            # boundary tensor sharded sp-way
    assert b2.mp == b1.mp and b2.compute == b1.compute


def test_overlap_chain_matches_roofline_identity():
    """comm_overlap_fraction: EP hides first, MP consumes the remaining
    budget — and each phase obeys exactly
    ``launch/roofline.exposed_comm_s`` (max(0, comm − overlappable)), so
    the XLA-side roofline and the analytical model cannot drift."""
    from repro.launch.roofline import exposed_comm_s
    w = _moe_workload(Strategy(2, 4, 2, ep=2))
    raw = Simulator("FRED-C").run(w)
    assert raw.ep_s > 0 and raw.mp > 0
    for f in (0.0, 0.02, 0.5, 1.0):
        br = Simulator("FRED-C", comm_overlap_fraction=f).run(w)
        budget = f * raw.compute
        ep = exposed_comm_s(raw.ep_s, budget)
        mp = exposed_comm_s(raw.mp, max(0.0, budget - raw.ep_s))
        assert br.ep_s == ep and br.mp == mp           # bit-exact
        assert br.exposed_comm_s == mp + ep
        assert (br.compute, br.dp, br.pp, br.stream) == \
            (raw.compute, raw.dp, raw.pp, raw.stream)
    # a full-compute budget hides everything here
    hidden = Simulator("FRED-C", comm_overlap_fraction=1.0).run(w)
    assert hidden.ep_s == 0.0 and hidden.mp == 0.0 \
        and hidden.exposed_comm_s == 0.0


# --------------------------------------------------------------------------
# placement
# --------------------------------------------------------------------------

def test_fred_placement_mp_consecutive():
    st = Strategy(4, 3, 2)
    pl = fred_placement(st)
    assert len(set(pl.values())) == st.n_workers     # bijection
    for grp in st.mp_groups():
        ids = sorted(pl[w] for w in grp)
        assert ids == list(range(ids[0], ids[0] + len(ids)))  # consecutive


def test_mesh_placement_bijection():
    st = Strategy(5, 2, 2)
    pl = mesh_placement(st, 5, 4)
    assert len(set(pl.values())) == 20


# --------------------------------------------------------------------------
# end-to-end simulator vs the paper (Fig. 10)
# --------------------------------------------------------------------------

def test_speedup_structure():
    sp = simulate_speedups(CALIBRATED["compute_efficiency"],
                           CALIBRATED["mesh_step_overhead"],
                           CALIBRATED["fred_step_overhead"])
    for w, row in sp.items():
        assert row["FRED-C"] >= 1.0
        assert row["FRED-D"] >= row["FRED-C"] * 0.999   # D ≥ C always
    # streaming workloads: C == D (paper Sec. VIII)
    assert sp["GPT-3"]["FRED-C"] == pytest.approx(sp["GPT-3"]["FRED-D"])
    assert sp["Transformer-1T"]["FRED-C"] == \
        pytest.approx(sp["Transformer-1T"]["FRED-D"])


def test_speedups_within_band_of_paper():
    """Calibrated reproduction: every cell within a ×[0.6, 1.9] band of the
    paper's number (exact ASTRA-SIM inputs are unpublished; residuals are
    analyzed in EXPERIMENTS.md §Fig10)."""
    sp = simulate_speedups(CALIBRATED["compute_efficiency"],
                           CALIBRATED["mesh_step_overhead"],
                           CALIBRATED["fred_step_overhead"])
    for w, row in PAPER_SPEEDUPS.items():
        for cfg, target in row.items():
            ratio = sp[w][cfg] / target
            assert 0.6 < ratio < 1.9, f"{w} {cfg}: {sp[w][cfg]} vs {target}"


def test_breakdown_nonnegative_and_exposed_types():
    for w in paper_workloads():
        for fab, br in compare(w).items():
            d = br.as_dict()
            assert all(v >= 0 for v in d.values())
            if w.execution == "streaming":
                assert d["dp"] == 0.0   # grads reduce toward I/O in-fabric


def test_fig2_strategy_sweep_runs():
    from repro.core.workloads import transformer
    sim = Simulator("baseline")
    per_sample = []
    for st in fig2_strategies():
        # Fig. 2 uses the per-sequence sample reading (see workloads.py)
        w = transformer("T17B", 78, 4256, 1024, st, "stationary",
                        token_samples=False)
        per_sample.append(sim.run(w).total / w.minibatch)
    assert all(t > 0 for t in per_sample)
    # Fig. 2's core observation, normalized per sample (strategies process
    # different minibatches): MP(20)'s wafer-wide per-layer ARs make it
    # slower per sample than MP(5)-DP(4) despite better compute efficiency
    mp20 = per_sample[0]
    mp5dp4 = per_sample[2]
    assert mp20 > mp5dp4
