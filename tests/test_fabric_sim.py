"""Mesh/FRED fabric models + end-to-end simulator vs the paper's numbers."""

import pytest

from repro.core.calibrate import CALIBRATED, PAPER_SPEEDUPS, simulate_speedups
from repro.core.fabric import CONFIGS, FredFabric
from repro.core.meshnet import MeshFabric
from repro.core.placement import Strategy, fred_placement, mesh_placement, placement_groups
from repro.core.simulator import Simulator, compare
from repro.core.workloads import paper_workloads, fig2_strategies


# --------------------------------------------------------------------------
# mesh model (Sec. III / VI-B2)
# --------------------------------------------------------------------------

def test_mesh_io_controllers_is_18():
    assert MeshFabric().n_io_controllers() == 18   # Table IV baseline


def test_mesh_hotspot_formula():
    m = MeshFabric()
    assert m.io_hotspot_load() == 9                      # (2·5−1)
    assert m.io_linerate_factor() == pytest.approx(750 / 1152, rel=1e-3)


def test_xy_routing():
    m = MeshFabric()
    assert len(m.xy_links(0, 0)) == 0
    assert len(m.xy_links(0, 3)) == 3          # same row
    assert len(m.xy_links(0, 19)) == 3 + 4     # manhattan distance


def test_wafer_wide_bw_matches_paper():
    # Sec. VIII: corner NPUs limit wafer-wide AR to 2 links = 1.5 TB/s
    assert MeshFabric().wafer_wide_allreduce_bw() == pytest.approx(1.5e12)


# --------------------------------------------------------------------------
# FRED fabric (Sec. VIII microbenchmark numbers)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,expected", [
    ("FRED-A", 1.875e12),   # 375 + 4·375 GB/s hierarchical analysis
    ("FRED-B", 1.5e12),     # L1→L2 line rate, in-network
    ("FRED-C", 3e12),       # NPU-L1 line rate
    ("FRED-D", 3e12),
])
def test_wafer_wide_effective_bw(cfg, expected):
    fab = FredFabric(CONFIGS[cfg])
    group = list(range(20))
    assert fab.effective_npu_bw(group) == pytest.approx(expected, rel=1e-6)


def test_dp_stride_effective_bw_fred_a():
    """MP(2)-DP(5)-PP(2): DP peers land under different L1s; L1→L2 shared
    by 4 concurrent DP groups → FRED-A eff = 375 GB/s (Sec. VIII)."""
    fab = FredFabric(CONFIGS["FRED-A"])
    group = [0, 4, 8, 12, 16]
    assert fab.effective_npu_bw(group, concurrent_groups=4) == \
        pytest.approx(375e9, rel=1e-6)


def test_mp2_same_time_across_configs():
    """dim(MP)=2: endpoint and in-network traffic coincide, and peers are
    under one L1 — all FRED variants equal (Sec. VIII GPT-3 discussion)."""
    times = []
    for name in ("FRED-A", "FRED-B", "FRED-C", "FRED-D"):
        fab = FredFabric(CONFIGS[name])
        times.append(fab.collective_time("all_reduce", [0, 1], 1e6))
    assert max(times) == pytest.approx(min(times), rel=0.35)
    assert times[2] == pytest.approx(times[3], rel=1e-6)  # C == D exactly


def test_in_network_halves_traffic():
    from repro.core.flows import (endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    n, D = 20, 1e9
    ratio = endpoint_traffic_bytes("all_reduce", n, D) / \
        innetwork_traffic_bytes("all_reduce", n, D)
    assert ratio == pytest.approx(2 * (n - 1) / n)   # ≈2× (Abstract)


def test_fred_io_line_rate():
    assert FredFabric(CONFIGS["FRED-C"]).io_linerate_factor() == 1.0
    assert MeshFabric().io_linerate_factor() < 0.66


# --------------------------------------------------------------------------
# placement
# --------------------------------------------------------------------------

def test_fred_placement_mp_consecutive():
    st = Strategy(4, 3, 2)
    pl = fred_placement(st)
    assert len(set(pl.values())) == st.n_workers     # bijection
    for grp in st.mp_groups():
        ids = sorted(pl[w] for w in grp)
        assert ids == list(range(ids[0], ids[0] + len(ids)))  # consecutive


def test_mesh_placement_bijection():
    st = Strategy(5, 2, 2)
    pl = mesh_placement(st, 5, 4)
    assert len(set(pl.values())) == 20


# --------------------------------------------------------------------------
# end-to-end simulator vs the paper (Fig. 10)
# --------------------------------------------------------------------------

def test_speedup_structure():
    sp = simulate_speedups(CALIBRATED["compute_efficiency"],
                           CALIBRATED["mesh_step_overhead"],
                           CALIBRATED["fred_step_overhead"])
    for w, row in sp.items():
        assert row["FRED-C"] >= 1.0
        assert row["FRED-D"] >= row["FRED-C"] * 0.999   # D ≥ C always
    # streaming workloads: C == D (paper Sec. VIII)
    assert sp["GPT-3"]["FRED-C"] == pytest.approx(sp["GPT-3"]["FRED-D"])
    assert sp["Transformer-1T"]["FRED-C"] == \
        pytest.approx(sp["Transformer-1T"]["FRED-D"])


def test_speedups_within_band_of_paper():
    """Calibrated reproduction: every cell within a ×[0.6, 1.9] band of the
    paper's number (exact ASTRA-SIM inputs are unpublished; residuals are
    analyzed in EXPERIMENTS.md §Fig10)."""
    sp = simulate_speedups(CALIBRATED["compute_efficiency"],
                           CALIBRATED["mesh_step_overhead"],
                           CALIBRATED["fred_step_overhead"])
    for w, row in PAPER_SPEEDUPS.items():
        for cfg, target in row.items():
            ratio = sp[w][cfg] / target
            assert 0.6 < ratio < 1.9, f"{w} {cfg}: {sp[w][cfg]} vs {target}"


def test_breakdown_nonnegative_and_exposed_types():
    for w in paper_workloads():
        for fab, br in compare(w).items():
            d = br.as_dict()
            assert all(v >= 0 for v in d.values())
            if w.execution == "streaming":
                assert d["dp"] == 0.0   # grads reduce toward I/O in-fabric


def test_fig2_strategy_sweep_runs():
    from repro.core.workloads import transformer
    sim = Simulator("baseline")
    per_sample = []
    for st in fig2_strategies():
        # Fig. 2 uses the per-sequence sample reading (see workloads.py)
        w = transformer("T17B", 78, 4256, 1024, st, "stationary",
                        token_samples=False)
        per_sample.append(sim.run(w).total / w.minibatch)
    assert all(t > 0 for t in per_sample)
    # Fig. 2's core observation, normalized per sample (strategies process
    # different minibatches): MP(20)'s wafer-wide per-layer ARs make it
    # slower per sample than MP(5)-DP(4) despite better compute efficiency
    mp20 = per_sample[0]
    mp5dp4 = per_sample[2]
    assert mp20 > mp5dp4
