"""Serving-cell cost model: prefill/decode rooflines, continuous batching
under Poisson arrivals, and the (throughput × p50/p99 × HBM) Pareto.

The north-star question — "how many wafers serve 1M concurrent users of
qwen3-32b at a 200 ms p99?" — needs a *serving* time model, not the
training-iteration rank reused since PR 3.  This module builds it from
parts the repo already trusts:

* **Phase rooflines.**  Prefill is compute-bound (the whole prompt's
  FLOPs amortize one weight read), decode is HBM-bound (every step
  re-reads the weights plus the batch's KV).  Each phase time is
  ``max(compute_s, hbm_s) + exposed collective`` — the exposed-comm term
  is bit-exactly :func:`repro.launch.roofline.exposed_comm_s`, and the
  Megatron MP All-Reduce (2/layer) is priced by the *real* fabric via
  ``Simulator._coll_time`` on the same placement groups the training
  sweep uses, so FRED-vs-mesh differences rank serving cells too.
* **KV-cache-aware batching.**  The decode batch is capped by
  :func:`repro.core.workloads.memory_bytes_per_npu` under a
  ``training=False`` :class:`MemoryModel` (weights + KV vs the HBM
  budget) — the exact predicate the training autostrategy trusts.
* **Continuous batching + queueing.**  A cell is abstracted as ``c``
  request slots of deterministic occupancy ``D = c / capacity``:
  Poisson arrivals feed a shared FIFO queue (M/D/c).  The closed form
  is the classic M/D/c-style approximation — Erlang-C wait probability
  with the deterministic-service halving of the M/M/c wait, and a
  self-consistent exponential wait tail — cross-checked by
  :func:`simulate_traffic`, a seeded discrete-event simulator of the
  *same* system (the lifetime.py estimate-vs-simulate pattern; the
  servesweep gate pins <1 % agreement on mean TTFT).  Pooling is
  modeled up to :data:`SLOT_POOL_CAP` equivalent slots (beyond a few
  hundred slots extra pooling no longer moves the wait; capacity is
  preserved exactly by rescaling the occupancy).
* **Cell composition.**  :func:`serving_candidates` sweeps wafers per
  cell × fabric × wafer shape × MP degree × decode batch ×
  placement: ``colocated`` (one shared config continuously batches both
  phases) vs ``disaggregated`` (each phase elects its own fabric/shape/
  MP — FRED's reduction-distribution flexibility; ``wafers_prefill=0``
  means per-phase fabric re-election on every wafer with an HBM KV
  reshard, ``>0`` means dedicated prefill wafers shipping KV over the
  inter-wafer links, where the ring / fully-connected / switch topology
  sets the hop count and per-pair width).  Disaggregated throughput
  ≥ co-located at equal hardware *by construction*: the per-phase
  optima are taken over a superset of any shared config.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

import numpy as np

from repro.launch.roofline import exposed_comm_s
from .cluster import TOPOLOGY_CODES
from .placement import Strategy
from .simulator import NPU_PEAK_FLOPS
from .specs import ClusterSpec
from .sweep import _simulator, fred_shapes, mesh_shapes
from .workloads import (BYTES, DEFAULT_NPU_HBM_BYTES, MemoryModel,
                        adapter_n_layers, from_model_config,
                        memory_bytes_per_npu)

if TYPE_CHECKING:
    from repro.models.config import ModelConfig

# Per-NPU sustained HBM bandwidth.  Table II gives the NPU's 1000 TFLOPS
# FP16 peak but no memory figure; 3.2 TB/s is the HBM3-class ratio
# (~0.3 B/FLOP) production accelerators of that compute class ship with.
NPU_HBM_BW = 3.2e12                   # bytes/s per NPU

DEFAULT_COMPUTE_EFFICIENCY = 0.45     # matches core/sweep.py's default

# Decode batch sizes searched per replica (powers of two; the HBM
# feasibility predicate prunes the infeasible tail per config).
BATCH_CANDIDATES = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# Slot-utilization ceiling: capacity quotes stop at 90 % occupancy so the
# queue keeps a stable operating margin (rho -> 1 waits diverge).
MAX_SLOT_UTILIZATION = 0.9

# Queue-model pooling cap: a cell's physical slot count c can reach tens
# of thousands (replicas × batch); beyond a few hundred pooled slots the
# M/D/c wait is already negligible at any stable utilization, so the
# abstract queue uses min(c, cap) slots with occupancy rescaled to keep
# the capacity exact.  Both the closed form and the DES use the same
# abstraction, so the <1 % agreement gate is meaningful at any scale.
SLOT_POOL_CAP = 512

_PLACEMENT_CODES = {"colocated": 0, "disaggregated": 1}


class InfeasibleServingError(RuntimeError):
    """No (placement × wafers × fabric × shape × mp × batch) serving cell
    meets the HBM budget and the latency SLO."""


# --------------------------------------------------------------------------
# request profile + model-derived phase terms
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RequestProfile:
    """Token counts of one served request (prompt in, tokens out)."""
    prompt_tokens: int = 1024
    output_tokens: int = 256

    @property
    def ctx_tokens(self) -> int:
        return self.prompt_tokens + self.output_tokens


@dataclasses.dataclass(frozen=True)
class ModelTerms:
    """Architecture quantities the phase rooflines consume, derived from
    the same :func:`from_model_config` accounting the training sweep
    uses (prefill FLOPs at the prompt's attention window, decode FLOPs
    at the full context window — the family's averaged-position
    convention)."""
    n_layers: int
    d_model: int
    param_bytes_total: float
    kv_bytes_per_token: float         # all layers, both K and V
    prefill_flops_per_token: float    # all layers
    decode_flops_per_token: float     # all layers
    mp_allreduce_per_layer: int


def model_terms(cfg: "ModelConfig", profile: RequestProfile) -> ModelTerms:
    from repro.models.config import ShapeConfig
    pf_shape = ShapeConfig("serve_prefill", "prefill",
                           profile.prompt_tokens, 1)
    dec_shape = ShapeConfig("serve_decode", "decode",
                            profile.ctx_tokens, 1)
    st = Strategy(1, 1, 1)
    w_pf = from_model_config(cfg, pf_shape, st)
    w_dec = from_model_config(cfg, dec_shape, st)
    n_layers = adapter_n_layers(cfg)
    return ModelTerms(
        n_layers=n_layers,
        d_model=cfg.d_model,
        param_bytes_total=w_dec.params_per_layer * n_layers * BYTES,
        kv_bytes_per_token=w_dec.kv_bytes_per_sample_layer * n_layers,
        prefill_flops_per_token=w_pf.flops_fwd_per_sample_layer * n_layers,
        decode_flops_per_token=w_dec.flops_fwd_per_sample_layer * n_layers,
        mp_allreduce_per_layer=w_dec.mp_allreduce_per_layer,
    )


def serving_memory_bytes_per_npu(cfg: "ModelConfig", profile: RequestProfile,
                                 mp: int, batch: int,
                                 npu_hbm_bytes: float) -> float:
    """Per-NPU resident bytes for ``batch`` live sequences at full
    context, via the training sweep's own ``memory_bytes_per_npu`` under
    a serving (``training=False``) :class:`MemoryModel` — weights + the
    KV cache of ``batch × ctx`` resident tokens, MP-sharded."""
    from repro.models.config import ShapeConfig
    shape = ShapeConfig("serve_resident", "decode", profile.ctx_tokens, 1)
    w = from_model_config(cfg, shape, Strategy(mp, 1, 1))
    w = dataclasses.replace(w, samples_per_dp=batch * profile.ctx_tokens,
                            seq=1)
    mem = MemoryModel(npu_hbm_bytes=npu_hbm_bytes, training=False)
    return memory_bytes_per_npu(w, mem)


# --------------------------------------------------------------------------
# phase rooflines (scalar oracle + batched engine, bit-identical)
# --------------------------------------------------------------------------

def decode_step_terms(flops_per_token_npu: float, weight_bytes_npu: float,
                      kv_seq_bytes_npu: float, coll_s: float, batch: int,
                      eff_flops: float,
                      comm_overlap_fraction: float = 0.0) -> float:
    """One decode step of a ``batch``-sequence replica (scalar oracle).

    ``max(compute, HBM) + exposed collective`` — the weights are re-read
    every step, the batch's KV streams once, and the MP All-Reduce is
    exposed past the overlappable compute share (the PR-8 overlap law,
    bit-exactly ``launch.roofline.exposed_comm_s``)."""
    compute_s = batch * flops_per_token_npu / eff_flops
    hbm_s = (weight_bytes_npu + batch * kv_seq_bytes_npu) / NPU_HBM_BW
    return max(compute_s, hbm_s) + exposed_comm_s(
        coll_s, comm_overlap_fraction * compute_s)


def decode_step_terms_batch(flops_per_token_npu: float,
                            weight_bytes_npu: float,
                            kv_seq_bytes_npu: float,
                            coll_s: np.ndarray, batches: np.ndarray,
                            eff_flops: float,
                            comm_overlap_fraction: float = 0.0
                            ) -> np.ndarray:
    """Vectorized :func:`decode_step_terms` over a batch axis —
    bit-identical to the scalar oracle (same float64 op order; pinned by
    tests/test_serving.py)."""
    compute_s = batches * flops_per_token_npu / eff_flops
    hbm_s = (weight_bytes_npu + batches * kv_seq_bytes_npu) / NPU_HBM_BW
    return np.maximum(compute_s, hbm_s) + np.maximum(
        0.0, coll_s - comm_overlap_fraction * compute_s)


def prefill_time_s(terms: ModelTerms, profile: RequestProfile, mp: int,
                   coll_s: float, eff_flops: float,
                   comm_overlap_fraction: float = 0.0) -> float:
    """One prompt's prefill on an ``mp``-NPU replica: the prompt's FLOPs
    against one weight read + the prompt's KV write, plus the exposed MP
    collective."""
    compute_s = (profile.prompt_tokens * terms.prefill_flops_per_token /
                 mp / eff_flops)
    hbm_s = ((terms.param_bytes_total +
              profile.prompt_tokens * terms.kv_bytes_per_token) / mp /
             NPU_HBM_BW)
    return max(compute_s, hbm_s) + exposed_comm_s(
        coll_s, comm_overlap_fraction * compute_s)


# --------------------------------------------------------------------------
# M/D/c-style queueing: closed form + seeded discrete-event simulator
# --------------------------------------------------------------------------

def erlang_c(slots: int, offered: float) -> float:
    """M/M/c wait probability (Erlang C) via the stable Erlang-B
    recurrence; ``offered`` = arrival_rate × service (< slots)."""
    if offered <= 0.0:
        return 0.0
    if offered >= slots:
        return 1.0
    b = 1.0
    for k in range(1, slots + 1):
        b = offered * b / (k + offered * b)
    return slots * b / (slots - offered * (1.0 - b))


@dataclasses.dataclass(frozen=True)
class QueueStats:
    """Closed-form M/D/c-style wait statistics at one operating point."""
    arrival_rate_rps: float
    slots: int
    service_s: float
    utilization: float
    wait_probability: float
    mean_wait_s: float
    p50_wait_s: float
    p99_wait_s: float


def queue_stats(arrival_rate_rps: float, service_s: float,
                slots: int) -> QueueStats:
    """M/D/c-style approximation: Erlang-C wait probability, the
    deterministic-service halving of the M/M/c mean wait (exact
    Pollaczek–Khinchine at c=1), and the self-consistent exponential
    tail ``P(W>t) = C·exp(-2(c-a)t/D)`` for quantiles."""
    offered = arrival_rate_rps * service_s
    rho = offered / slots
    if rho >= 1.0:
        inf = math.inf
        return QueueStats(arrival_rate_rps, slots, service_s, rho,
                          1.0, inf, inf, inf)
    c_wait = erlang_c(slots, offered)
    theta = 2.0 * (slots - offered) / service_s
    mean_wait_s = c_wait / theta

    def quantile(p: float) -> float:
        if c_wait <= 1.0 - p:
            return 0.0
        return math.log(c_wait / (1.0 - p)) / theta

    return QueueStats(arrival_rate_rps, slots, service_s, rho, c_wait,
                      mean_wait_s, quantile(0.5), quantile(0.99))


def simulate_traffic(arrival_rate_rps: float, service_s: float, slots: int,
                     *, base_latency_s: float = 0.0,
                     n_requests: int = 200_000, seed: int = 0,
                     warmup: int = 2_000) -> Dict[str, float]:
    """Seeded discrete-event simulation of the same M/D/c system the
    closed form approximates: Poisson arrivals, one shared FIFO queue,
    ``slots`` servers of deterministic occupancy ``service_s``.

    With equal deterministic service and FIFO order, request ``i``
    starts exactly when request ``i - slots`` departs — an O(1) ring
    buffer replaces the event heap.  Returns wait/TTFT tallies
    (``base_latency_s`` is the deterministic prefill + handoff + first
    decode step added to every request)."""
    rng = random.Random(seed)
    dep = [-math.inf] * slots          # departure of the (i-slots)-th job
    t = 0.0
    waits: List[float] = []
    for i in range(n_requests):
        t += rng.expovariate(arrival_rate_rps)
        free = dep[i % slots]
        wait = free - t if free > t else 0.0
        dep[i % slots] = t + wait + service_s
        if i >= warmup:
            waits.append(wait)
    waits.sort()
    n = len(waits)

    def quantile(p: float) -> float:
        return waits[min(n - 1, int(p * n))]

    mean_wait_s = math.fsum(waits) / n
    return {
        "n_requests": float(n),
        "mean_wait_s": mean_wait_s,
        "p50_wait_s": quantile(0.5),
        "p99_wait_s": quantile(0.99),
        "mean_ttft_s": base_latency_s + mean_wait_s,
        "p50_ttft_s": base_latency_s + quantile(0.5),
        "p99_ttft_s": base_latency_s + quantile(0.99),
    }

# --------------------------------------------------------------------------
# cell candidates: placement × wafers × fabric × shape × mp × batch
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """One phase's per-wafer configuration and its service rate."""
    fabric: str
    wafer_shape: Tuple[int, int]
    mp: int
    batch: int                        # decode batch per replica (1 = prefill)
    replicas: int                     # per wafer (n_npus // mp)
    step_s: float                     # prefill time / decode step time
    rate_rps: float                   # per-wafer phase service rate
    memory_bytes_per_npu: float

    def key(self) -> Tuple:
        return (self.fabric, self.wafer_shape, self.mp)


@dataclasses.dataclass(frozen=True)
class CellCandidate:
    """One serving-cell composition with its capacity and queue shape.

    ``placement="colocated"``: one shared (fabric, shape, mp, batch)
    continuously batches both phases — each replica time-shares prefills
    into the decode stream, so a slot's occupancy is
    ``batch·T_pf + output·T_step``.  ``placement="disaggregated"``: each
    phase runs its own optimum; ``wafers_prefill=0`` re-elects the
    fabric per phase on every wafer (KV stays in HBM, resharded when the
    phase configs differ), ``>0`` dedicates wafers per phase and ships
    the prompt's KV over the inter-wafer topology.
    """
    placement: str                    # colocated | disaggregated
    wafers: int
    wafers_prefill: int               # 0 = per-phase fabric re-election
    inter_topology: str               # "" unless KV crosses wafers
    prefill: PhasePlan
    decode: PhasePlan
    capacity_rps: float               # sustained request rate (rho -> 1)
    slots: int                        # physical decode slots (replicas×batch)
    handoff_s: float                  # KV reshard / inter-wafer transfer
    base_ttft_s: float                # unloaded TTFT: prefill+handoff+step
    memory_bytes_per_npu: float

    def queue_shape(self) -> Tuple[int, float]:
        """(slots, occupancy_s) of the abstract M/D/c queue — pooling
        capped at SLOT_POOL_CAP with the occupancy rescaled so
        slots/occupancy equals the physical capacity exactly."""
        c = min(self.slots, SLOT_POOL_CAP)
        return c, c / self.capacity_rps

    def ttft_stats(self, arrival_rate_rps: float) -> QueueStats:
        c, occ = self.queue_shape()
        return queue_stats(arrival_rate_rps, occ, c)

    def ttft_p99_s(self, arrival_rate_rps: float) -> float:
        return self.base_ttft_s + self.ttft_stats(arrival_rate_rps).p99_wait_s


def _handoff_s(profile: RequestProfile, terms: ModelTerms,
               prefill: PhasePlan, decode: PhasePlan,
               wafers: int, wafers_prefill: int,
               inter_topology: str) -> float:
    """Per-request KV handoff cost (latency-only: the transfer DMAs
    overlap other batches' compute, so capacity is unaffected).

    Re-election (wafers_prefill=0): zero when both phases share a
    config; otherwise the prompt's KV is rewritten into the decode
    sharding through HBM.  Dedicated wafers: the KV additionally crosses
    the inter-wafer level once — ring pays worst-case hops, fully
    connected a 1/(w-1)-width pair link, switch the full budget with two
    hop latencies (core/cluster.py's level model, first-order)."""
    kv_prompt_bytes = profile.prompt_tokens * terms.kv_bytes_per_token
    if wafers_prefill == 0:
        if prefill.key() == decode.key():
            return 0.0
        return 2.0 * kv_prompt_bytes / decode.mp / NPU_HBM_BW
    spec = ClusterSpec()
    agg_bw = spec.inter_wafer_links * spec.inter_wafer_bw
    lat_s = spec.inter_wafer_latency
    reshard_s = 2.0 * kv_prompt_bytes / decode.mp / NPU_HBM_BW
    if inter_topology == "ring":
        hops = max(1, wafers // 2)
        wire_s = hops * (kv_prompt_bytes / agg_bw) + hops * lat_s
    elif inter_topology == "fully_connected":
        wire_s = kv_prompt_bytes * (wafers - 1) / agg_bw + lat_s
    else:                             # switch: full width, up + down
        wire_s = kv_prompt_bytes / agg_bw + 2.0 * lat_s
    return reshard_s + wire_s


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def phase_plans(cfg: "ModelConfig", profile: RequestProfile, *,
                n_npus: int = 64,
                fabrics: Sequence[str] = ("baseline", "FRED-C", "FRED-D"),
                npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES,
                compute_efficiency: float = DEFAULT_COMPUTE_EFFICIENCY,
                comm_overlap_fraction: float = 0.0,
                cache: Optional[dict] = None
                ) -> Tuple[List[PhasePlan], List[PhasePlan]]:
    """(prefill_plans, decode_plans) per wafer, HBM-feasible only.

    The MP All-Reduce is priced per (fabric, wafer shape, mp) by the
    real collective model on the training placements; decode step times
    run through the batched engine (bit-identical to the scalar
    oracle).  One collective cache spans the sweep, like core/sweep."""
    terms = model_terms(cfg, profile)
    eff_flops = NPU_PEAK_FLOPS * compute_efficiency
    cache = {} if cache is None else cache
    pf_plans: List[PhasePlan] = []
    dec_plans: List[PhasePlan] = []
    for fabric in fabrics:
        shapes = (mesh_shapes(n_npus) if fabric == "baseline"
                  else fred_shapes(n_npus))
        for shape in shapes:
            sim = _simulator(fabric, shape, n_npus, cache,
                             compute_efficiency)
            for mp in _divisors(n_npus):
                replicas = n_npus // mp
                # memory at batch=1 gates even the prefill-only plan
                mem_1 = serving_memory_bytes_per_npu(
                    cfg, profile, mp, 1, npu_hbm_bytes)
                if mem_1 > npu_hbm_bytes:
                    continue
                group = None
                if mp > 1:
                    groups = sim._groups(Strategy(mp, replicas, 1))
                    group = (groups["mp"][0], len(groups["mp"]))

                def ar_s(nbytes: float) -> float:
                    if group is None:
                        return 0.0
                    per = sim._coll_time("all_reduce", group[0], nbytes,
                                         concurrent=group[1])
                    return per * terms.mp_allreduce_per_layer * terms.n_layers

                pf_step = prefill_time_s(
                    terms, profile, mp,
                    ar_s(profile.prompt_tokens * terms.d_model * BYTES),
                    eff_flops, comm_overlap_fraction)
                pf_plans.append(PhasePlan(
                    fabric, shape, mp, 1, replicas, pf_step,
                    replicas / pf_step, mem_1))

                batches = [b for b in BATCH_CANDIDATES
                           if serving_memory_bytes_per_npu(
                               cfg, profile, mp, b, npu_hbm_bytes)
                           <= npu_hbm_bytes]
                if not batches:
                    continue
                coll = np.array([ar_s(b * terms.d_model * BYTES)
                                 for b in batches], dtype=np.float64)
                steps = decode_step_terms_batch(
                    terms.decode_flops_per_token / mp,
                    terms.param_bytes_total / mp,
                    profile.ctx_tokens * terms.kv_bytes_per_token / mp,
                    coll, np.array(batches, dtype=np.float64),
                    eff_flops, comm_overlap_fraction)
                for b, step in zip(batches, steps.tolist()):
                    dec_plans.append(PhasePlan(
                        fabric, shape, mp, b, replicas, step,
                        replicas * b / (profile.output_tokens * step),
                        serving_memory_bytes_per_npu(
                            cfg, profile, mp, b, npu_hbm_bytes)))
    return pf_plans, dec_plans


def _plan_key(p: PhasePlan) -> Tuple:
    """Deterministic preference among equal-rate plans: faster step,
    smaller footprint, then a total lexical tiebreak."""
    return (-p.rate_rps, p.step_s, p.memory_bytes_per_npu, p.fabric,
            p.wafer_shape, p.mp, p.batch)


def serving_candidates(cfg: "ModelConfig", profile: RequestProfile, *,
                       n_npus: int = 64,
                       fabrics: Sequence[str] = ("baseline", "FRED-C",
                                                 "FRED-D"),
                       max_wafers: int = 2,
                       inter_topologies: Sequence[str] = (
                           "ring", "fully_connected", "switch"),
                       npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES,
                       compute_efficiency: float = DEFAULT_COMPUTE_EFFICIENCY,
                       comm_overlap_fraction: float = 0.0
                       ) -> List[CellCandidate]:
    """Every serving-cell composition up to ``max_wafers``."""
    pf_plans, dec_plans = phase_plans(
        cfg, profile, n_npus=n_npus, fabrics=fabrics,
        npu_hbm_bytes=npu_hbm_bytes,
        compute_efficiency=compute_efficiency,
        comm_overlap_fraction=comm_overlap_fraction)
    if not pf_plans or not dec_plans:
        return []
    terms = model_terms(cfg, profile)
    pf_by_key = {p.key(): p for p in pf_plans}
    best_pf = min(pf_plans, key=_plan_key)
    best_dec = min(dec_plans, key=_plan_key)
    out: List[CellCandidate] = []
    for w in range(1, max_wafers + 1):
        # co-located: the decode config also runs the prefills, so both
        # phases must share (fabric, shape, mp); a slot's occupancy is
        # its prefill (serialized with the replica's batch-mates') plus
        # its decode share.
        for dec in dec_plans:
            pf = pf_by_key[dec.key()]
            occupancy_s = dec.batch * pf.step_s + \
                profile.output_tokens * dec.step_s
            slots = w * dec.replicas * dec.batch
            out.append(CellCandidate(
                placement="colocated", wafers=w, wafers_prefill=0,
                inter_topology="", prefill=pf, decode=dec,
                capacity_rps=slots / occupancy_s, slots=slots,
                handoff_s=0.0,
                base_ttft_s=pf.step_s + dec.step_s,
                memory_bytes_per_npu=dec.memory_bytes_per_npu))
        # disaggregated, per-phase fabric re-election on every wafer:
        # capacity = 1 / (1/a + 1/b) per wafer (each request consumes
        # 1/a of the cell in prefill mode then 1/b in decode mode)
        hand = _handoff_s(profile, terms, best_pf, best_dec, w, 0, "")
        cap = w / (1.0 / best_pf.rate_rps + 1.0 / best_dec.rate_rps)
        slots = w * best_dec.replicas * best_dec.batch
        out.append(CellCandidate(
            placement="disaggregated", wafers=w, wafers_prefill=0,
            inter_topology="", prefill=best_pf, decode=best_dec,
            capacity_rps=cap, slots=slots, handoff_s=hand,
            base_ttft_s=best_pf.step_s + hand + best_dec.step_s,
            memory_bytes_per_npu=max(best_pf.memory_bytes_per_npu,
                                     best_dec.memory_bytes_per_npu)))
        # disaggregated, dedicated prefill wafers: steady state is paced
        # by the slower stage; the prompt's KV crosses the inter level
        for w_pf in range(1, w):
            w_dec = w - w_pf
            cap = min(w_pf * best_pf.rate_rps, w_dec * best_dec.rate_rps)
            slots = w_dec * best_dec.replicas * best_dec.batch
            for topo in inter_topologies:
                hand = _handoff_s(profile, terms, best_pf, best_dec,
                                  w, w_pf, topo)
                out.append(CellCandidate(
                    placement="disaggregated", wafers=w,
                    wafers_prefill=w_pf, inter_topology=topo,
                    prefill=best_pf, decode=best_dec,
                    capacity_rps=cap, slots=slots, handoff_s=hand,
                    base_ttft_s=best_pf.step_s + hand + best_dec.step_s,
                    memory_bytes_per_npu=max(
                        best_pf.memory_bytes_per_npu,
                        best_dec.memory_bytes_per_npu)))
    return out


def slo_capacity_rps(cand: CellCandidate, target_p99_s: float) -> float:
    """Largest sustainable arrival rate with p99 TTFT within the SLO
    (0.0 = the cell can never meet it).  p99(rate) is monotone, so a
    bisection between 0 and the utilization-capped capacity suffices;
    the common case (SLO met at the cap) costs one evaluation."""
    cap = MAX_SLOT_UTILIZATION * cand.capacity_rps
    if cand.base_ttft_s > target_p99_s:
        return 0.0
    if cand.ttft_p99_s(cap) <= target_p99_s:
        return cap
    lo, hi = 0.0, cap
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if cand.ttft_p99_s(mid) <= target_p99_s:
            lo = mid
        else:
            hi = mid
    return lo


# --------------------------------------------------------------------------
# Pareto front + decision
# --------------------------------------------------------------------------

def _dominates(a: Tuple, b: Tuple) -> bool:
    return a != b and all(x <= y for x, y in zip(a, b))


def pareto_indices(points: Sequence[Tuple]) -> List[int]:
    """Indices of the minimizing Pareto front (incremental, like
    ``core.sweep.pareto_front`` — O(n·|front|), deterministic order)."""
    front: List[int] = []
    for i, p in enumerate(points):
        if any(_dominates(points[j], p) for j in front):
            continue
        front = [j for j in front if not _dominates(p, points[j])]
        front.append(i)
    return front


@dataclasses.dataclass(frozen=True)
class ServingDecision:
    """The elected serving-cell composition for one (model, Objective)."""
    arch: str
    prompt_tokens: int
    output_tokens: int
    target_p99_ms: float
    arrival_rate_rps: float           # total offered load across cells
    placement: str
    wafers_per_cell: int
    wafers_prefill: int
    inter_topology: str
    prefill_fabric: str
    prefill_shape: Tuple[int, int]
    prefill_mp: int
    decode_fabric: str
    decode_shape: Tuple[int, int]
    decode_mp: int
    decode_batch: int
    n_cells: int
    total_wafers: int                 # the north-star answer
    cell_capacity_rps: float
    cell_slo_capacity_rps: float
    ttft_p50_ms: float                # at the per-cell operating rate
    ttft_p99_ms: float
    prefill_s: float
    decode_step_s: float
    handoff_s: float
    memory_bytes_per_npu: float
    npu_hbm_bytes: float
    slots: int
    n_candidates: int
    n_infeasible: int
    n_dominated: int
    sweep_seconds: float
    cell: CellCandidate

    def golden(self) -> Dict:
        """Stable decision signature for golden diffs (float-laden rate
        fields stay out; the p99 is pinned at 6 significant digits like
        the lifetime goldens pin goodput)."""
        d: Dict = {
            "placement": self.placement,
            "wafers_per_cell": self.wafers_per_cell,
            "inter_topology": self.inter_topology,
            "n_cells": self.n_cells,
            "total_wafers": self.total_wafers,
            "prefill": {"fabric": self.prefill_fabric,
                        "wafer_shape": list(self.prefill_shape),
                        "mp": self.prefill_mp},
            "decode": {"fabric": self.decode_fabric,
                       "wafer_shape": list(self.decode_shape),
                       "mp": self.decode_mp,
                       "batch": self.decode_batch},
            "ttft_p99_ms": float(f"{self.ttft_p99_ms:.6g}"),
        }
        if self.wafers_prefill > 0:
            d["wafers_prefill"] = self.wafers_prefill
        return d


SERVING_CSV_HEADER = (
    "arch,placement,wafers_per_cell,wafers_prefill,inter_topology,"
    "prefill_fabric,prefill_shape_a,prefill_shape_b,prefill_mp,"
    "decode_fabric,decode_shape_a,decode_shape_b,decode_mp,decode_batch,"
    "n_cells,total_wafers,cell_capacity_rps,cell_slo_capacity_rps,"
    "ttft_p50_ms,ttft_p99_ms,prefill_s,decode_step_s,handoff_s,"
    "memory_bytes_per_npu,npu_hbm_bytes,slots,"
    "n_candidates,n_infeasible,n_dominated,sweep_s"
)


def serving_csv_rows(decisions: Sequence[ServingDecision]) -> List[str]:
    rows = [SERVING_CSV_HEADER]
    for d in decisions:
        rows.append(",".join(str(v) for v in (
            d.arch, d.placement, d.wafers_per_cell, d.wafers_prefill,
            d.inter_topology or "-",
            d.prefill_fabric, d.prefill_shape[0], d.prefill_shape[1],
            d.prefill_mp,
            d.decode_fabric, d.decode_shape[0], d.decode_shape[1],
            d.decode_mp, d.decode_batch,
            d.n_cells, d.total_wafers,
            f"{d.cell_capacity_rps:.6g}", f"{d.cell_slo_capacity_rps:.6g}",
            f"{d.ttft_p50_ms:.6g}", f"{d.ttft_p99_ms:.6g}",
            f"{d.prefill_s:.6g}", f"{d.decode_step_s:.6g}",
            f"{d.handoff_s:.6g}",
            int(d.memory_bytes_per_npu), int(d.npu_hbm_bytes), d.slots,
            d.n_candidates, d.n_infeasible, d.n_dominated,
            f"{d.sweep_seconds:.3f}")))
    return rows


def decide_serving(cfg: "ModelConfig", objective, *,
                   n_npus: int = 64,
                   fabrics: Sequence[str] = ("baseline", "FRED-C",
                                             "FRED-D"),
                   max_wafers: int = 2,
                   inter_topologies: Sequence[str] = (
                       "ring", "fully_connected", "switch"),
                   npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES,
                   compute_efficiency: float = DEFAULT_COMPUTE_EFFICIENCY,
                   comm_overlap_fraction: float = 0.0) -> ServingDecision:
    """Elect the serving-cell composition for a serving
    :class:`repro.core.specs.Objective` (duck-typed: ``target_p99_ms``,
    ``arrival_rate_rps`` / ``concurrent_users`` + ``think_time_s``,
    ``prompt_tokens``, ``output_tokens``).

    The winner minimizes total wafers for the offered load, then p99
    TTFT at the per-cell operating rate, then HBM footprint, with a
    total deterministic tiebreak (placement, wafers, topology, configs).
    """
    t0 = time.perf_counter()  # repro: ignore[DETERMINISM] duration metric only
    profile = RequestProfile(prompt_tokens=objective.prompt_tokens,
                             output_tokens=objective.output_tokens)
    lam = float(objective.arrival_rate_rps)
    if lam <= 0.0 and objective.concurrent_users > 0:
        lam = objective.concurrent_users / objective.think_time_s
    if lam <= 0.0:
        raise ValueError(
            "serving objective needs arrival_rate_rps > 0 or "
            "concurrent_users > 0 (with think_time_s)")
    target_s = objective.target_p99_ms / 1e3
    cands = serving_candidates(
        cfg, profile, n_npus=n_npus, fabrics=fabrics,
        max_wafers=max_wafers, inter_topologies=inter_topologies,
        npu_hbm_bytes=npu_hbm_bytes, compute_efficiency=compute_efficiency,
        comm_overlap_fraction=comm_overlap_fraction)
    feasible: List[Tuple[CellCandidate, float]] = []
    for cand in cands:
        cap = slo_capacity_rps(cand, target_s)
        if cap > 0.0:
            feasible.append((cand, cap))
    if not feasible:
        raise InfeasibleServingError(
            f"{cfg.name}: no serving cell (≤{max_wafers} wafers of "
            f"{n_npus} NPUs) meets p99 ≤ {objective.target_p99_ms} ms "
            f"within {npu_hbm_bytes / 2**30:.0f} GiB HBM")
    front = pareto_indices([
        (-cap / cand.wafers,
         float(f"{cand.base_ttft_s + cand.ttft_stats(cap).p99_wait_s:.12g}"),
         cand.memory_bytes_per_npu)
        for cand, cap in feasible])
    best_key = None
    best = None
    for cand, cap in feasible:
        n_cells = max(1, math.ceil(lam / cap))
        lam_op = lam / n_cells
        stats = cand.ttft_stats(lam_op)
        p99_op = cand.base_ttft_s + stats.p99_wait_s
        key = (n_cells * cand.wafers, p99_op, cand.memory_bytes_per_npu,
               _PLACEMENT_CODES[cand.placement], cand.wafers,
               TOPOLOGY_CODES.get(cand.inter_topology, -1),
               (cand.prefill.fabric, cand.prefill.wafer_shape,
                cand.prefill.mp),
               (cand.decode.fabric, cand.decode.wafer_shape,
                cand.decode.mp, cand.decode.batch))
        if best_key is None or key < best_key:
            best_key = key
            best = (cand, cap, n_cells, lam_op, stats, p99_op)
    cand, cap, n_cells, lam_op, stats, p99_op = best
    return ServingDecision(
        arch=cfg.name,
        prompt_tokens=profile.prompt_tokens,
        output_tokens=profile.output_tokens,
        target_p99_ms=objective.target_p99_ms,
        arrival_rate_rps=lam,
        placement=cand.placement,
        wafers_per_cell=cand.wafers,
        wafers_prefill=cand.wafers_prefill,
        inter_topology=cand.inter_topology,
        prefill_fabric=cand.prefill.fabric,
        prefill_shape=cand.prefill.wafer_shape,
        prefill_mp=cand.prefill.mp,
        decode_fabric=cand.decode.fabric,
        decode_shape=cand.decode.wafer_shape,
        decode_mp=cand.decode.mp,
        decode_batch=cand.decode.batch,
        n_cells=n_cells,
        total_wafers=n_cells * cand.wafers,
        cell_capacity_rps=cand.capacity_rps,
        cell_slo_capacity_rps=cap,
        ttft_p50_ms=(cand.base_ttft_s + stats.p50_wait_s) * 1e3,
        ttft_p99_ms=p99_op * 1e3,
        prefill_s=cand.prefill.step_s,
        decode_step_s=cand.decode.step_s,
        handoff_s=cand.handoff_s,
        memory_bytes_per_npu=cand.memory_bytes_per_npu,
        npu_hbm_bytes=npu_hbm_bytes,
        slots=cand.slots,
        n_candidates=len(cands),
        n_infeasible=len(cands) - len(feasible),
        n_dominated=len(feasible) - len(front),
        sweep_seconds=time.perf_counter() - t0,  # repro: ignore[DETERMINISM] never feeds goldens
        cell=cand,
    )
