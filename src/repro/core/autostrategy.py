"""Sweep-driven auto-strategy: the simulator picks (mp, dp, pp, wafers).

The paper's thesis (Sec. I, Fig. 2) is that a flexible fabric lets the
*compiler* pick whatever parallelization strategy compute/memory prefers.
This module closes that loop for the JAX substrate: given a registry
``ModelConfig`` and a ``ShapeConfig`` cell, it

  1. derives the analytical :class:`~repro.core.workloads.Workload` via
     :func:`~repro.core.workloads.from_model_config`,
  2. runs the (fabric × wafer shape × wafer count × inter-wafer topology
     × strategy) sweep of :mod:`repro.core.sweep` with the per-NPU
     memory-feasibility model (weights + optimizer state per the
     OptimConfig master/moments dtypes + activation footprint under the
     remat setting, against an ``npu_hbm_bytes`` budget) and
     canonical-form symmetry pruning — the inter-wafer topology (ring /
     fully_connected / switch, core/cluster.py) is searched alongside
     the strategy, so the fabric flexes to the parallelization *and*
     vice versa,
  3. falls back to weight-streaming execution (Sec. III-A: weights stream
     through I/O, optimizer runs near storage) when no weight-stationary
     strategy fits — the paper's own answer for Transformer-1T-class
     models, and
  4. returns the Pareto-optimal feasible point as an
     :class:`AutoStrategyDecision`, with the dominated/infeasible counts
     that explain *why* (recorded by the dry-run and the decision table).

``repro.parallel.policy.cell_policy(..., autostrategy=True)`` consumes
this; ``benchmarks.run --only autostrategy`` emits the per-model decision
table the CI strategy-regression gate diffs against
``tests/goldens/autostrategy.json``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union, \
    TYPE_CHECKING

from .cluster import INTER_TOPOLOGIES, TOPOLOGY_CODES
from .placement import Strategy
from .serving import ServingDecision, decide_serving
from .specs import DeploymentRequest, Objective
from .sweep import SweepResult, sweep
from .workloads import (DEFAULT_NPU_HBM_BYTES, MemoryModel,
                        adapter_n_layers, from_model_config)

if TYPE_CHECKING:
    from repro.models.config import ModelConfig, ShapeConfig

DEFAULT_FABRICS = ("baseline", "FRED-C", "FRED-D")

# Legacy kwarg-sprawl entry points (ISSUE 10): calls to these names are
# flagged by analysis/deprecation.py (rule X3) outside this module and
# core/specs.py — new call sites build a DeploymentRequest (+ Objective)
# and go through choose(request).  The shim itself stays: it warns and
# resolves to a bit-identical decision.
_LEGACY_CHOOSE_FNS = ("choose_strategy",)

# The MoE registry entries the epsweep CI gate pins (both must choose
# ep > 1) and the expert/sequence axes their decision sweep searches —
# shared by benchmarks.run --only epsweep and tests/gen_epsweep_golden.py
# so the gate and its golden generator can never drift apart.
MOE_ARCHS = ("mixtral-8x7b", "arctic-480b")
EP_SWEEP_KW = dict(ep_candidates=(1, 2, 4, 8), sp_candidates=(1, 2))

# The lifetimesweep CI gate: every registry arch, decided twice (healthy
# time vs lifetime goodput) on the PR-6 single-wafer deployment at a
# realistic per-NPU MTBF.  2000 h/NPU ≈ 83 days; at 20 used NPUs the
# system fails every ~100 h, ~7 failures over the 720 h mission — enough
# for elastic-degradation differences to flip decisions (zamba2-2.7b,
# chatglm3-6b, arctic-480b at the pinned settings).  Shared by
# benchmarks.run --only lifetimesweep and tests/gen_lifetime_golden.py so
# the gate and its golden generator can never drift apart.
LIFETIME_ARCHS = ("zamba2-2.7b", "llava-next-34b", "whisper-medium",
                  "llama3.2-1b", "chatglm3-6b", "qwen3-32b", "qwen1.5-4b",
                  "arctic-480b", "mixtral-8x7b", "mamba2-1.3b")
LIFETIME_SWEEP_KW = dict(n_npus=20, max_wafers=1)
LIFETIME_MTBF_NPU_HOURS = 2000.0


class InfeasibleModelError(RuntimeError):
    """No (fabric × shape × wafers × strategy × execution) candidate fits
    the per-NPU HBM budget."""


@dataclasses.dataclass(frozen=True)
class AutoStrategyDecision:
    """One row of the auto-strategy decision table."""
    arch: str
    shape: str                        # ShapeConfig.name
    fabric: str
    wafer_shape: Tuple[int, int]      # per-wafer (rows, cols) / (g, k)
    strategy: Strategy
    inter_topology: str               # ring | fully_connected | switch;
                                      # "" when the choice is single-wafer
    hierarchy: Tuple[int, ...]        # inter-level counts ((1,) = single
                                      # wafer, (4,) = flat, (2, 2) = rack×pod)
    execution: str                    # stationary | streaming
    remat: str
    master: bool
    moments_dtype: str
    time_per_sample_s: float
    memory_bytes_per_npu: float
    npu_hbm_bytes: float
    n_candidates: int                 # simulated sweep points (all modes)
    n_infeasible: int                 # failed the memory predicate
    n_dominated: int                  # feasible but off the Pareto front
    sweep_seconds: float
    # lifetime-goodput objective (core/lifetime.py); defaults are the
    # plain time objective so pre-lifetime constructions/goldens are
    # untouched
    objective: str = "time"           # time | goodput
    mtbf_npu_hours: float = math.inf
    goodput_samples_per_s: float = 0.0
    ckpt_write_s: float = 0.0         # repro: unit[s]
    ckpt_interval_s: float = 0.0      # repro: unit[s] (inf: never ckpt)
    useful_fraction: float = 1.0      # healthy-state wall-clock share
    survives_mission: bool = True     # degradation chain never went dead

    @property
    def mp(self) -> int:
        return self.strategy.mp

    @property
    def dp(self) -> int:
        return self.strategy.dp

    @property
    def pp(self) -> int:
        return self.strategy.pp

    @property
    def wafers(self) -> int:
        return self.strategy.wafers

    @property
    def ep(self) -> int:
        return self.strategy.ep

    @property
    def sp(self) -> int:
        return self.strategy.sp

    def golden(self) -> Dict[str, object]:
        """The fields the CI strategy-regression gate pins.  ep/sp appear
        only when > 1, so goldens from the 5-axis era stay byte-identical
        for dense models while MoE decisions pin their EP degree."""
        out = {"mp": self.mp, "dp": self.dp, "pp": self.pp,
               "wafers": self.wafers, "fabric": self.fabric,
               "inter_topology": self.inter_topology,
               "execution": self.execution}
        if self.ep > 1:
            out["ep"] = self.ep
        if self.sp > 1:
            out["sp"] = self.sp
        if self.objective != "time":
            out["objective"] = self.objective
        return out


def _pick_key(r: SweepResult):
    """The deterministic tiebreak chain shared by the time objective's
    Pareto pick and the goodput objective's equal-goodput tiebreak."""
    return (r.time_per_sample, r.memory_bytes_per_npu, r.n_wafers,
            TOPOLOGY_CODES.get(r.inter_topology, -1), len(r.hierarchy),
            r.fabric, r.hierarchy, r.shape,
            (r.strategy.mp, r.strategy.dp, r.strategy.pp,
             r.strategy.ep, r.strategy.sp))


def _pick(front: Sequence[SweepResult]) -> SweepResult:
    """Deterministic choice from the feasible Pareto front: fastest first,
    then smallest footprint, fewest wafers, the cheapest inter-wafer
    interconnect (ring < fully-connected < switch — at 2 wafers all
    three are time-equal, so the tiebreak buys the ring's 2 links over a
    switch or n² point-to-point wiring), then a total lexical tiebreak."""
    return min(front, key=_pick_key)


def _pick_by_goodput(workload_fn, feasible: Sequence[SweepResult],
                     n_npus: int, *, mem: MemoryModel, failure,
                     top_k: int, n_states: int, seed: int,
                     sweep_kw: Dict):
    """(chosen, LifetimeEstimate) with the highest lifetime goodput.

    Candidates come from the whole *feasible* set (ordered and truncated
    by the time objective's deterministic key), not just the time/memory
    Pareto front — a survivable strategy dominated on healthy time is
    exactly what this objective exists to find.  Fallback re-sweeps are
    shared across candidates via one per-mask cache.  Equal goodput
    falls back to the time objective's tiebreak, so at ``mtbf = ∞``
    (every fraction exactly 1.0) the choice is bit-identical to
    ``_pick``."""
    from .lifetime import evaluate_candidate
    ranked = sorted(feasible, key=_pick_key)[:top_k]
    cache: Dict = {}
    best = None
    for r in ranked:
        est = evaluate_candidate(
            workload_fn, r, n_npus, failure=failure, mem=mem,
            n_states=n_states, seed=seed, sweep_kw=sweep_kw,
            fallback_cache=cache)
        key = (-est.goodput_samples_per_s,) + _pick_key(r)
        if best is None or key < best[0]:
            best = (key, r, est)
    return best[1], best[2]


def _choose_training(cfg: "ModelConfig", shape: "ShapeConfig", *,
                    n_npus: int = 64,
                    fabrics: Sequence[str] = DEFAULT_FABRICS,
                    max_wafers: int = 2,
                    inter_topologies: Sequence[str] = INTER_TOPOLOGIES,
                    max_levels: int = 1,
                    npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES,
                    master: bool = True,
                    moments_dtype: str = "float32",
                    remat: str = "full",
                    min_utilization: float = 0.9,
                    prune_symmetric: bool = True,
                    ep_candidates: Sequence[int] = (1,),
                    sp_candidates: Sequence[int] = (1,),
                    comm_overlap_fraction: float = 0.0,
                    objective: str = "time",
                    mtbf_npu_hours: float = math.inf,
                    mtbf_wafer_hours: float = math.inf,
                    mission_hours: float = 720.0,
                    restart_s: float = 60.0,
                    goodput_top_k: int = 32,
                    n_failure_states: int = 3,
                    failure_seed: int = 0
                    ) -> AutoStrategyDecision:
    """Return the simulator-chosen, memory-feasible strategy for a cell.

    ``objective="goodput"`` ranks candidates by **lifetime goodput**
    (core/lifetime.py) instead of healthy-iteration time: the top
    ``goodput_top_k`` feasible candidates (by the time-objective order)
    are each pushed through the MTBF / checkpoint / elastic-degradation
    model at ``mtbf_npu_hours`` (and optionally ``mtbf_wafer_hours``)
    over a ``mission_hours`` run, so a slightly-slower strategy that
    keeps running after failures can beat a fragile healthy-time winner.
    At ``mtbf = ∞`` the goodput ranking is bit-identical to the time
    objective (nothing fails, the useful fraction is exactly 1.0).

    Weight-stationary execution is preferred (paper Sec. III-A);
    weight-streaming is tried only when no stationary candidate fits the
    HBM budget, which is how Transformer-1T-class models (arctic-480b)
    become feasible at wafer scale.  Raises :class:`InfeasibleModelError`
    if neither mode yields a feasible point.

    The inter-wafer topology is a first-class decision axis: every
    multi-wafer candidate is evaluated under each ``inter_topologies``
    entry (and, with ``max_levels=2``, each rack/pod stacking), and the
    winning topology/hierarchy is stamped on the decision — the CI
    golden gate diffs it alongside (mp, dp, pp, wafers).

    Serving cells (``shape.kind != "train"``) drop gradients/optimizer
    state and add the KV cache in the memory model; the simulated time is
    still the training-iteration model, so serving decisions rank
    strategies by the same communication structure, not absolute latency.
    """
    if objective not in ("time", "goodput"):
        raise ValueError(f"unknown objective {objective!r} — "
                         f"'time' or 'goodput'")
    training = shape.kind == "train"
    mem = MemoryModel(npu_hbm_bytes=npu_hbm_bytes, master=master,
                      moments_dtype=moments_dtype, remat=remat,
                      training=training)
    n_layers = adapter_n_layers(cfg)
    n_candidates = n_infeasible = 0
    sweep_kw = dict(fabrics=fabrics, n_layers=n_layers,
                    min_utilization=min_utilization,
                    max_wafers=max_wafers,
                    inter_topologies=inter_topologies,
                    max_levels=max_levels, memory=mem,
                    prune_symmetric=prune_symmetric,
                    ep_candidates=ep_candidates,
                    sp_candidates=sp_candidates,
                    comm_overlap_fraction=comm_overlap_fraction)
    t0 = time.perf_counter()  # repro: ignore[DETERMINISM] duration metric only
    for execution in ("stationary", "streaming"):
        def wl(st: Strategy, _e=execution):
            return from_model_config(cfg, shape, st, execution=_e)
        results = sweep(wl, n_npus, **sweep_kw)
        n_candidates += len(results)
        feasible = [r for r in results if r.feasible]
        n_infeasible += len(results) - len(feasible)
        if not feasible:
            continue
        front = [r for r in feasible if r.pareto]
        extra: Dict[str, object] = {}
        if objective == "goodput":
            from .lifetime import FailureModel
            failure = FailureModel(mtbf_npu_hours=mtbf_npu_hours,
                                   mtbf_wafer_hours=mtbf_wafer_hours,
                                   restart_s=restart_s,
                                   mission_hours=mission_hours)
            chosen, est = _pick_by_goodput(
                wl, feasible, n_npus, mem=mem, failure=failure,
                top_k=goodput_top_k, n_states=n_failure_states,
                seed=failure_seed, sweep_kw=sweep_kw)
            extra = dict(objective="goodput",
                         mtbf_npu_hours=mtbf_npu_hours,
                         goodput_samples_per_s=est.goodput_samples_per_s,
                         ckpt_write_s=est.ckpt_write_s,
                         ckpt_interval_s=est.interval_s,
                         useful_fraction=est.fractions["useful"],
                         survives_mission=est.survives_mission)
        else:
            chosen = _pick(front)
        return AutoStrategyDecision(
            arch=cfg.name, shape=shape.name, fabric=chosen.fabric,
            wafer_shape=chosen.shape, strategy=chosen.strategy,
            inter_topology=chosen.inter_topology,
            hierarchy=chosen.hierarchy,
            execution=execution, remat=remat, master=master,
            moments_dtype=moments_dtype,
            time_per_sample_s=chosen.time_per_sample,
            memory_bytes_per_npu=chosen.memory_bytes_per_npu,
            npu_hbm_bytes=npu_hbm_bytes,
            n_candidates=n_candidates, n_infeasible=n_infeasible,
            n_dominated=len(feasible) - len(front),
            sweep_seconds=time.perf_counter() - t0,  # repro: ignore[DETERMINISM] never feeds goldens
            **extra)
    raise InfeasibleModelError(
        f"{cfg.name}/{shape.name}: none of {n_candidates} candidates fits "
        f"{npu_hbm_bytes / 2**30:.1f} GiB/NPU at {n_npus} NPUs/wafer × "
        f"≤{max_wafers} wafers (try more NPUs, wafers, or a leaner "
        f"OptimConfig)")


# --------------------------------------------------------------------------
# unified entry point: choose(DeploymentRequest) + legacy shim
# --------------------------------------------------------------------------

Decision = Union[AutoStrategyDecision, ServingDecision]

# choose_strategy kwargs that belong to the Objective, not the request
_OBJECTIVE_KWARGS = ("objective", "mtbf_npu_hours", "mtbf_wafer_hours",
                     "mission_hours", "restart_s", "goodput_top_k",
                     "n_failure_states", "failure_seed")


def _build_request(cfg: "ModelConfig", shape: Optional["ShapeConfig"],
                   **kwargs) -> DeploymentRequest:
    """Fold a legacy ``choose_strategy(**kwargs)`` call form into a
    :class:`DeploymentRequest` — the objective-family kwargs move onto
    the :class:`Objective`, everything else maps one-for-one."""
    obj_kw = {k: kwargs.pop(k) for k in _OBJECTIVE_KWARGS if k in kwargs}
    kind = obj_kw.pop("objective", "time")
    objective = kind if isinstance(kind, Objective) else \
        Objective(kind=kind, **obj_kw)
    for f in ("fabrics", "inter_topologies", "ep_candidates",
              "sp_candidates"):
        if f in kwargs:
            kwargs[f] = tuple(kwargs[f])
    return DeploymentRequest(model=cfg, shape=shape, objective=objective,
                             **kwargs)


def choose(request: DeploymentRequest) -> Decision:
    """The one decision entry point — training and serving alike.

    ``request.objective.kind`` dispatches: ``time``/``goodput`` run the
    training sweep (an :class:`AutoStrategyDecision`); ``serving`` runs
    the serving-cell sweep of :mod:`repro.core.serving` (a
    :class:`~repro.core.serving.ServingDecision`, whose request profile
    and SLO live on the Objective — ``request.shape`` is ignored).
    """
    obj = request.objective
    if obj.kind == "serving":
        return decide_serving(
            request.model, obj, n_npus=request.n_npus,
            fabrics=request.fabrics, max_wafers=request.max_wafers,
            inter_topologies=request.inter_topologies,
            npu_hbm_bytes=request.npu_hbm_bytes,
            comm_overlap_fraction=request.comm_overlap_fraction)
    if request.shape is None:
        raise ValueError(
            f"objective {obj.kind!r} needs DeploymentRequest.shape "
            f"(a ShapeConfig — which cell to train)")
    return _choose_training(
        request.model, request.shape, n_npus=request.n_npus,
        fabrics=request.fabrics, max_wafers=request.max_wafers,
        inter_topologies=request.inter_topologies,
        max_levels=request.max_levels,
        npu_hbm_bytes=request.npu_hbm_bytes, master=request.master,
        moments_dtype=request.moments_dtype, remat=request.remat,
        min_utilization=request.min_utilization,
        prune_symmetric=request.prune_symmetric,
        ep_candidates=request.ep_candidates,
        sp_candidates=request.sp_candidates,
        comm_overlap_fraction=request.comm_overlap_fraction,
        objective=obj.kind, mtbf_npu_hours=obj.mtbf_npu_hours,
        mtbf_wafer_hours=obj.mtbf_wafer_hours,
        mission_hours=obj.mission_hours, restart_s=obj.restart_s,
        goodput_top_k=obj.goodput_top_k,
        n_failure_states=obj.n_failure_states,
        failure_seed=obj.failure_seed)


def choose_serving_strategy(cfg: "ModelConfig",
                            objective: Optional[Objective] = None, *,
                            n_npus: int = 64,
                            fabrics: Sequence[str] = DEFAULT_FABRICS,
                            max_wafers: int = 2,
                            inter_topologies: Sequence[str] =
                            INTER_TOPOLOGIES,
                            npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES,
                            comm_overlap_fraction: float = 0.0
                            ) -> ServingDecision:
    """Elect a serving-cell composition (the ROADMAP's millions-of-users
    item): sugar for :func:`choose` with a serving
    :class:`~repro.core.specs.Objective` (default: the pinned
    :data:`SERVE_OBJECTIVE` — 1M concurrent users, 200 ms p99)."""
    objective = SERVE_OBJECTIVE if objective is None else objective
    if objective.kind != "serving":
        raise ValueError(f"choose_serving_strategy needs a serving "
                         f"Objective, got kind={objective.kind!r}")
    return choose(DeploymentRequest(
        model=cfg, objective=objective, n_npus=n_npus,
        fabrics=tuple(fabrics), max_wafers=max_wafers,
        inter_topologies=tuple(inter_topologies),
        npu_hbm_bytes=npu_hbm_bytes,
        comm_overlap_fraction=comm_overlap_fraction))


def choose_strategy(cfg: "ModelConfig", shape: "ShapeConfig",
                    **kwargs) -> AutoStrategyDecision:
    """Deprecated legacy call form — build a :class:`DeploymentRequest`
    (+ :class:`Objective`) and call :func:`choose` instead.

    The shim resolves to a bit-identical decision (the kwargs map
    one-for-one onto the request, same defaults), so every pre-redesign
    golden stays byte-stable; it only adds a :class:`DeprecationWarning`.
    """
    warnings.warn(
        "choose_strategy(**kwargs) is deprecated — build a "
        "DeploymentRequest (+ Objective) in repro.core.specs and call "
        "choose(request)", DeprecationWarning, stacklevel=2)
    return choose(_build_request(cfg, shape, **kwargs))


# --------------------------------------------------------------------------
# decision table (benchmarks.run --only autostrategy / CI artifact)
# --------------------------------------------------------------------------

DECISION_CSV_HEADER = (
    "arch,shape,fabric,shape_a,shape_b,mp,dp,pp,ep,sp,wafers,hierarchy,"
    "inter_topology,execution,remat,"
    "master,moments_dtype,time_per_sample_s,memory_bytes_per_npu,"
    "npu_hbm_bytes,n_candidates,n_infeasible,n_dominated,sweep_s")


def decision_csv_rows(decisions: Sequence[AutoStrategyDecision]) -> List[str]:
    rows = []
    for d in decisions:
        rows.append(
            f"{d.arch},{d.shape},{d.fabric},"
            f"{d.wafer_shape[0]},{d.wafer_shape[1]},"
            f"{d.mp},{d.dp},{d.pp},{d.ep},{d.sp},{d.wafers},"
            f"{'x'.join(map(str, d.hierarchy))},{d.inter_topology},"
            f"{d.execution},{d.remat},"
            f"{int(d.master)},{d.moments_dtype},"
            f"{d.time_per_sample_s:.9g},{d.memory_bytes_per_npu:.9g},"
            f"{d.npu_hbm_bytes:.9g},{d.n_candidates},{d.n_infeasible},"
            f"{d.n_dominated},{d.sweep_seconds:.3f}")
    return rows


def decision_table(archs: Sequence[str], shape_name: str = "train_4k",
                   **kw) -> List[AutoStrategyDecision]:
    """Run :func:`choose` for each registry arch on one shape.

    The policy's frozen per-arch OptimConfig defaults feed the memory
    model (the same settings ``cell_policy`` would return), so the table
    is exactly what ``autostrategy=True`` decides.  ``**kw`` accepts the
    legacy kwarg vocabulary (it is folded into a
    :class:`DeploymentRequest` without the deprecation warning)."""
    from repro.configs.registry import get_config
    from repro.models.config import SHAPES_BY_NAME
    from repro.parallel.policy import paper_defaults
    shape = SHAPES_BY_NAME[shape_name]
    out = []
    for arch in archs:
        cfg = get_config(arch)
        pcfg, ocfg = paper_defaults(cfg, shape)
        out.append(choose(_build_request(
            cfg, shape, master=ocfg.master,
            moments_dtype=ocfg.moments_dtype, remat=pcfg.remat, **kw)))
    return out


def check_goldens(decisions: Sequence[AutoStrategyDecision],
                  golden_path: str) -> List[str]:
    """Diff chosen strategies against the checked-in goldens.

    Returns human-readable mismatch lines (empty = green).  The golden
    file maps ``"{arch}/{shape}"`` → :meth:`AutoStrategyDecision.golden`
    dicts; a cost-model change that silently flips any (mp, dp, pp,
    wafers, fabric, execution) fails the CI gate."""
    with open(golden_path) as fh:
        goldens = json.load(fh)
    errors = []
    seen = set()
    for d in decisions:
        key = f"{d.arch}/{d.shape}"
        seen.add(key)
        want = goldens.get(key)
        if want is None:
            errors.append(f"{key}: no golden entry (add it to "
                          f"{golden_path})")
            continue
        got = d.golden()
        if got != want:
            errors.append(f"{key}: chosen {got} != golden {want}")
    # a golden with no matching decision means the gate lost coverage
    # (model dropped/renamed in the bench list) — that must fail too
    for key in sorted(set(goldens) - seen):
        errors.append(f"{key}: golden has no matching decision (model "
                      f"removed from the bench list? delete the golden "
                      f"entry if intended)")
    return errors


# --------------------------------------------------------------------------
# lifetimesweep (time-vs-goodput decision pairs + golden gate)
# --------------------------------------------------------------------------

def lifetime_decision_pairs(
        archs: Sequence[str] = LIFETIME_ARCHS,
        shape_name: str = "train_4k",
        mtbf_npu_hours: float = LIFETIME_MTBF_NPU_HOURS,
        **kw) -> List[Tuple[AutoStrategyDecision, AutoStrategyDecision]]:
    """Per-arch ``(time, goodput)`` decision pairs at one MTBF.

    Both decisions see the identical sweep space (``LIFETIME_SWEEP_KW``
    unless overridden) — the only difference is the ranking objective,
    so a differing pair is a genuine MTBF-driven strategy flip."""
    merged = {**LIFETIME_SWEEP_KW, **kw}
    time_d = decision_table(archs, shape_name, objective="time", **merged)
    good_d = decision_table(archs, shape_name, objective="goodput",
                            mtbf_npu_hours=mtbf_npu_hours, **merged)
    return list(zip(time_d, good_d))


def _strategy_signature(d: AutoStrategyDecision) -> Dict[str, object]:
    """The decision fields a flip is judged on (objective key dropped —
    the two columns differ there by construction)."""
    sig = d.golden()
    sig.pop("objective", None)
    sig["wafer_shape"] = list(d.wafer_shape)
    return sig


def lifetime_golden(pair: Tuple[AutoStrategyDecision, AutoStrategyDecision]
                    ) -> Dict[str, object]:
    """One golden entry: both decisions, the flip verdict, and whether
    the goodput winner's degradation chain survives the mission."""
    t, g = pair
    ts, gs = _strategy_signature(t), _strategy_signature(g)
    return {"time": ts, "goodput": gs, "flip": ts != gs,
            "survives_mission": g.survives_mission}


def check_lifetime_goldens(
        pairs: Sequence[Tuple[AutoStrategyDecision, AutoStrategyDecision]],
        golden_path: str) -> List[str]:
    """Diff time/goodput decision pairs against the lifetimesweep golden.

    Same contract as :func:`check_goldens`: returns human-readable
    mismatch lines (empty = green) and flags orphaned golden entries, so
    a cost-model change that silently flips a goodput decision — or
    un-flips one the gate pins — fails CI."""
    with open(golden_path) as fh:
        goldens = json.load(fh)
    errors = []
    seen = set()
    for pair in pairs:
        t = pair[0]
        key = f"{t.arch}/{t.shape}"
        seen.add(key)
        want = goldens.get(key)
        if want is None:
            errors.append(f"{key}: no golden entry (add it to "
                          f"{golden_path})")
            continue
        got = lifetime_golden(pair)
        if got != want:
            errors.append(f"{key}: decided {got} != golden {want}")
    for key in sorted(set(goldens) - seen):
        errors.append(f"{key}: golden has no matching decision (model "
                      f"removed from the bench list? delete the golden "
                      f"entry if intended)")
    return errors


# --------------------------------------------------------------------------
# servesweep (serving-cell decisions + golden gate)
# --------------------------------------------------------------------------

# The servesweep CI gate: one small / one north-star dense model + one
# MoE, decided under the pinned production objective — 1M concurrent
# users on a 60 s think time (16.7k requests/s offered), 1024-token
# prompts, 256 generated tokens, 200 ms p99 TTFT.  qwen3-32b under this
# objective IS the ROADMAP's "how many wafers serve 1M concurrent users
# at a 200 ms p99" question; its total_wafers is pinned in the golden.
# Shared by benchmarks.run --only servesweep and
# tests/gen_servesweep_golden.py so the gate and its golden generator
# can never drift apart.
SERVESWEEP_ARCHS = ("llama3.2-1b", "qwen3-32b", "mixtral-8x7b")
SERVE_OBJECTIVE = Objective.serving(
    target_p99_ms=200.0, concurrent_users=1_000_000, think_time_s=60.0,
    prompt_tokens=1024, output_tokens=256)
SERVE_SWEEP_KW = dict(n_npus=64, max_wafers=2)


def serving_decision_table(archs: Sequence[str] = SERVESWEEP_ARCHS,
                           objective: Optional[Objective] = None,
                           **kw) -> List[ServingDecision]:
    """Run :func:`choose` with a serving objective for each arch."""
    from repro.configs.registry import get_config
    objective = SERVE_OBJECTIVE if objective is None else objective
    merged = {**SERVE_SWEEP_KW, **kw}
    return [choose(DeploymentRequest(model=get_config(arch),
                                     objective=objective, **merged))
            for arch in archs]


def check_serving_goldens(decisions: Sequence[ServingDecision],
                          golden_path: str) -> List[str]:
    """Diff serving-cell decisions against the servesweep golden.

    Same contract as :func:`check_goldens`: human-readable mismatch
    lines (empty = green) plus orphan detection, keyed by arch — a cost-
    model change that silently moves the pinned wafer count (or flips a
    placement/fabric election) fails the CI gate."""
    with open(golden_path) as fh:
        goldens = json.load(fh)
    errors = []
    seen = set()
    for d in decisions:
        seen.add(d.arch)
        want = goldens.get(d.arch)
        if want is None:
            errors.append(f"{d.arch}: no golden entry (add it to "
                          f"{golden_path})")
            continue
        got = d.golden()
        if got != want:
            errors.append(f"{d.arch}: decided {got} != golden {want}")
    for key in sorted(set(goldens) - seen):
        errors.append(f"{key}: golden has no matching decision (model "
                      f"removed from the bench list? delete the golden "
                      f"entry if intended)")
    return errors
