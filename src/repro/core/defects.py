"""Defect masks: dead NPUs / dead links on a wafer, with seedable samplers.

Wafer-scale parts ship with manufacturing defects (the yield argument of
Hecaton / Wafer-on-Wafer hybrid bonding); a :class:`DefectMask` is the
repo-wide description of one defect draw.  The mask lives in the *NPU id
space* of a single wafer and every fabric interprets the parts of it that
exist on that fabric:

  * ``dead_npus`` — NPU ids that are unusable.  All fabrics honour these;
    placement compacts logical workers onto the healthy ids
    (``core/placement.py``) and a dead NPU's router is considered dead too,
    so its mesh links carry no traffic.
  * ``dead_links`` — undirected ``(a, b)`` NPU-id pairs.  Only meaningful on
    the 2D mesh, and only for pairs that are actual mesh edges under the
    fabric's (rows, cols) shape; non-edges are ignored (a mask sampled for
    one shape stays usable across a shape sweep).
  * ``dead_uplinks`` — ``(l1_index, n_dead)`` pairs: severed L1→L2 uplinks
    on a FRED fabric.  An NPU's single link to its L1 switch is identified
    with the NPU itself (a dead NPU-link *is* a dead NPU).

Masks are frozen and fully hashable, so they slot directly into the
placement / collective-structure memo keys.  An *empty* mask (no defects)
is normalized away at the Simulator boundary so the zero-defect code path
is literally the pre-defect code path — bit-identical by construction.
"""

from __future__ import annotations

import dataclasses
import json
import random
from typing import Dict, Optional, Sequence, Tuple

Link = Tuple[int, int]


def _norm_link(a: int, b: int) -> Link:
    return (a, b) if a <= b else (b, a)


@dataclasses.dataclass(frozen=True)
class DefectMask:
    """One defect draw over a wafer of ``n_npus`` NPUs."""

    n_npus: int
    dead_npus: Tuple[int, ...] = ()
    dead_links: Tuple[Link, ...] = ()
    dead_uplinks: Tuple[Tuple[int, int], ...] = ()
    seed: int = -1                      # sampler seed; -1 for hand-built masks

    def __post_init__(self):
        dead = tuple(sorted(set(self.dead_npus)))
        links = tuple(sorted({_norm_link(a, b) for a, b in self.dead_links}))
        ups = tuple(sorted(dict(self.dead_uplinks).items()))
        object.__setattr__(self, "dead_npus", dead)
        object.__setattr__(self, "dead_links", links)
        object.__setattr__(self, "dead_uplinks", ups)
        if dead and not (0 <= dead[0] and dead[-1] < self.n_npus):
            raise ValueError(f"dead NPU id out of range 0..{self.n_npus - 1}")
        if len(dead) >= self.n_npus:
            raise ValueError("mask kills every NPU on the wafer")

    # ---- queries ----------------------------------------------------------
    @property
    def is_empty(self) -> bool:
        return not (self.dead_npus or self.dead_links or self.dead_uplinks)

    @property
    def n_healthy(self) -> int:
        return self.n_npus - len(self.dead_npus)

    @property
    def dead_npu_rate(self) -> float:
        return len(self.dead_npus) / self.n_npus

    def healthy(self) -> Tuple[int, ...]:
        """Sorted healthy NPU ids — the compaction target of placement."""
        dead = set(self.dead_npus)
        return tuple(i for i in range(self.n_npus) if i not in dead)

    def npu_dead(self, nid: int) -> bool:
        return nid in set(self.dead_npus)

    def link_dead(self, a: int, b: int) -> bool:
        """True if the (a, b) link is dead — explicitly, or because either
        endpoint's router died with its NPU."""
        dead = set(self.dead_npus)
        return (a in dead or b in dead
                or _norm_link(a, b) in set(self.dead_links))

    def dead_uplinks_of(self, l1: int) -> int:
        return dict(self.dead_uplinks).get(l1, 0)

    # ---- serialization ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "n_npus": self.n_npus,
            "dead_npus": list(self.dead_npus),
            "dead_links": [list(l) for l in self.dead_links],
            "dead_uplinks": [list(u) for u in self.dead_uplinks],
            "seed": self.seed,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "DefectMask":
        d = json.loads(text)
        return cls(n_npus=d["n_npus"],
                   dead_npus=tuple(d.get("dead_npus", ())),
                   dead_links=tuple((a, b) for a, b in d.get("dead_links", ())),
                   dead_uplinks=tuple((l1, n) for l1, n
                                      in d.get("dead_uplinks", ())),
                   seed=d.get("seed", -1))


def mesh_links(rows: int, cols: int) -> Tuple[Link, ...]:
    """All undirected links of a rows×cols 2D mesh (id = r*cols + c)."""
    out = []
    for r in range(rows):
        for c in range(cols):
            nid = r * cols + c
            if c + 1 < cols:
                out.append((nid, nid + 1))
            if r + 1 < rows:
                out.append((nid, nid + cols))
    return tuple(out)


def _demote_unreachable(n_npus: int, dead_npus: Sequence[int],
                        dead_links: Sequence[Link],
                        mesh_shape: Tuple[int, int]) -> Sequence[int]:
    """Healthy NPUs cut off from the largest healthy region of the mesh
    are useless — no traffic can reach them — so the sampler counts them
    as dead.  Keeps the largest connected component (ties broken by the
    lowest member id), guaranteeing defect routing never fails between
    two healthy NPUs of a sampled mask."""
    dead = set(dead_npus)
    deadl = {_norm_link(a, b) for a, b in dead_links}
    adj: Dict[int, list] = {i: [] for i in range(n_npus) if i not in dead}
    for a, b in mesh_links(*mesh_shape):
        if a in dead or b in dead or (a, b) in deadl:
            continue
        adj[a].append(b)
        adj[b].append(a)
    seen: set = set()
    comps = []
    for start in sorted(adj):
        if start in seen:
            continue
        comp = [start]
        seen.add(start)
        q = [start]
        while q:
            nid = q.pop()
            for nb in adj[nid]:
                if nb not in seen:
                    seen.add(nb)
                    comp.append(nb)
                    q.append(nb)
        comps.append(comp)
    if len(comps) <= 1:
        return sorted(dead)
    comps.sort(key=lambda c: (-len(c), min(c)))
    keep = set(comps[0])
    return sorted(dead | {i for i in adj if i not in keep})


def sample_mask(n_npus: int, *, dead_npu_rate: float = 0.0,
                dead_link_rate: float = 0.0, dead_uplink_rate: float = 0.0,
                seed: int = 0, mesh_shape: Optional[Tuple[int, int]] = None,
                n_groups: int = 0, uplinks_per_l1: int = 0) -> DefectMask:
    """Draw a mask: each element fails independently at its rate.

    Deterministic in ``seed`` (``random.Random``, no global state).  Link
    kills need ``mesh_shape`` to enumerate the edge set; uplink kills need
    ``n_groups`` × ``uplinks_per_l1``.  At least one NPU always survives,
    and with ``mesh_shape`` the surviving NPUs form one connected mesh
    region (NPUs stranded by the draw are demoted to dead — an
    unreachable NPU can do no work).
    """
    rng = random.Random(seed)
    dead_npus = [i for i in range(n_npus) if rng.random() < dead_npu_rate]
    if len(dead_npus) >= n_npus:
        dead_npus = dead_npus[:-1]
    dead_links: Sequence[Link] = ()
    if mesh_shape is not None and dead_link_rate > 0.0:
        dead_links = [l for l in mesh_links(*mesh_shape)
                      if rng.random() < dead_link_rate]
    if mesh_shape is not None and (dead_npus or dead_links) \
            and mesh_shape[0] * mesh_shape[1] == n_npus:
        dead_npus = list(_demote_unreachable(n_npus, dead_npus, dead_links,
                                             mesh_shape))
        if len(dead_npus) >= n_npus:
            raise ValueError(
                f"defect draw (seed={seed}) disconnects every NPU")
    dead_uplinks: Dict[int, int] = {}
    if n_groups and uplinks_per_l1 and dead_uplink_rate > 0.0:
        for l1 in range(n_groups):
            n_dead = sum(1 for _ in range(uplinks_per_l1)
                         if rng.random() < dead_uplink_rate)
            # keep at least one uplink alive — a fully severed L1 is a
            # dead group, which the cost model treats as unplaceable anyway
            n_dead = min(n_dead, uplinks_per_l1 - 1)
            if n_dead:
                dead_uplinks[l1] = n_dead
    return DefectMask(n_npus=n_npus, dead_npus=tuple(dead_npus),
                      dead_links=tuple(dead_links),
                      dead_uplinks=tuple(dead_uplinks.items()), seed=seed)


def mesh_connected(mask: DefectMask, rows: int, cols: int) -> bool:
    """True iff the mask's healthy NPUs form one connected region on a
    rows×cols mesh.  A mask is sampled in flat id space, so the same
    draw can leave one mesh shape connected and cut another in two —
    shape sweeps skip the disconnected shapes (no collective can run
    across a severed wafer)."""
    demoted = _demote_unreachable(rows * cols, mask.dead_npus,
                                  mask.dead_links, (rows, cols))
    return len(demoted) == len(mask.dead_npus)


def normalize(mask: Optional[DefectMask]) -> Optional[DefectMask]:
    """Empty masks → None, so all-healthy draws share the no-mask path."""
    return None if mask is None or mask.is_empty else mask


# ---- per-wafer mask lists (WaferCluster.wafer_defects) --------------------

def masks_to_json(masks: Sequence[Optional[DefectMask]]) -> str:
    """JSON for a per-wafer mask list — one entry per wafer, ``null`` for
    a pristine wafer (the on-disk form of
    ``ClusterSpec.wafer_defects``)."""
    return json.dumps([None if m is None else json.loads(m.to_json())
                       for m in masks], sort_keys=True)


def masks_from_json(text: str) -> Tuple[Optional[DefectMask], ...]:
    """Inverse of :func:`masks_to_json`; entries are normalized (an empty
    mask loads as None)."""
    return tuple(None if e is None
                 else normalize(DefectMask.from_json(json.dumps(e)))
                 for e in json.loads(text))
