"""Paper workloads (Table V) characterized at layer level.

All four workloads are modeled from first principles (params, FLOPs,
activation sizes) with the paper's settings: FP16 everywhere, minibatch =
DP_size × 16 samples, Megatron-style MP sync (2 All-Reduces per layer per
pass), GPipe microbatching for PP, weight-stationary vs weight-streaming
execution (Sec. III-A).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .placement import Strategy

BYTES = 2  # FP16


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    n_layers: int
    params_per_layer: float        # bytes are params × BYTES
    flops_fwd_per_sample_layer: float
    act_bytes_per_sample: float    # boundary activation per sample
    strategy: Strategy
    execution: str                 # "stationary" | "streaming"
    mp_allreduce_per_layer: int = 2   # Megatron fwd (and again in bwd)
    samples_per_dp: int = 16
    seq: int = 1

    @property
    def params_total(self) -> float:
        return self.params_per_layer * self.n_layers

    @property
    def param_bytes_total(self) -> float:
        return self.params_total * BYTES

    @property
    def minibatch(self) -> int:
        return self.strategy.dp * self.samples_per_dp


def transformer(name: str, n_layers: int, d_model: int, seq: int,
                strategy: Strategy, execution: str,
                samples_per_dp: int = 16,
                token_samples: bool = True) -> Workload:
    """LM workload.  The paper sets minibatch = DP_size×16 *samples* but
    does not define a transformer sample.  Two readings:

    * ``token_samples=True``  — a sample is one token.  This is the only
      reading under which all four Fig. 10 speedups are jointly
      reachable (compute small ⇒ latency-bound mesh collectives and
      critical-path weight streaming; see EXPERIMENTS.md §Fig10).
      Used for the calibrated headline reproduction.
    * ``token_samples=False`` — a sample is a seq-length sequence.  This
      is the reading under which Fig. 2's 'MP(20) communication dominates'
      sweep holds (activation all-reduces are then param-scale).  Used by
      benchmarks/fig2_strategies.py.

    Both are reported; the ambiguity is documented, not hidden."""
    params_layer = 12 * d_model * d_model          # qkvo + 4d ff
    if token_samples:
        flops_fwd = 2 * params_layer               # per token
        act = d_model * BYTES
    else:
        flops_fwd = 2 * params_layer * seq + 4 * seq * seq * d_model
        act = seq * d_model * BYTES
    return Workload(name=name, n_layers=n_layers,
                    params_per_layer=params_layer,
                    flops_fwd_per_sample_layer=flops_fwd,
                    act_bytes_per_sample=act,
                    strategy=strategy, execution=execution,
                    samples_per_dp=samples_per_dp, seq=seq)


def resnet152(strategy: Strategy) -> Workload:
    total_params = 60.2e6
    total_fwd_flops = 11.5e9          # @224² per sample
    n_layers = 152
    return Workload(name="ResNet-152", n_layers=n_layers,
                    params_per_layer=total_params / n_layers,
                    flops_fwd_per_sample_layer=total_fwd_flops / n_layers,
                    act_bytes_per_sample=7 * 7 * 2048 * BYTES,
                    strategy=strategy, execution="stationary",
                    mp_allreduce_per_layer=0)


def paper_workloads() -> List[Workload]:
    """Table V exactly."""
    return [
        resnet152(Strategy(1, 20, 1)),
        # Turing-NLG 17B: 78 layers, d=4256, seq 1024
        transformer("Transformer-17B", 78, 4256, 1024,
                    Strategy(3, 3, 2), "stationary"),
        # GPT-3 175B: 96 layers, d=12288, seq 2048
        transformer("GPT-3", 96, 12288, 2048,
                    Strategy(2, 5, 2), "streaming"),
        # Transformer-1T: 128 layers, d=25600, seq 2048
        transformer("Transformer-1T", 128, 25600, 2048,
                    Strategy(1, 20, 1), "streaming"),
    ]


def fig2_strategies() -> List[Strategy]:
    """The Transformer-17B parallelization sweep of Fig. 2."""
    return [
        Strategy(20, 1, 1),
        Strategy(10, 2, 1),
        Strategy(5, 4, 1),
        Strategy(4, 5, 1),
        Strategy(2, 10, 1),
        Strategy(1, 20, 1),
        Strategy(5, 2, 2),
        Strategy(2, 5, 2),
        Strategy(10, 1, 2),
        Strategy(4, 1, 5),
    ]
