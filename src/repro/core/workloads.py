"""Paper workloads (Table V) characterized at layer level.

All four workloads are modeled from first principles (params, FLOPs,
activation sizes) with the paper's settings: FP16 everywhere, minibatch =
DP_size × 16 samples, Megatron-style MP sync (2 All-Reduces per layer per
pass), GPipe microbatching for PP, weight-stationary vs weight-streaming
execution (Sec. III-A).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple, TYPE_CHECKING

from .placement import Strategy

if TYPE_CHECKING:                      # core stays jax-free at runtime
    from repro.models.config import ModelConfig, ShapeConfig

BYTES = 2  # FP16


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    n_layers: int
    params_per_layer: float        # bytes are params × BYTES
    flops_fwd_per_sample_layer: float
    act_bytes_per_sample: float    # boundary activation per sample
    strategy: Strategy
    execution: str                 # "stationary" | "streaming"
    mp_allreduce_per_layer: int = 2   # Megatron fwd (and again in bwd)
    samples_per_dp: int = 16
    seq: int = 1
    # serving-only KV-cache footprint (2·d_kv·BYTES for attention models,
    # 0 for training workloads where the cache is part of the activations)
    kv_bytes_per_sample_layer: float = 0.0
    # fraction of params_per_layer actually multiplied per sample (MoE
    # top-k routing; 1.0 for dense).  flops_fwd_per_sample_layer already
    # accounts for it — this field only documents the ratio.
    active_param_fraction: float = 1.0
    # expert-dispatch All-to-All payload per sample per MoE layer
    # (top_k · d_model · BYTES: each token ships top-k d-vectors to its
    # experts); 0 for dense models — gates the simulator's EP phase.
    a2a_bytes_per_sample_layer: float = 0.0
    # fraction of params_per_layer that are expert FFN weights — the part
    # expert parallelism shards over Strategy.ep (0 for dense models)
    expert_param_fraction: float = 0.0

    @property
    def params_total(self) -> float:
        return self.params_per_layer * self.n_layers

    @property
    def param_bytes_total(self) -> float:
        return self.params_total * BYTES

    @property
    def minibatch(self) -> int:
        return self.strategy.dp * self.samples_per_dp


def transformer(name: str, n_layers: int, d_model: int, seq: int,
                strategy: Strategy, execution: str,
                samples_per_dp: int = 16,
                token_samples: bool = True) -> Workload:
    """LM workload.  The paper sets minibatch = DP_size×16 *samples* but
    does not define a transformer sample.  Two readings:

    * ``token_samples=True``  — a sample is one token.  This is the only
      reading under which all four Fig. 10 speedups are jointly
      reachable (compute small ⇒ latency-bound mesh collectives and
      critical-path weight streaming; see EXPERIMENTS.md §Fig10).
      Used for the calibrated headline reproduction.
    * ``token_samples=False`` — a sample is a seq-length sequence.  This
      is the reading under which Fig. 2's 'MP(20) communication dominates'
      sweep holds (activation all-reduces are then param-scale).  Used by
      benchmarks/fig2_strategies.py.

    Both are reported; the ambiguity is documented, not hidden."""
    params_layer = 12 * d_model * d_model          # qkvo + 4d ff
    if token_samples:
        flops_fwd = 2 * params_layer               # per token
        act = d_model * BYTES
    else:
        flops_fwd = 2 * params_layer * seq + 4 * seq * seq * d_model
        act = seq * d_model * BYTES
    return Workload(name=name, n_layers=n_layers,
                    params_per_layer=params_layer,
                    flops_fwd_per_sample_layer=flops_fwd,
                    act_bytes_per_sample=act,
                    strategy=strategy, execution=execution,
                    samples_per_dp=samples_per_dp, seq=seq)


def resnet152(strategy: Strategy) -> Workload:
    total_params = 60.2e6
    total_fwd_flops = 11.5e9          # @224² per sample
    n_layers = 152
    return Workload(name="ResNet-152", n_layers=n_layers,
                    params_per_layer=total_params / n_layers,
                    flops_fwd_per_sample_layer=total_fwd_flops / n_layers,
                    act_bytes_per_sample=7 * 7 * 2048 * BYTES,
                    strategy=strategy, execution="stationary",
                    mp_allreduce_per_layer=0)


def paper_workloads() -> List[Workload]:
    """Table V exactly."""
    return [
        resnet152(Strategy(1, 20, 1)),
        # Turing-NLG 17B: 78 layers, d=4256, seq 1024
        transformer("Transformer-17B", 78, 4256, 1024,
                    Strategy(3, 3, 2), "stationary"),
        # GPT-3 175B: 96 layers, d=12288, seq 2048
        transformer("GPT-3", 96, 12288, 2048,
                    Strategy(2, 5, 2), "streaming"),
        # Transformer-1T: 128 layers, d=25600, seq 2048
        transformer("Transformer-1T", 128, 25600, 2048,
                    Strategy(1, 20, 1), "streaming"),
    ]


# --------------------------------------------------------------------------
# per-NPU memory-feasibility model (ISSUE 3: richer sweep objectives)
# --------------------------------------------------------------------------

# Production-chip assumption used across the JAX substrate (launch/perf.py
# hillclimb notes, the arctic-480b optimizer-mode comment in
# parallel/policy.py): 16 GiB of HBM per NPU/chip.
DEFAULT_NPU_HBM_BYTES = 16 * 2**30

# Activation multiplier vs the layer-boundary tensor, per remat setting.
# First-order: "full" keeps one boundary tensor per layer for backward;
# "block" additionally saves the projection outputs (~4× boundary);
# "none" keeps every intermediate (qkv + scores + ffn hidden ≈ 12×
# boundary for a 4×-FFN transformer).
ACT_REMAT_MULT = {"full": 1.0, "block": 4.0, "none": 12.0}


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Per-NPU memory settings the feasibility predicate evaluates under.

    ``master`` / ``moments_dtype`` mirror ``repro.train.optim.OptimConfig``
    (fp32 master copy; fp32/bf16/int8 Adam moments); ``remat`` mirrors
    ``ParallelConfig.remat``.  ``training=False`` drops gradients and
    optimizer state and adds the KV cache instead (serving cells).
    """
    npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES
    master: bool = True
    moments_dtype: str = "float32"   # float32 | bfloat16 | int8
    remat: str = "full"              # none | block | full
    training: bool = True


def optimizer_bytes_per_param(master: bool, moments_dtype: str) -> float:
    """Optimizer-state bytes per parameter (excl. the param + grad).

    fp32 master (optional, 4 B) + two Adam moments at ``moments_dtype``
    (int8 carries a per-row fp32 scale — amortized below 1.1 B/param for
    any row ≥ 16 wide, folded into the 1-byte figure)."""
    moment = {"float32": 4.0, "bfloat16": 2.0, "int8": 1.0}[moments_dtype]
    return (4.0 if master else 0.0) + 2 * moment


def memory_bytes_per_npu(w: Workload, mem: MemoryModel) -> float:
    """Peak per-NPU bytes for ``w`` under its own strategy and ``mem``.

    Sharding model (matches the simulator's placement): MP shards within a
    layer, PP shards layers (largest stage = ceil(n_layers/pp) paces the
    pipeline *and* holds the most state), DP replicates.  Sequence
    parallelism (``Strategy.sp``) shards activations a further ``sp``-way;
    expert parallelism (``Strategy.ep``) shards the expert share of the
    params over the EP group.  Weight-streaming
    keeps only a double-buffered layer (+ a gradient buffer when
    training) resident — the optimizer runs near storage (Sec. III-A).

    Monotone by construction: nondecreasing in params_per_layer,
    n_layers, act_bytes_per_sample and seq at a fixed strategy — the
    property the hypothesis tests in tests/test_autostrategy.py pin.
    """
    st = w.strategy
    layers_per_stage = -(-w.n_layers // st.pp)
    # expert weights shard over the EP group: the (1−f) dense share stays
    # replicated per MP shard, the f expert share divides by ep
    ep_share = 1.0
    if st.ep > 1 and w.expert_param_fraction:
        f = w.expert_param_fraction
        ep_share = (1.0 - f) + f / st.ep
    if w.execution == "streaming":
        buffers = 3 if mem.training else 2      # 2 stream + 1 grad out
        resident_params = buffers * w.params_per_layer * ep_share / st.mp
        opt_bytes = 0.0                          # optimizer near storage
        grad_bytes = 0.0                         # counted in the buffers
    else:
        resident_params = (w.params_per_layer * ep_share *
                           layers_per_stage / st.mp)
        opt_bytes = (resident_params *
                     optimizer_bytes_per_param(mem.master, mem.moments_dtype)
                     if mem.training else 0.0)
        grad_bytes = resident_params * BYTES if mem.training else 0.0
    weight_bytes = resident_params * BYTES

    # activation working set: one microbatch of `seq` samples per replica
    # (gradient accumulation bounds it regardless of samples_per_dp),
    # boundary tensor per layer of the stage, remat-scaled, SP-sharded
    mult = ACT_REMAT_MULT[mem.remat] if mem.training else 1.0
    act_layers = layers_per_stage if mem.training else 1
    act_bytes = (mult * act_layers * w.act_bytes_per_sample *
                 max(w.seq, 1) / st.mp / st.sp)

    kv_bytes = 0.0
    if not mem.training and w.kv_bytes_per_sample_layer:
        # full cache: every past sample of the replica's batch, all layers
        kv_bytes = (w.kv_bytes_per_sample_layer * w.samples_per_dp *
                    layers_per_stage / st.mp)
    return weight_bytes + grad_bytes + opt_bytes + act_bytes + kv_bytes


def is_feasible(w: Workload, mem: MemoryModel) -> bool:
    """The memory-feasibility predicate: fits the per-NPU HBM budget.

    Monotone in the budget (more HBM never removes a feasible strategy)
    and antitone in model size (a larger model never adds one)."""
    return memory_bytes_per_npu(w, mem) <= mem.npu_hbm_bytes


# --------------------------------------------------------------------------
# ModelConfig → Workload adapter (ISSUE 3: sweep-driven auto-strategy)
# --------------------------------------------------------------------------

def _layer_param_counts(cfg: "ModelConfig") -> Tuple[float, float]:
    """(resident, active) params per layer for a registry architecture.

    First-order per-family accounting; embeddings/LM head are spread
    across layers so ``params_total`` covers the whole model.  MoE keeps
    every expert resident but multiplies only top-k per sample.
    """
    d = cfg.d_model
    attn = (d * cfg.d_qkv + 2 * d * cfg.d_kv + cfg.d_qkv * d
            if cfg.n_heads else 0.0)
    ffn_gated = 3 * d * cfg.d_ff                 # SwiGLU (llama/qwen style)
    if cfg.family == "moe":
        router = d * cfg.n_experts
        experts = cfg.n_experts * ffn_gated
        dense_branch = 3 * d * cfg.moe_dense_ff if cfg.moe_dense_ff else 0.0
        resident = attn + router + experts + dense_branch
        active = attn + router + cfg.top_k * ffn_gated + dense_branch
    elif cfg.family == "ssm":
        resident = active = _ssm_block_params(cfg)
    elif cfg.family == "hybrid":
        # Mamba2 stack + ONE shared attention block (zamba2), amortized
        shared = attn + ffn_gated if cfg.d_ff else attn
        resident = active = (_ssm_block_params(cfg) +
                             shared / max(cfg.num_layers, 1))
    elif cfg.family == "audio":
        # encoder: self-attn + 2-matrix GELU MLP; decoder adds cross-attn.
        # Averaged over (enc + dec) layers — Workload.n_layers is the sum.
        mlp = 2 * d * cfg.d_ff
        enc = cfg.n_enc_layers * (attn + mlp)
        dec = cfg.num_layers * (2 * attn + mlp)
        resident = active = (enc + dec) / max(cfg.num_layers +
                                              cfg.n_enc_layers, 1)
    else:                                        # dense | vlm
        resident = active = attn + ffn_gated
    n_layers = adapter_n_layers(cfg)
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    return resident + emb / n_layers, active + emb / n_layers


def _ssm_block_params(cfg: "ModelConfig") -> float:
    """Mamba2/SSD block: in-proj (x, z, B, C, dt heads), depthwise conv,
    out-proj, per-head A/D/dt-bias (first-order)."""
    d, di = cfg.d_model, cfg.d_inner
    bc = 2 * cfg.ssm_groups * cfg.ssm_state
    in_proj = d * (2 * di + bc + cfg.ssm_heads)
    conv = cfg.ssm_conv * (di + bc)
    out_proj = di * d
    return in_proj + conv + out_proj + 3 * cfg.ssm_heads


def adapter_n_layers(cfg: "ModelConfig") -> int:
    """Layer count as the Workload sees it (audio: enc + dec)."""
    return max(cfg.num_layers + cfg.n_enc_layers, 1)


def from_model_config(cfg: "ModelConfig", shape: "ShapeConfig",
                      strategy: Strategy,
                      execution: str = "stationary") -> Workload:
    """Derive the analytical Workload for a registry (arch × shape) cell.

    A sample is one token (the calibrated Fig. 10 reading); a microbatch
    is one ``seq_len``-token sequence.  ``samples_per_dp`` carries the
    cell's *whole* per-replica token budget (global_batch · seq_len / dp)
    so ``minibatch`` ≈ the fixed global token count and ``time_per_sample``
    compares strategies at equal work.  MP all-reduces follow Megatron
    (2/layer each pass) for families with intra-layer sharded matmuls —
    which is every family here; SSM scans sync B/C projections the same
    way, so the count is kept uniform.
    """
    resident, active = _layer_param_counts(cfg)
    n_layers = adapter_n_layers(cfg)
    d = cfg.d_model
    # per-token forward FLOPs: 2·active params + causal attention
    # quadratic term (averaged position ⇒ seq/2 keys, 2 matmuls ⇒ 2·seq)
    seq_eff = shape.seq_len
    if cfg.sliding_window:
        seq_eff = min(seq_eff, cfg.sliding_window)
    quad = 2 * seq_eff * cfg.d_qkv if cfg.n_heads else 0.0
    if cfg.family == "hybrid":
        quad = quad / max(cfg.attn_every, 1)     # shared block cadence
    flops_fwd = 2 * active + quad
    total_samples = shape.global_batch * shape.seq_len
    samples_per_dp = max(1, total_samples // strategy.dp)
    serving = shape.kind != "train"
    kv = 2 * cfg.d_kv * BYTES if (serving and cfg.n_heads) else 0.0
    moe = cfg.family == "moe"
    # each token ships top-k d-vectors to its experts (dispatch; combine
    # is charged separately by the simulator's ×2)
    a2a = cfg.top_k * d * BYTES if moe else 0.0
    expert_frac = ((cfg.n_experts * 3 * d * cfg.d_ff) / resident
                   if moe and resident else 0.0)
    return Workload(
        name=f"{cfg.name}:{shape.name}",
        n_layers=n_layers,
        params_per_layer=resident,
        flops_fwd_per_sample_layer=flops_fwd,
        act_bytes_per_sample=d * BYTES,
        strategy=strategy,
        execution=execution,
        mp_allreduce_per_layer=2,
        samples_per_dp=samples_per_dp,
        seq=shape.seq_len,
        kv_bytes_per_sample_layer=kv,
        active_param_fraction=active / resident if resident else 1.0,
        a2a_bytes_per_sample_layer=a2a,
        expert_param_fraction=expert_frac,
    )


def fig2_strategies() -> List[Strategy]:
    """The Transformer-17B parallelization sweep of Fig. 2."""
    return [
        Strategy(20, 1, 1),
        Strategy(10, 2, 1),
        Strategy(5, 4, 1),
        Strategy(4, 5, 1),
        Strategy(2, 10, 1),
        Strategy(1, 20, 1),
        Strategy(5, 2, 2),
        Strategy(2, 5, 2),
        Strategy(10, 1, 2),
        Strategy(4, 1, 5),
    ]
