"""FRED wafer-scale fabric: 2-level almost-fat-tree of FRED switches
(paper Sec. VI, Fig. 8) and the four evaluation configs of Table IV.

Topology is parameterized: ``n_groups`` L1 groups of ``group_size`` NPUs,
plus ``n_io`` I/O controllers spread across L1 switches; L2 spine connects
L1s.  The paper's wafer is the default shape (5 groups of 4, 18 I/O).
Almost-fat-tree: L1→L2 BW sums the *NPU* bandwidth only (I/O flows are
bottlenecked by the 128 GB/s controllers anyway).

Effective-bandwidth model: for a collective over ``group`` with in-network
execution the per-NPU injection traffic is D (vs 2(n−1)/n·D endpoint); the
sustained rate is the bottleneck of NPU→L1 BW and the per-flow share of
L1→L2 BW — reproducing the paper's Sec. VIII microbenchmark numbers
(1875 GB/s FRED-A, 3 TB/s FRED-C/D wafer-wide, 375 GB/s FRED-A DP, ...).

HW accounting (Table III) is likewise derived from the shape: every L1
switch is a FRED_3 with ``group_size`` NPU ports + its share of the I/O
ports + uplink ports; the L2 spine switch aggregates the uplinks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .defects import DefectMask, normalize
from .flows import endpoint_traffic_bytes, innetwork_traffic_bytes


@dataclasses.dataclass
class FredConfig:
    name: str
    npu_l1_bw: float            # per-NPU link to its L1 switch (B/s, one dir)
    l1_l2_bw: float             # per-L1-switch uplink to the L2 spine
    in_network: bool
    io_bw: float = 128e9
    switch_latency: float = 20e-9     # repro: unit[s]
    step_overhead: float = 4e-7       # repro: unit[s] per flow-step (single fabric
                                      # traversal; no multi-hop protocol)


# Table IV configurations
FRED_A = FredConfig("FRED-A", npu_l1_bw=3e12, l1_l2_bw=1.5e12, in_network=False)
FRED_B = FredConfig("FRED-B", npu_l1_bw=3e12, l1_l2_bw=1.5e12, in_network=True)
FRED_C = FredConfig("FRED-C", npu_l1_bw=3e12, l1_l2_bw=12e12, in_network=False)
FRED_D = FredConfig("FRED-D", npu_l1_bw=3e12, l1_l2_bw=12e12, in_network=True)

CONFIGS = {c.name: c for c in (FRED_A, FRED_B, FRED_C, FRED_D)}


@dataclasses.dataclass
class FredFabric:
    config: FredConfig
    n_groups: int = 5                 # L1 switches
    group_size: int = 4               # NPUs per L1 switch
    n_io: int = 18                    # I/O controllers, spread across L1s
    defects: Optional[DefectMask] = None

    def __post_init__(self):
        if self.n_groups < 1 or self.group_size < 1:
            raise ValueError(f"fabric needs positive shape, got "
                             f"{self.n_groups} groups of {self.group_size}")
        self.defects = normalize(self.defects)

    @property
    def n_npus(self) -> int:
        return self.n_groups * self.group_size

    @property
    def n_healthy(self) -> int:
        return (self.n_npus if self.defects is None
                else self.defects.n_healthy)

    def healthy_npus(self) -> List[int]:
        if self.defects is None:
            return list(range(self.n_npus))
        return list(self.defects.healthy())

    @property
    def npus_per_l1(self) -> int:
        return self.group_size

    @property
    def n_l1(self) -> int:
        return self.n_groups

    @property
    def bisection(self) -> float:
        """Full-duplex spine bisection-cut bandwidth.

        Splitting the NPUs into two halves of L1 groups severs the smaller
        half's uplinks — ``n_groups // 2`` links — counted in both
        directions, consistent with :meth:`MeshFabric.bisection_bw`'s
        ``2 · (links crossing the cut) · link_bw`` definition.  (The seed
        formula ``n_groups · l1_l2_bw / 2 * 2`` let the halving cancel and
        double-counted the cut by one uplink per *group*.)"""
        return 2 * (self.n_groups // 2) * self.config.l1_l2_bw

    def bisection_bw(self) -> float:
        """Alias matching :meth:`MeshFabric.bisection_bw`."""
        return self.bisection

    def l1_of(self, nid: int) -> int:
        return nid // self.group_size

    def io_per_group(self) -> List[int]:
        """I/O controllers per L1 switch, spread as evenly as possible
        (paper: 18 over 5 L1s → 4,4,4,3,3)."""
        base, extra = divmod(self.n_io, self.n_groups)
        return [base + (g < extra) for g in range(self.n_groups)]

    # ---- effective bandwidth --------------------------------------------------
    def _group_l1_span(self, group: Sequence[int]) -> Dict[int, int]:
        span: Dict[int, int] = {}
        for nid in group:
            l1 = self.l1_of(nid)
            span[l1] = span.get(l1, 0) + 1
        return span

    def span_structure(self, group: Sequence[int]) -> Tuple[int, int]:
        """(g, k) = (#L1 switches spanned, max members under one L1) —
        the only group-dependent structure :meth:`collective_time` and
        :meth:`effective_npu_bw` consume.  The batched sweep engine
        (core/batch_engine.py) memoizes this per distinct group pattern
        and vectorizes the remaining pure arithmetic."""
        span = self._group_l1_span(group)
        if not span:
            return 1, 1
        return len(span), max(span.values())

    def effective_npu_bw(self, group: Sequence[int],
                         concurrent_groups: int = 1) -> float:
        """Sustained per-NPU injection BW for one collective flow.

        * group under one L1 → full NPU-L1 BW.
        * group spanning L1s, endpoint hierarchical algorithm → the upper
          ring runs at the per-NPU share of L1→L2 (paper: local phase at
          3 TB/s contributes; effective = share + (k−1)·share for k NPUs
          per L1 — i.e. the Sec. VIII '375 + 4×375 = 1875 GB/s' analysis).
        * in-network → L1 reduces first; each NPU effectively drives
          min(NPU-L1, L1-L2) for its (halved) traffic.
        """
        cfg = self.config
        span = self._group_l1_span(group)
        if len(span) <= 1:
            return cfg.npu_l1_bw
        k = max(span.values())                    # NPUs of this group per L1
        l2_bw = cfg.l1_l2_bw
        f = self.uplink_factor(group)
        if f != 1.0:                   # severed uplinks shrink the spine BW;
            l2_bw = cfg.l1_l2_bw * f   # defect-free path stays byte-for-byte
        # L1→L2 BW shared by concurrent flows crossing the spine
        share = l2_bw / max(k * concurrent_groups, 1)
        if cfg.in_network:
            return min(cfg.npu_l1_bw,
                       l2_bw / max(concurrent_groups, 1))
        # hierarchical endpoint: the local phase at npu_l1_bw amplifies the
        # spine-limited phase by the local fan-in — the paper's Sec. VIII
        # '375 + 4·375 = 1875 GB/s' analysis, i.e. share·(1+k) when several
        # group members share an L1
        if k > 1:
            return min(cfg.npu_l1_bw, share * (1 + k))
        return min(cfg.npu_l1_bw, share)

    def collective_time(self, kind: str, group: Sequence[int], nbytes: float,
                        concurrent_groups: int = 1) -> float:
        """Step-explicit collective time.

        In-network: one injection of the (≈halved) traffic through the
        reduction/distribution tree — 4 fabric traversals (NPU→L1→L2→L1→NPU)
        regardless of n (this is FRED's latency win over 2(n−1) ring steps).
        Endpoint (FRED-A/C): hierarchical two-phase ring — 2(k−1) local +
        2(g−1) spine steps."""
        n = len(group)
        if n <= 1 or nbytes <= 0:
            return 0.0
        cfg = self.config
        span = self._group_l1_span(group)
        g, k = len(span), max(span.values())
        if cfg.in_network:
            traffic = innetwork_traffic_bytes(kind, n, nbytes)
            steps = 4 if g > 1 else 2
        else:
            traffic = endpoint_traffic_bytes(kind, n, nbytes)
            steps = (2 * (k - 1) + 2 * (g - 1)) if g > 1 else 2 * (n - 1)
            steps = max(steps, 2)
            if kind != "all_reduce":
                steps = max(steps // 2, 1)
        bw = self.effective_npu_bw(group, concurrent_groups)
        per_step = (traffic / max(steps, 1)) / bw + cfg.switch_latency +             cfg.step_overhead
        return steps * per_step

    def pp_transfer_time(self, nbytes: float) -> float:
        """Peer NPUs sit under one L1: full NPU-L1 BW (Sec. VIII)."""
        return nbytes / self.config.npu_l1_bw

    # ---- I/O -------------------------------------------------------------------
    def io_linerate_factor(self) -> float:
        """FRED routes I/O streams through the tree without hotspots —
        full line rate (Sec. III Metric 1)."""
        return 1.0

    def io_stream_rate(self, n_io: "int | None" = None) -> float:
        return (self.n_io if n_io is None else n_io) * self.config.io_bw

    def uplink_factor(self, group: Sequence[int]) -> float:
        """Fraction of L1→L2 bandwidth surviving the defect mask for this
        group: min over spanned L1s of healthy/total uplinks.  1.0 with no
        mask (or no severed uplinks); at least one uplink per L1 is assumed
        alive (a fully severed L1 is an unplaceable dead group).  NPU→L1
        links are identified with their NPU (core/defects.py), so they
        never show up here."""
        d = self.defects
        if d is None or not d.dead_uplinks:
            return 1.0
        up = self.uplinks_per_l1()
        f = 1.0
        for l1 in self._group_l1_span(group):
            if l1 < self.n_groups:
                healthy = max(1, up - d.dead_uplinks_of(l1))
                f = min(f, healthy / up)
        return f

    # ---- Table III HW accounting (derived from the shape) ----------------------
    def uplinks_per_l1(self) -> int:
        """Physical uplink ports per L1 switch, at NPU-port width."""
        return max(1, math.ceil(self.config.l1_l2_bw / self.config.npu_l1_bw))

    def switch_inventory(self) -> List[Tuple[str, int, int]]:
        """(level, ports, count) of the FRED switches this shape needs.

        L1 switches carry ``group_size`` NPU ports, their share of the I/O
        controllers, and the spine uplinks; the L2 spine switch aggregates
        every L1's uplinks.  L1s with different I/O shares are distinct
        port counts (the paper's FRED3(12)/FRED3(11) split on the default
        wafer)."""
        up = self.uplinks_per_l1()
        by_ports: Dict[int, int] = {}
        for io in self.io_per_group():
            p = self.group_size + io + up
            by_ports[p] = by_ports.get(p, 0) + 1
        inv = [("L1", p, c) for p, c in sorted(by_ports.items(), reverse=True)]
        inv.append(("L2", max(self.n_groups * up, 2), 1))
        return inv

    def hw_accounting(self, m: int = 3) -> Dict[str, float]:
        """Aggregate area/power/µswitch count over the derived inventory
        (FRED_m switches; paper Table III models m=3)."""
        from .switch import FredSwitch, hw_overhead
        total = {"area_mm2": 0.0, "power_w": 0.0, "microswitches": 0,
                 "switches": 0}
        for _level, ports, count in self.switch_inventory():
            o = hw_overhead(FredSwitch.build(ports, m))
            total["area_mm2"] += count * o["area_mm2"]
            total["power_w"] += count * o["power_w"]
            total["microswitches"] += count * o["microswitches"]
            total["switches"] += count
        return total
