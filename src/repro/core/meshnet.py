"""Baseline wafer-scale 2D-mesh network model (paper Sec. III, VI-B2).

``rows``×``cols`` mesh of NPUs (paper evaluates 5×4), 750 GB/s per link per
direction, X-Y routing, I/O controllers (128 GB/s CXL) attached to border
NPUs (corners get two, or an explicit ``n_io`` override).
Collectives use logical rings over the member NPUs routed X-Y, except the
wafer-wide All-Reduce which uses the hierarchical 2D algorithm with two
reverse-direction chunks [Kumar & Jouppi 2020] (Sec. VII-B).

The model exposes:
  * ``xy_links``           — links crossed between two NPUs under X-Y.
  * ``ring_max_congestion``— worst per-link overlap for a set of rings.
  * ``collective_time``    — endpoint-algorithm time for one collective.
  * ``io_linerate_factor`` — Fig. 4's (2N−1)·P hotspot analysis: the factor
                             by which I/O streams must be slowed so the
                             hotspot link sustains all channels.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .defects import DefectMask, normalize

Link = Tuple[Tuple[int, int], Tuple[int, int]]   # ((r,c) -> (r,c))


def strided_ring_family(healthy: Sequence[int], count: int, stride: int,
                        n_used: int) -> List[List[int]]:
    """All concurrent rings of one strided-group pattern, materialized on
    the ``healthy`` id list.

    Under the canonical placements every collective of one parallelism
    axis is a family of arithmetic progressions over the first ``n_used``
    healthy NPUs, fully determined by ``(count, stride, n_used)``: ring
    ``(blk, r)`` holds ``healthy[blk·count·stride + r + i·stride]`` for
    ``i < count`` — MP groups are ``(mp, 1)`` blocks, DP groups
    ``(dp_per_wafer, mp·pp)`` interleaves, EP groups ``(ep, mp·pp)``
    interleaved blocks.  ``family[0]`` is always the representative group
    the scalar simulator evaluates, so feeding the whole family to
    :meth:`MeshFabric.collective_time` as ``concurrent_rings`` charges the
    evaluated ring the *real* shared-link congestion its siblings' detour
    paths create under a defect mask (healthy meshes keep the
    single-ring model — disjoint X-Y rings never detour onto each other).
    Degenerate patterns (``count ≤ 1`` or a block wider than ``n_used``)
    fall back to the single representative ring."""
    block = count * stride
    if count <= 1 or block <= 0 or block > n_used:
        return [[healthy[i * stride] for i in range(max(count, 1))]]
    return [[healthy[blk * block + r + i * stride] for i in range(count)]
            for blk in range(n_used // block)
            for r in range(stride)]


@dataclasses.dataclass
class MeshFabric:
    rows: int = 5
    cols: int = 4
    link_bw: float = 750e9            # B/s per direction
    io_bw: float = 128e9              # per I/O controller
    latency_per_hop: float = 20e-9    # repro: unit[s]
    step_overhead: float = 8e-7       # repro: unit[s] per ring-step SW/protocol
                                      # (ASTRA-SIM-style NPU processing delay)
    n_io: Optional[int] = None        # None → derived border placement
    defects: Optional[DefectMask] = None

    def __post_init__(self):
        if self.rows < 1 or self.cols < 1:
            raise ValueError(f"mesh needs positive dims, got "
                             f"{self.rows}x{self.cols}")
        self.defects = normalize(self.defects)

    @property
    def n(self) -> int:
        return self.rows * self.cols

    @property
    def n_npus(self) -> int:
        """Alias of :attr:`n` — uniform NPU-count accessor across fabric
        types (FredFabric, WaferCluster expose the same name)."""
        return self.n

    def corner_degree(self) -> int:
        """Links at a corner NPU — the wafer-wide All-Reduce bottleneck
        (2 on a proper 2D mesh, 1 on a degenerate 1×N line)."""
        return max((self.rows > 1) + (self.cols > 1), 1)

    def coord(self, nid: int) -> Tuple[int, int]:
        return divmod(nid, self.cols)

    def degree(self, nid: int) -> int:
        r, c = self.coord(nid)
        return ((r > 0) + (r < self.rows - 1) +
                (c > 0) + (c < self.cols - 1))

    def border_npus(self) -> List[int]:
        out = []
        for nid in range(self.n):
            r, c = self.coord(nid)
            if r in (0, self.rows - 1) or c in (0, self.cols - 1):
                out.append(nid)
        return out

    def n_io_controllers(self) -> int:
        """Border NPUs get one controller; corners two (paper: 18 on 5×4).
        An explicit ``n_io`` overrides the derived placement."""
        if self.n_io is not None:
            return self.n_io
        total = 0
        for nid in self.border_npus():
            r, c = self.coord(nid)
            corner = (r in (0, self.rows - 1)) and (c in (0, self.cols - 1))
            total += 2 if corner else 1
        return total

    @property
    def n_healthy(self) -> int:
        return self.n if self.defects is None else self.defects.n_healthy

    def healthy_npus(self) -> List[int]:
        if self.defects is None:
            return list(range(self.n))
        return list(self.defects.healthy())

    # ---- routing -------------------------------------------------------------
    def xy_links(self, src: int, dst: int) -> List[Link]:
        (r0, c0), (r1, c1) = self.coord(src), self.coord(dst)
        links: List[Link] = []
        c = c0
        while c != c1:
            nc = c + (1 if c1 > c else -1)
            links.append(((r0, c), (r0, nc)))
            c = nc
        r = r0
        while r != r1:
            nr = r + (1 if r1 > r else -1)
            links.append(((r, c1), (nr, c1)))
            r = nr
        return links

    def _yx_links(self, src: int, dst: int) -> List[Link]:
        """Y-then-X dimension order — the first detour tried under defects."""
        (r0, c0), (r1, c1) = self.coord(src), self.coord(dst)
        links: List[Link] = []
        r = r0
        while r != r1:
            nr = r + (1 if r1 > r else -1)
            links.append(((r, c0), (nr, c0)))
            r = nr
        c = c0
        while c != c1:
            nc = c + (1 if c1 > c else -1)
            links.append(((r1, c), (r1, nc)))
            c = nc
        return links

    def _link_healthy(self, ln: Link) -> bool:
        if self.defects is None:
            return True
        (r0, c0), (r1, c1) = ln
        return not self.defects.link_dead(r0 * self.cols + c0,
                                          r1 * self.cols + c1)

    def route_links(self, src: int, dst: int) -> List[Link]:
        """Links crossed src→dst, avoiding dead links/NPUs when a
        :class:`DefectMask` is set: X-Y first (the healthy-path fast case),
        then the Y-X detour, then a deterministic BFS over healthy links.
        A dead NPU's router is dead too, so no path may cross it.
        Raises ``ValueError`` when an endpoint is dead or the healthy
        sub-mesh is disconnected."""
        if self.defects is None:
            return self.xy_links(src, dst)
        for nid in (src, dst):
            if self.defects.npu_dead(nid):
                raise ValueError(f"route endpoint NPU {nid} is dead")
        for path in (self.xy_links(src, dst), self._yx_links(src, dst)):
            if all(self._link_healthy(ln) for ln in path):
                return path
        # BFS over the healthy sub-mesh (deterministic neighbour order)
        parent: Dict[int, int] = {src: src}
        frontier = [src]
        while frontier and dst not in parent:
            nxt: List[int] = []
            for nid in frontier:
                r, c = self.coord(nid)
                for nb in ((nid + 1) if c + 1 < self.cols else -1,
                           (nid - 1) if c > 0 else -1,
                           (nid + self.cols) if r + 1 < self.rows else -1,
                           (nid - self.cols) if r > 0 else -1):
                    if nb < 0 or nb in parent:
                        continue
                    if self.defects.link_dead(nid, nb):
                        continue
                    parent[nb] = nid
                    nxt.append(nb)
            frontier = nxt
        if dst not in parent:
            raise ValueError(
                f"no healthy mesh path {src}->{dst} under defect mask")
        ids = [dst]
        while ids[-1] != src:
            ids.append(parent[ids[-1]])
        ids.reverse()
        return [(self.coord(a), self.coord(b)) for a, b in zip(ids, ids[1:])]

    def _path_links(self, src: int, dst: int) -> List[Link]:
        return (self.xy_links(src, dst) if self.defects is None
                else self.route_links(src, dst))

    def ring_max_congestion(self, rings: Sequence[Sequence[int]]) -> int:
        """Max number of ring edges (over all rings) crossing any one link."""
        load: Dict[Link, int] = {}
        for ring in rings:
            n = len(ring)
            if n < 2:
                continue
            for i in range(n):
                a, b = ring[i], ring[(i + 1) % n]
                for ln in self._path_links(a, b):
                    load[ln] = load.get(ln, 0) + 1
        return max(load.values()) if load else 0

    # ---- collectives -----------------------------------------------------------
    def bisection_bw(self) -> float:
        """Full-duplex bisection: cutting the longer dimension in half
        crosses min(rows, cols) links (4 × 750 GB/s × 2 on the 5×4 wafer)."""
        return 2 * min(self.rows, self.cols) * self.link_bw

    def wafer_wide_allreduce_bw(self) -> float:
        """Hierarchical 2D algorithm, 2 reverse chunks: bounded by corner
        NPUs ⇒ per-NPU effective BW = corner_degree·link_bw — 2·750 GB/s
        on any proper 2D mesh (Sec. VIII)."""
        return self.corner_degree() * self.link_bw

    def _ring_hops(self, ring: Sequence[int]) -> float:
        """Mean X-Y hop count between ring neighbours."""
        n = len(ring)
        if n < 2:
            return 1.0
        tot = sum(len(self._path_links(ring[i], ring[(i + 1) % n]))
                  for i in range(n))
        return max(tot / n, 1.0)

    def ring_structure(self, group: Sequence[int]) -> Tuple[int, float]:
        """(congestion, mean hops) of the single logical ring over
        ``group`` — the exact structural inputs :meth:`collective_time`
        derives for its non-wafer-wide branch (``cong`` already floored
        at 1).  Pure integer/ratio topology quantities, independent of
        payload size and step overheads; the batched sweep engine
        (core/batch_engine.py) computes them once per distinct group
        pattern and then evaluates every strategy's times as array ops.

        Implemented as a single integer-keyed pass over the ring's X-Y
        unit links (directed, X before Y — the same walk
        :meth:`xy_links` materializes as tuple paths), hot enough in
        500+-NPU sweeps that the tuple allocations mattered; equivalence
        with ``ring_max_congestion`` + ``_ring_hops`` is pinned in
        tests/test_batch_engine.py."""
        ring = list(group)
        n = len(ring)
        if n < 2:
            return 1, 1.0
        if self.defects is not None:
            # defect-aware (detoured) paths: generic directed-link walk —
            # the same quantities ring_max_congestion + _ring_hops derive
            load2: Dict[Link, int] = {}
            tot2 = 0
            for i in range(n):
                path = self.route_links(ring[i], ring[(i + 1) % n])
                tot2 += len(path)
                for ln in path:
                    load2[ln] = load2.get(ln, 0) + 1
            cong2 = max(load2.values()) if load2 else 0
            return max(cong2, 1), max(tot2 / n, 1.0)
        C = self.cols
        base_v = 2 * self.rows * C           # separate id space for Y links
        load: Dict[int, int] = {}
        tot = 0
        for i in range(n):
            (r0, c0) = divmod(ring[i], C)
            (r1, c1) = divmod(ring[(i + 1) % n], C)
            if c1 > c0:                      # X first, heading right
                for c in range(c0, c1):
                    key = (r0 * C + c) * 2
                    load[key] = load.get(key, 0) + 1
                tot += c1 - c0
            elif c0 > c1:                    # heading left
                for c in range(c1, c0):
                    key = (r0 * C + c) * 2 + 1
                    load[key] = load.get(key, 0) + 1
                tot += c0 - c1
            if r1 > r0:                      # then Y along column c1, down
                for r in range(r0, r1):
                    key = base_v + (r * C + c1) * 2
                    load[key] = load.get(key, 0) + 1
                tot += r1 - r0
            elif r0 > r1:                    # up
                for r in range(r1, r0):
                    key = base_v + (r * C + c1) * 2 + 1
                    load[key] = load.get(key, 0) + 1
                tot += r0 - r1
        cong = max(load.values()) if load else 0
        return max(cong, 1), max(tot / n, 1.0)

    def collective_time(self, kind: str, group: Sequence[int], nbytes: float,
                        concurrent_rings: Sequence[Sequence[int]] = ()
                        ) -> float:
        """Endpoint ring algorithm over ``group``, step-explicit.

        Ring All-Reduce = 2(n−1) serialized steps, each moving a D/n chunk
        over (possibly multi-hop, possibly congested) X-Y paths.  This is
        what makes per-layer collectives on the mesh *latency-bound* — the
        effect FRED's single-injection in-network trees eliminate; the
        wafer-wide case uses the hierarchical-2D algorithm with 2 reverse
        chunks, whose step count is (rows−1)+(cols−1) per phase.
        """
        from .flows import endpoint_traffic_bytes
        n = len(group)
        if n <= 1 or nbytes <= 0:
            return 0.0
        traffic = endpoint_traffic_bytes(kind, n, nbytes)
        if n == self.n and self.defects is None:
            # hierarchical 2D: row rings then column rings, 2 chunks
            # (requires the full defect-free rectangle — any hole or dead
            # link degrades to the generic ring branch below)
            bw = self.wafer_wide_allreduce_bw()
            steps = 2 * ((self.cols - 1) + (self.rows - 1))
            if kind != "all_reduce":
                steps //= 2
            hops = 1.0
        else:
            rings = list(concurrent_rings) or [list(group)]
            cong = max(self.ring_max_congestion(rings), 1)
            bw = self.link_bw / cong
            steps = 2 * (n - 1) if kind == "all_reduce" else (n - 1)
            hops = self._ring_hops(list(group))
        chunk = traffic / max(steps, 1)
        per_step = (chunk / bw + self.latency_per_hop * hops +
                    self.step_overhead)
        return steps * per_step

    def pp_transfer_time(self, nbytes: float) -> float:
        """Border-to-next-stage multicast: one link (Sec. VIII)."""
        return nbytes / self.link_bw

    # ---- Fig. 4: I/O hotspot ----------------------------------------------------
    def io_hotspot_load(self) -> float:
        """Required hotspot-link BW (in units of per-channel rate P) for
        all I/O channels streaming a broadcast simultaneously: (2N−1) for an
        N×N mesh (paper's formula; for rectangular meshes use the max
        dimension)."""
        n = max(self.rows, self.cols)
        return 2 * n - 1

    def io_linerate_factor(self) -> float:
        """Fraction of I/O line rate sustainable through the hotspot link:
        min(1, link_bw / ((2N−1)·P)) — GPT-3 case: 750/1152 = 0.65."""
        need = self.io_hotspot_load() * self.io_bw
        return min(1.0, self.link_bw / need)

    def io_stream_rate(self) -> float:
        """Aggregate sustainable I/O streaming rate onto the wafer."""
        return self.n_io_controllers() * self.io_bw * self.io_linerate_factor()
