"""Device placement (paper Sec. V-C option 4 + baseline policy Sec. VII-C).

``fred_placement``: workers of the same MP group on consecutive NPUs, then
iterate PP, then DP — with FRED_3 switches this suffices to avoid routing
conflicts for 3D-parallelism (the property ``tests/test_routing.py``
verifies exhaustively for many (mp, dp, pp) shapes).

``mesh_placement``: the baseline's priority order MP > PP > DP mapped onto
the 2D mesh row-major (favoring MP adjacency, as in Megatron-LM [28]).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterator, List, Optional, Tuple

from .defects import DefectMask

Worker = Tuple[int, int, int]          # (mp, dp, pp) coordinates


@dataclasses.dataclass(frozen=True)
class Strategy:
    mp: int
    dp: int
    pp: int
    wafers: int = 1        # wafer axis: DP replicas spread over this many
                           # wafers of a WaferCluster (1 = single wafer)
    ep: int = 1            # expert-parallel degree: experts shard over ep
                           # DP peers within a wafer; the dispatch/combine
                           # All-to-All runs inside each EP group
    sp: int = 1            # sequence-parallel degree: activations split
                           # along the sequence dim across sp of the mp
                           # peers (Megatron-SP style)

    @property
    def n_workers(self) -> int:
        return self.mp * self.dp * self.pp

    @property
    def dp_per_wafer(self) -> int:
        return self.dp // self.wafers

    def workers(self) -> Iterator[Worker]:
        for d in range(self.dp):
            for p in range(self.pp):
                for m in range(self.mp):
                    yield (m, d, p)

    def mp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for m in range(self.mp)]
                for d in range(self.dp) for p in range(self.pp)]

    def dp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for d in range(self.dp)]
                for m in range(self.mp) for p in range(self.pp)]

    def pp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for p in range(self.pp)]
                for m in range(self.mp) for d in range(self.dp)]

    def ep_groups(self) -> List[List[Worker]]:
        """Blocks of ``ep`` consecutive DP peers per (mp, pp) coordinate —
        consecutive d share a wafer under :func:`cluster_placement` as long
        as ``ep`` divides ``dp_per_wafer`` (validated by the simulator)."""
        return [[(m, b * self.ep + e, p) for e in range(self.ep)]
                for m in range(self.mp) for p in range(self.pp)
                for b in range(self.dp // self.ep)]

    def __str__(self):
        s = f"MP({self.mp})-DP({self.dp})-PP({self.pp})"
        if self.wafers > 1:
            s += f"-W({self.wafers})"
        if self.ep > 1:
            s += f"-EP({self.ep})"
        if self.sp > 1:
            s += f"-SP({self.sp})"
        return s


def fred_placement(strategy: Strategy, n_npus: "int | None" = None
                   ) -> Dict[Worker, int]:
    """worker → physical NPU id; MP consecutive, then PP, then DP.

    ``n_npus`` (when given) validates the strategy against a generalized
    fabric capacity."""
    if n_npus is not None and strategy.n_workers > n_npus:
        raise ValueError(f"{strategy} needs {strategy.n_workers} NPUs, "
                         f"fabric has {n_npus}")
    placement: Dict[Worker, int] = {}
    nid = 0
    for d in range(strategy.dp):
        for p in range(strategy.pp):
            for m in range(strategy.mp):
                placement[(m, d, p)] = nid
                nid += 1
    return placement


def mesh_placement(strategy: Strategy, rows: int, cols: int
                   ) -> Dict[Worker, Tuple[int, int]]:
    """worker → (row, col); MP > PP > DP priority (baseline, Sec. VII-C)."""
    if strategy.n_workers > rows * cols:
        raise ValueError(f"{strategy} needs {strategy.n_workers} NPUs, "
                         f"{rows}x{cols} mesh has {rows * cols}")
    placement: Dict[Worker, Tuple[int, int]] = {}
    nid = 0
    for d in range(strategy.dp):
        for p in range(strategy.pp):
            for m in range(strategy.mp):
                placement[(m, d, p)] = divmod(nid, cols)
                nid += 1
    return placement


def cluster_placement(strategy: Strategy, n_wafers: int,
                      npus_per_wafer: int) -> Dict[Worker, int]:
    """worker → global NPU id on a :class:`~repro.core.cluster.WaferCluster`.

    DP replicas are spread across wafers *first* (the DP gradient exchange
    is the cheapest traffic to push over the inter-wafer links: one
    hierarchical All-Reduce per layer, vs per-microbatch MP/PP activation
    traffic), and each model instance (its mp×pp workers) lives entirely
    within one wafer.  Within a wafer the ``fred_placement`` order — MP
    consecutive, then PP, then DP — is preserved, so ``strategy.wafers = 1``
    reproduces ``fred_placement`` exactly.

    Global ids are ``wafer_idx * npus_per_wafer + local_id``; wafers are
    numbered rack-major (wafer ``w`` sits in rack ``w // rack_size``), so
    a DP split maps across the *deepest* hierarchy levels progressively —
    it fills one rack before spilling into the next, and only
    wafer-counts beyond the rack size pay the pod-level exchange
    (``WaferCluster.level_spans``).
    """
    w = strategy.wafers
    if w < 1:
        raise ValueError(f"{strategy} has wafers={w}; need ≥ 1")
    if w > n_wafers:
        raise ValueError(f"{strategy} spans {w} wafers, cluster has "
                         f"{n_wafers}")
    if strategy.dp % w != 0:
        raise ValueError(f"{strategy}: dp={strategy.dp} not divisible by "
                         f"wafers={w} — DP replicas map whole onto wafers")
    per_wafer_workers = strategy.mp * strategy.pp * (strategy.dp // w)
    if per_wafer_workers > npus_per_wafer:
        raise ValueError(f"{strategy} needs {per_wafer_workers} NPUs per "
                         f"wafer, wafer has {npus_per_wafer}")
    dp_per_wafer = strategy.dp // w
    placement: Dict[Worker, int] = {}
    for d in range(strategy.dp):
        wafer, dl = divmod(d, dp_per_wafer)
        for p in range(strategy.pp):
            for m in range(strategy.mp):
                local = (dl * strategy.pp + p) * strategy.mp + m
                placement[(m, d, p)] = wafer * npus_per_wafer + local
    return placement


def placement_groups(strategy: Strategy, placement: Dict[Worker, int]
                     ) -> Dict[str, List[List[int]]]:
    """NPU-id groups per parallelism type under a placement."""
    as_ids = lambda groups: [[placement[w] for w in g] for g in groups]
    return {"mp": as_ids(strategy.mp_groups()),
            "dp": as_ids(strategy.dp_groups()),
            "pp": as_ids(strategy.pp_groups())}


def defect_placement(strategy: Strategy, mask: DefectMask,
                     npus_per_wafer: "int | None" = None) -> Dict[Worker, int]:
    """worker → physical NPU id, compacted around the mask's holes.

    Logical slot ``i`` of the canonical :func:`fred_placement` order lands
    on the ``i``-th *healthy* NPU (SpiNNaker2-style compaction): MP groups
    stay on consecutive healthy NPUs, the strategy's relative order is
    unchanged, and an all-healthy mask reproduces ``fred_placement``
    exactly.  Raises when the strategy needs more workers than the wafer
    has healthy NPUs."""
    npw = npus_per_wafer if npus_per_wafer is not None else mask.n_npus
    base = fred_placement(strategy, npw)
    healthy = mask.healthy()
    if strategy.n_workers > len(healthy):
        raise ValueError(
            f"{strategy} needs {strategy.n_workers} healthy NPUs, "
            f"defect mask leaves {len(healthy)}")
    return {w: healthy[nid] for w, nid in base.items()}


def _masked_wafer_capacity(strategy: Strategy, n_wafers: int,
                           mask: DefectMask) -> None:
    per_wafer = strategy.mp * strategy.pp * strategy.dp_per_wafer
    if per_wafer > mask.n_healthy:
        raise ValueError(
            f"{strategy} needs {per_wafer} healthy NPUs per wafer, "
            f"defect mask leaves {mask.n_healthy}")


@functools.lru_cache(maxsize=4096)
def cached_placement_groups(strategy: Strategy, n_wafers: int,
                            npus_per_wafer: int,
                            defects: Optional[DefectMask] = None,
                            wafer_defects: "Optional[Tuple[Optional[DefectMask], ...]]" = None
                            ) -> Dict[str, List[List[int]]]:
    """Memoized :func:`placement_groups` for the canonical placements.

    The NPU-id groups depend only on (strategy, n_wafers, npus_per_wafer):
    ``mesh_placement``'s row-major (row, col) linearizes back to the same
    ids ``fred_placement`` assigns, and ``cluster_placement`` is already
    id-based — so one memo entry serves every fabric type and shape with
    the same per-wafer capacity.  Sweeps re-run the same strategy across
    many (fabric, shape) pairs; this turns the dominant per-``run`` cost
    (rebuilding O(n_workers) group lists) into a dict hit.

    With a :class:`DefectMask` the canonical local ids are compacted onto
    each wafer's healthy NPUs (the same mask is applied to every wafer —
    the cost model's worst-wafer simplification), keeping MP groups on
    consecutive *healthy* NPUs.  ``wafer_defects`` (mutually exclusive
    with ``defects``) supplies one mask — or None for a pristine wafer —
    per cluster wafer instead, compacting each wafer onto its *own*
    healthy list; the strategy occupies wafers ``0..strategy.wafers-1``,
    so only those wafers' capacities gate it.

    Callers must treat the returned lists as immutable (they are shared).
    Capacity violations raise ``ValueError`` exactly like the uncached
    placements (exceptions are not cached by ``lru_cache``).
    """
    if defects is not None and wafer_defects is not None:
        raise ValueError("defects and wafer_defects are mutually "
                         "exclusive — pass one uniform mask or one mask "
                         "per wafer")
    if n_wafers > 1:
        ids = cluster_placement(strategy, n_wafers, npus_per_wafer)
    else:
        ids = fred_placement(strategy, npus_per_wafer)
    groups = placement_groups(strategy, ids)
    npw = npus_per_wafer
    if wafer_defects is not None:
        if len(wafer_defects) != n_wafers:
            raise ValueError(
                f"wafer_defects has {len(wafer_defects)} entries for "
                f"{n_wafers} wafers — one mask (or None) per wafer")
        per_wafer = strategy.mp * strategy.pp * strategy.dp_per_wafer
        healthy_by_wafer = [tuple(range(npw)) if m is None else m.healthy()
                            for m in wafer_defects]
        for w in range(strategy.wafers):
            if per_wafer > len(healthy_by_wafer[w]):
                raise ValueError(
                    f"{strategy} needs {per_wafer} healthy NPUs on wafer "
                    f"{w}, its defect mask leaves "
                    f"{len(healthy_by_wafer[w])}")

        def remap_pw(gid: int) -> int:
            wafer, local = divmod(gid, npw)
            return wafer * npw + healthy_by_wafer[wafer][local]

        return {k: [[remap_pw(i) for i in g] for g in gs]
                for k, gs in groups.items()}
    if defects is None:
        return groups
    _masked_wafer_capacity(strategy, n_wafers, defects)
    healthy = defects.healthy()

    def remap(gid: int) -> int:
        wafer, local = divmod(gid, npw)
        return wafer * npw + healthy[local]

    return {k: [[remap(i) for i in g] for g in gs]
            for k, gs in groups.items()}


def strided_group(count: int, stride: int) -> List[int]:
    """The NPU-id pattern every canonical first group reduces to.

    Under :func:`fred_placement` / :func:`mesh_placement` /
    :func:`cluster_placement` the simulator's representative groups are
    arithmetic progressions from 0: the first MP group is
    ``strided_group(mp, 1)`` and the first DP group (per wafer) is
    ``strided_group(dp_per_wafer, mp * pp)``.  The batched engine
    (core/batch_engine.py) keys its structural tables on (count, stride)
    instead of materializing whole placements."""
    return list(range(0, count * stride, stride))
