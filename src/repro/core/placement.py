"""Device placement (paper Sec. V-C option 4 + baseline policy Sec. VII-C).

``fred_placement``: workers of the same MP group on consecutive NPUs, then
iterate PP, then DP — with FRED_3 switches this suffices to avoid routing
conflicts for 3D-parallelism (the property ``tests/test_routing.py``
verifies exhaustively for many (mp, dp, pp) shapes).

``mesh_placement``: the baseline's priority order MP > PP > DP mapped onto
the 2D mesh row-major (favoring MP adjacency, as in Megatron-LM [28]).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

Worker = Tuple[int, int, int]          # (mp, dp, pp) coordinates


@dataclasses.dataclass(frozen=True)
class Strategy:
    mp: int
    dp: int
    pp: int

    @property
    def n_workers(self) -> int:
        return self.mp * self.dp * self.pp

    def workers(self) -> Iterator[Worker]:
        for d in range(self.dp):
            for p in range(self.pp):
                for m in range(self.mp):
                    yield (m, d, p)

    def mp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for m in range(self.mp)]
                for d in range(self.dp) for p in range(self.pp)]

    def dp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for d in range(self.dp)]
                for m in range(self.mp) for p in range(self.pp)]

    def pp_groups(self) -> List[List[Worker]]:
        return [[(m, d, p) for p in range(self.pp)]
                for m in range(self.mp) for d in range(self.dp)]

    def __str__(self):
        return f"MP({self.mp})-DP({self.dp})-PP({self.pp})"


def fred_placement(strategy: Strategy, n_npus: "int | None" = None
                   ) -> Dict[Worker, int]:
    """worker → physical NPU id; MP consecutive, then PP, then DP.

    ``n_npus`` (when given) validates the strategy against a generalized
    fabric capacity."""
    if n_npus is not None and strategy.n_workers > n_npus:
        raise ValueError(f"{strategy} needs {strategy.n_workers} NPUs, "
                         f"fabric has {n_npus}")
    placement: Dict[Worker, int] = {}
    nid = 0
    for d in range(strategy.dp):
        for p in range(strategy.pp):
            for m in range(strategy.mp):
                placement[(m, d, p)] = nid
                nid += 1
    return placement


def mesh_placement(strategy: Strategy, rows: int, cols: int
                   ) -> Dict[Worker, Tuple[int, int]]:
    """worker → (row, col); MP > PP > DP priority (baseline, Sec. VII-C)."""
    if strategy.n_workers > rows * cols:
        raise ValueError(f"{strategy} needs {strategy.n_workers} NPUs, "
                         f"{rows}x{cols} mesh has {rows * cols}")
    placement: Dict[Worker, Tuple[int, int]] = {}
    nid = 0
    for d in range(strategy.dp):
        for p in range(strategy.pp):
            for m in range(strategy.mp):
                placement[(m, d, p)] = divmod(nid, cols)
                nid += 1
    return placement


def placement_groups(strategy: Strategy, placement: Dict[Worker, int]
                     ) -> Dict[str, List[List[int]]]:
    """NPU-id groups per parallelism type under a placement."""
    as_ids = lambda groups: [[placement[w] for w in g] for g in groups]
    return {"mp": as_ids(strategy.mp_groups()),
            "dp": as_ids(strategy.dp_groups()),
            "pp": as_ids(strategy.pp_groups())}
