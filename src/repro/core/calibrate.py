"""Fig. 10 calibration (documented in EXPERIMENTS.md).

The paper reports *relative* end-to-end speedups only; absolute compute and
per-step software overheads in their ASTRA-SIM setup are not published.
We therefore fit three physical parameters:

  * ``compute_efficiency`` — achieved fraction of the 1 PFLOP/s NPU peak,
  * ``mesh_step_overhead`` — per ring-step processing delay on the mesh,
  * ``fred_step_overhead`` — per flow-step delay on the FRED fabric,

against the eight published speedups (4 workloads × FRED-C/D), then freeze
them for every simulator experiment.  A good joint fit with a single
parameter set is evidence the model captures the paper's mechanisms; the
residuals are reported, not hidden.
"""

from __future__ import annotations

import itertools
import math
from typing import Dict, Tuple

PAPER_SPEEDUPS = {
    "ResNet-152": {"FRED-C": 1.41, "FRED-D": 1.76},
    "Transformer-17B": {"FRED-C": 1.75, "FRED-D": 1.87},
    "GPT-3": {"FRED-C": 1.34, "FRED-D": 1.34},
    "Transformer-1T": {"FRED-C": 1.40, "FRED-D": 1.40},
}


def simulate_speedups(eff: float, mesh_oh: float, fred_oh: float
                      ) -> Dict[str, Dict[str, float]]:
    import repro.core.meshnet as meshnet
    import repro.core.fabric as fabric
    from repro.core.simulator import Simulator
    from repro.core.workloads import paper_workloads

    out = {}
    for w in paper_workloads():
        row = {}
        sims = {}
        for name in ("baseline", "FRED-C", "FRED-D"):
            sim = Simulator(name, compute_efficiency=eff)
            if sim.mesh is not None:
                sim.mesh.step_overhead = mesh_oh
            else:
                sim.fred.config = type(sim.fred.config)(
                    **{**sim.fred.config.__dict__, "step_overhead": fred_oh})
            sims[name] = sim.run(w).total
        base = sims["baseline"]
        out[w.name] = {"FRED-C": base / sims["FRED-C"],
                       "FRED-D": base / sims["FRED-D"]}
    return out


def loss(speedups) -> float:
    err = 0.0
    for wname, row in PAPER_SPEEDUPS.items():
        for cfg, target in row.items():
            err += (math.log(speedups[wname][cfg]) - math.log(target)) ** 2
    return err


def fit(verbose: bool = False) -> Tuple[Dict[str, float], float]:
    best, best_err = None, float("inf")
    for eff in (0.25, 0.35, 0.45, 0.6, 0.8, 1.0):
        for mesh_oh in (2e-7, 4e-7, 6e-7, 8e-7, 1.2e-6):
            for fred_oh in (5e-8, 1e-7, 2e-7, 4e-7):
                sp = simulate_speedups(eff, mesh_oh, fred_oh)
                e = loss(sp)
                if e < best_err:
                    best, best_err = {"compute_efficiency": eff,
                                      "mesh_step_overhead": mesh_oh,
                                      "fred_step_overhead": fred_oh}, e
                    if verbose:
                        print(f"eff={eff} mesh_oh={mesh_oh:.1e} "
                              f"fred_oh={fred_oh:.1e} err={e:.4f}")
    return best, best_err


# Frozen calibration (re-derive with ``python -m repro.core.calibrate``).
CALIBRATED = {"compute_efficiency": 0.45,
              "mesh_step_overhead": 6e-7,
              "fred_step_overhead": 4e-7}


def main():
    best, err = fit(verbose=True)
    print("\nbest:", best, "err:", err)
    sp = simulate_speedups(**{k: v for k, v in zip(
        ("eff", "mesh_oh", "fred_oh"),
        (best["compute_efficiency"], best["mesh_step_overhead"],
         best["fred_step_overhead"]))})
    for w, row in sp.items():
        tgt = PAPER_SPEEDUPS[w]
        print(f"  {w:16s} C={row['FRED-C']:.2f} (paper {tgt['FRED-C']}) "
              f"D={row['FRED-D']:.2f} (paper {tgt['FRED-D']})")


if __name__ == "__main__":
    main()
