"""Consolidated fabric / cluster construction specs for the Simulator.

``Simulator`` historically grew one kwarg per axis (mesh_shape, fred_shape,
n_io, n_wafers, inter_wafer_*, inter_topology, hierarchy — ten in total).
These two frozen dataclasses are the consolidated front door:

    Simulator("FRED-D", spec=FabricSpec(fred_shape=(8, 8)),
              cluster_spec=ClusterSpec(n_wafers=4, inter_topology="switch"))

``FabricSpec`` describes one wafer (shape, I/O, and its defect draw);
``ClusterSpec`` describes how wafers stack into racks/pods.  The legacy
kwargs survive as thin deprecation shims that build a spec (see
``Simulator.__post_init__``) and produce bit-identical Breakdowns.

The same consolidation fronts the *decision* layer (ISSUE 10):
``autostrategy.choose_strategy`` had grown the identical kwarg sprawl
(``objective=``, ``mtbf_npu_hours=``, ``ep_candidates=``, ...), and
serving added a third objective family.  :class:`Objective` names *what
to optimize* (time | goodput | serving, with the family's parameters)
and :class:`DeploymentRequest` names *what to deploy* (model, hardware
axes, strategy axes) — ``autostrategy.choose(request)`` is the one entry
point for training and serving alike, and the legacy
``choose_strategy(**kwargs)`` call form is a ``DeprecationWarning`` shim
that builds the equivalent request (bit-identical decisions).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, TYPE_CHECKING

from .defects import DefectMask, normalize
from .workloads import DEFAULT_NPU_HBM_BYTES

if TYPE_CHECKING:
    from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """One wafer: fabric shape, I/O controllers, and the defect draw.

    ``mesh_shape`` applies to the baseline 2D mesh, ``fred_shape``
    (n_groups, group_size) to the FRED fabrics; leave either None for the
    fabric's paper default.  ``defects`` is interpreted by whichever fabric
    is built (see core/defects.py for the id-space overlay rules).
    """
    mesh_shape: Optional[Tuple[int, int]] = None
    fred_shape: Optional[Tuple[int, int]] = None
    n_io: Optional[int] = None
    defects: Optional[DefectMask] = None

    def __post_init__(self):
        object.__setattr__(self, "defects", normalize(self.defects))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Inter-wafer scale-out: wafer count / stacking and the link model.

    ``wafer_defects`` carries one :class:`DefectMask` (or None for a
    pristine wafer) per wafer — the cluster-honest alternative to
    ``FabricSpec.defects``, which applies a single mask to *every* wafer.
    The two are mutually exclusive (enforced by the Simulator); an
    all-None tuple normalizes away so the pristine path stays
    bit-identical.
    """
    n_wafers: int = 1
    hierarchy: Optional[Tuple[int, ...]] = None
    inter_topology: str = "ring"
    inter_wafer_links: int = 32
    inter_wafer_bw: float = 400e9
    inter_wafer_latency: float = 5e-7   # repro: unit[s] (per inter-level step)
    wafer_defects: Optional[Tuple[Optional[DefectMask], ...]] = None

    def __post_init__(self):
        if self.hierarchy is not None:
            object.__setattr__(self, "hierarchy", tuple(self.hierarchy))
        if self.wafer_defects is not None:
            masks = tuple(normalize(m) for m in self.wafer_defects)
            object.__setattr__(
                self, "wafer_defects",
                None if all(m is None for m in masks) else masks)


DEFAULT_FABRIC_SPEC = FabricSpec()
DEFAULT_CLUSTER_SPEC = ClusterSpec()


@dataclasses.dataclass(frozen=True)
class Objective:
    """What a deployment optimizes — the typed successor to
    ``choose_strategy``'s objective kwargs.

    ``kind`` selects the family and which parameter block applies:

    * ``"time"`` — healthy per-iteration time (no parameters).
    * ``"goodput"`` — MTBF-driven lifetime goodput (PR 9): the mtbf /
      mission / restart block.
    * ``"serving"`` — SLO-constrained serving throughput (ISSUE 10): the
      SLO / traffic / request-profile block.  Offered load is
      ``arrival_rate_rps`` if positive, else
      ``concurrent_users / think_time_s``.

    Prefer the :meth:`time` / :meth:`goodput` / :meth:`serving`
    constructors — they keep the irrelevant blocks at their inert
    defaults, which is what the bit-identity shims rely on.
    """
    kind: str = "time"
    # -- goodput block ----------------------------------------------------
    mtbf_npu_hours: float = math.inf
    mtbf_wafer_hours: float = math.inf
    mission_hours: float = 720.0
    restart_s: float = 60.0
    goodput_top_k: int = 32
    n_failure_states: int = 3
    failure_seed: int = 0
    # -- serving block ----------------------------------------------------
    target_p99_ms: float = 200.0
    arrival_rate_rps: float = 0.0
    concurrent_users: int = 0
    think_time_s: float = 60.0
    prompt_tokens: int = 1024
    output_tokens: int = 256

    def __post_init__(self):
        if self.kind not in ("time", "goodput", "serving"):
            raise ValueError(
                f"Objective.kind must be time|goodput|serving, "
                f"got {self.kind!r}")

    @classmethod
    def time(cls) -> "Objective":
        return cls(kind="time")

    @classmethod
    def goodput(cls, *, mtbf_npu_hours: float = math.inf,
                mtbf_wafer_hours: float = math.inf,
                mission_hours: float = 720.0, restart_s: float = 60.0,
                goodput_top_k: int = 32, n_failure_states: int = 3,
                failure_seed: int = 0) -> "Objective":
        return cls(kind="goodput", mtbf_npu_hours=mtbf_npu_hours,
                   mtbf_wafer_hours=mtbf_wafer_hours,
                   mission_hours=mission_hours, restart_s=restart_s,
                   goodput_top_k=goodput_top_k,
                   n_failure_states=n_failure_states,
                   failure_seed=failure_seed)

    @classmethod
    def serving(cls, *, target_p99_ms: float = 200.0,
                arrival_rate_rps: float = 0.0, concurrent_users: int = 0,
                think_time_s: float = 60.0, prompt_tokens: int = 1024,
                output_tokens: int = 256) -> "Objective":
        return cls(kind="serving", target_p99_ms=target_p99_ms,
                   arrival_rate_rps=arrival_rate_rps,
                   concurrent_users=concurrent_users,
                   think_time_s=think_time_s, prompt_tokens=prompt_tokens,
                   output_tokens=output_tokens)


@dataclasses.dataclass(frozen=True)
class DeploymentRequest:
    """What to deploy and over which axes to search — the one argument of
    ``autostrategy.choose``.

    ``model`` is a registry :class:`~repro.models.config.ModelConfig`;
    ``shape`` a :class:`~repro.models.config.ShapeConfig` (required for
    training objectives, ignored by serving, whose request profile lives
    on the :class:`Objective`).  The remaining fields mirror the legacy
    ``choose_strategy`` kwargs one-for-one, same defaults — a shim-built
    request decides bit-identically.
    """
    model: "ModelConfig"
    shape: Optional["ShapeConfig"] = None
    objective: Objective = Objective()
    n_npus: int = 64
    fabrics: Tuple[str, ...] = ("baseline", "FRED-C", "FRED-D")
    max_wafers: int = 2
    inter_topologies: Tuple[str, ...] = ("ring", "fully_connected",
                                         "switch")
    max_levels: int = 1
    npu_hbm_bytes: float = DEFAULT_NPU_HBM_BYTES
    master: bool = True
    moments_dtype: str = "float32"
    remat: str = "full"
    min_utilization: float = 0.9
    prune_symmetric: bool = True
    ep_candidates: Tuple[int, ...] = (1,)
    sp_candidates: Tuple[int, ...] = (1,)
    comm_overlap_fraction: float = 0.0
