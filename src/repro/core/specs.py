"""Consolidated fabric / cluster construction specs for the Simulator.

``Simulator`` historically grew one kwarg per axis (mesh_shape, fred_shape,
n_io, n_wafers, inter_wafer_*, inter_topology, hierarchy — ten in total).
These two frozen dataclasses are the consolidated front door:

    Simulator("FRED-D", spec=FabricSpec(fred_shape=(8, 8)),
              cluster_spec=ClusterSpec(n_wafers=4, inter_topology="switch"))

``FabricSpec`` describes one wafer (shape, I/O, and its defect draw);
``ClusterSpec`` describes how wafers stack into racks/pods.  The legacy
kwargs survive as thin deprecation shims that build a spec (see
``Simulator.__post_init__``) and produce bit-identical Breakdowns.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .defects import DefectMask, normalize


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """One wafer: fabric shape, I/O controllers, and the defect draw.

    ``mesh_shape`` applies to the baseline 2D mesh, ``fred_shape``
    (n_groups, group_size) to the FRED fabrics; leave either None for the
    fabric's paper default.  ``defects`` is interpreted by whichever fabric
    is built (see core/defects.py for the id-space overlay rules).
    """
    mesh_shape: Optional[Tuple[int, int]] = None
    fred_shape: Optional[Tuple[int, int]] = None
    n_io: Optional[int] = None
    defects: Optional[DefectMask] = None

    def __post_init__(self):
        object.__setattr__(self, "defects", normalize(self.defects))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Inter-wafer scale-out: wafer count / stacking and the link model.

    ``wafer_defects`` carries one :class:`DefectMask` (or None for a
    pristine wafer) per wafer — the cluster-honest alternative to
    ``FabricSpec.defects``, which applies a single mask to *every* wafer.
    The two are mutually exclusive (enforced by the Simulator); an
    all-None tuple normalizes away so the pristine path stays
    bit-identical.
    """
    n_wafers: int = 1
    hierarchy: Optional[Tuple[int, ...]] = None
    inter_topology: str = "ring"
    inter_wafer_links: int = 32
    inter_wafer_bw: float = 400e9
    inter_wafer_latency: float = 5e-7   # repro: unit[s] (per inter-level step)
    wafer_defects: Optional[Tuple[Optional[DefectMask], ...]] = None

    def __post_init__(self):
        if self.hierarchy is not None:
            object.__setattr__(self, "hierarchy", tuple(self.hierarchy))
        if self.wafer_defects is not None:
            masks = tuple(normalize(m) for m in self.wafer_defects)
            object.__setattr__(
                self, "wafer_defects",
                None if all(m is None for m in masks) else masks)


DEFAULT_FABRIC_SPEC = FabricSpec()
DEFAULT_CLUSTER_SPEC = ClusterSpec()
