"""Strategy/topology sweep engine (LIBRA/WATOS-style co-exploration).

The paper's core claim is that FRED stays efficient across *arbitrary*
parallelization strategies; this module makes that explorable.  For a given
NPU count it enumerates

  * every valid (mp, dp, pp) 3D-parallel strategy (divisor triples, with
    an optional utilization floor so near-full wafers count too — the
    paper's Transformer-17B uses 18 of 20 NPUs), and
  * every wafer shape realizing that NPU count: rows×cols meshes for the
    baseline, n_groups×group_size almost-fat-trees for FRED, and
  * (``max_wafers > 1``) every wafer count of a multi-wafer cluster —
    the wafer is the manufacturing unit, so 2 wafers double the NPUs and
    the DP axis splits across them (Strategy.wafers, core/cluster.py) —
    crossed with every inter-wafer topology in ``inter_topologies``
    (ring / fully_connected / switch) and every hierarchy stacking of
    the wafer count into ≤ ``max_levels`` rack/pod levels
    (:func:`hierarchy_specs`),

then evaluates the cross-product under one of two bit-identical engines:
the default ``engine="batched"`` vectorizes all strategies of each
(fabric, shape, wafer count) configuration as NumPy array ops
(:mod:`repro.core.batch_engine` — what makes exhaustive 500+-NPU sweeps
fit the CI budget), while ``engine="scalar"`` walks
:class:`repro.core.simulator.Simulator` per point as the reference
oracle.  Scalar collective times are memoized per (fabric, shape) in a
bounded LRU — strategies share collective calls heavily (the same
wafer-wide or per-group All-Reduce appears in many strategies) — and
placement groups are memoized per (strategy, wafer count, wafer size)
across the whole process.

Reporting: :func:`pareto_front` extracts the strategies not dominated on
(time-per-sample, parameter-bytes-per-NPU) — the throughput/memory
trade-off DP replication buys — and :func:`to_csv_rows` emits the schema
documented in ``benchmarks/README.md``.
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .cluster import INTER_TOPOLOGIES
from .defects import DefectMask, normalize
from .placement import Strategy
from .simulator import Breakdown, LRUCache, Simulator
from .specs import ClusterSpec, FabricSpec
from .workloads import (MemoryModel, Workload, is_feasible,
                        memory_bytes_per_npu, transformer)

FABRICS = ("baseline", "FRED-A", "FRED-B", "FRED-C", "FRED-D")
ENGINES = ("batched", "scalar")

# bound on the shared collective memo — a 500+-NPU multi-wafer scalar
# sweep would otherwise grow it without limit (the batched engine keeps
# its own per-pattern structural tables and never touches this)
COLLECTIVE_CACHE_SIZE = 1 << 17


# --------------------------------------------------------------------------
# search spaces
# --------------------------------------------------------------------------

def factor_pairs(n: int) -> List[Tuple[int, int]]:
    """(a, b) with a·b = n and a ≥ b (orientation is symmetric for both
    fabric models)."""
    out = []
    b = 1
    while b * b <= n:
        if n % b == 0:
            out.append((n // b, b))
        b += 1
    return out


def mesh_shapes(n_npus: int) -> List[Tuple[int, int]]:
    """rows×cols meshes realizing ``n_npus`` (degenerate 1×N included —
    the model handles it; the sweep ranks it out on its own merits)."""
    return factor_pairs(n_npus)


def fred_shapes(n_npus: int) -> List[Tuple[int, int]]:
    """n_groups×group_size almost-fat-trees realizing ``n_npus``.  The
    single-group shape (1, n) is a pure crossbar — valid but excluded:
    the 2-level tree needs ≥ 2 L1 groups."""
    out: List[Tuple[int, int]] = []
    for a, b in factor_pairs(n_npus):
        for g, k in ((b, a), (a, b)):       # narrow groups first
            if g >= 2 and (g, k) not in out:
                out.append((g, k))
    return out


def cluster_shapes(n_npus: int, max_wafers: int,
                   shape_fn: Callable[[int], List[Tuple[int, int]]]
                   = fred_shapes) -> List[Tuple[int, Tuple[int, int]]]:
    """(n_wafers, per-wafer shape) pairs for every wafer count up to
    ``max_wafers``.  ``n_npus`` is *per wafer* — the wafer is the
    manufacturing unit, so scale-out multiplies the NPU count (a 2-wafer
    cluster of 20-NPU wafers has 40 NPUs).  ``max_wafers=1`` reduces to
    ``[(1, s) for s in shape_fn(n_npus)]``."""
    if max_wafers < 1:
        raise ValueError(f"max_wafers must be ≥ 1, got {max_wafers}")
    return [(w, s) for w in range(1, max_wafers + 1)
            for s in shape_fn(n_npus)]


def hierarchy_specs(n_wafers: int, max_levels: int = 1
                    ) -> List[Tuple[int, ...]]:
    """Stackings of ``n_wafers`` into ≤ ``max_levels`` inter levels
    (level counts innermost first, every level ≥ 2 wafers/units): 4
    wafers at 2 levels → the flat ``(4,)`` ring-of-wafers and the
    ``(2, 2)`` rack-of-2 × pod-of-2.  Deterministic order: flat spec
    first, then ascending innermost count."""
    if max_levels < 1:
        raise ValueError(f"max_levels must be ≥ 1, got {max_levels}")
    if n_wafers == 1:
        return [(1,)]
    specs: List[Tuple[int, ...]] = [(n_wafers,)]
    if max_levels >= 2:
        for c1 in range(2, n_wafers // 2 + 1):
            if n_wafers % c1:
                continue
            for rest in hierarchy_specs(n_wafers // c1, max_levels - 1):
                spec = (c1,) + rest
                if all(c >= 2 for c in spec):
                    specs.append(spec)
    return specs


def hierarchy_configs(n_npus: int, max_wafers: int,
                      shape_fn: Callable[[int], List[Tuple[int, int]]]
                      = fred_shapes,
                      inter_topologies: Sequence[str] = ("ring",),
                      max_levels: int = 1
                      ) -> List[Tuple[int, Tuple[int, int],
                                      Tuple[int, ...], str]]:
    """(n_wafers, per-wafer shape, hierarchy, inter topology) tuples —
    the full scale-out configuration axis of the sweep.  Single-wafer
    configurations carry the degenerate ``((1,), "")`` hierarchy/topology
    so the defaults reduce exactly to :func:`cluster_shapes` order."""
    if max_wafers < 1:
        raise ValueError(f"max_wafers must be ≥ 1, got {max_wafers}")
    for t in inter_topologies:
        if t not in INTER_TOPOLOGIES:
            raise ValueError(f"unknown inter topology {t!r}; expected "
                             f"a subset of {INTER_TOPOLOGIES}")
    out: List[Tuple[int, Tuple[int, int], Tuple[int, ...], str]] = []
    for w in range(1, max_wafers + 1):
        for s in shape_fn(n_npus):
            if w == 1:
                out.append((1, s, (1,), ""))
                continue
            for hier in hierarchy_specs(w, max_levels):
                for topo in inter_topologies:
                    out.append((w, s, hier, topo))
    return out


def _expand_ep_sp(st: Strategy, ep_candidates: Sequence[int],
                  sp_candidates: Sequence[int]) -> List[Strategy]:
    """``st`` followed by its valid (ep, sp) variants: ep must divide the
    per-wafer DP degree (EP groups stay within a wafer) and sp must divide
    mp (SP splits activations across MP peers).  The base (ep=1, sp=1)
    point is never duplicated, so the default candidates ``(1,)`` return
    ``[st]`` — bit-identical enumeration order."""
    out = [st]
    for ep in ep_candidates:
        for sp in sp_candidates:
            if ep == 1 and sp == 1:
                continue
            if ep > 1 and st.dp_per_wafer % ep != 0:
                continue
            if sp > 1 and st.mp % sp != 0:
                continue
            out.append(dataclasses.replace(st, ep=ep, sp=sp))
    return out


def strategy_space(n_npus: int, n_layers: Optional[int] = None,
                   min_utilization: float = 0.9,
                   n_wafers: int = 1,
                   ep_candidates: Sequence[int] = (1,),
                   sp_candidates: Sequence[int] = (1,)) -> List[Strategy]:
    """All (mp, dp, pp) with mp·dp·pp ≤ n_npus and utilization ≥ the floor.

    ``n_layers`` (when given) keeps only pp that divide the layer count —
    GPipe stages must hold whole layers.  Deterministic order: descending
    worker count, then (mp, dp, pp) lexicographic.

    ``n_wafers > 1`` adds the wafer axis: after each base triple, the
    wafer-split variants ``Strategy(mp, dp, pp, wafers=w)`` for every
    2 ≤ w ≤ n_wafers dividing dp (DP replicas map whole onto wafers;
    per-wafer capacity is checked later, at placement/sweep time).

    ``ep_candidates``/``sp_candidates`` expand each emitted strategy with
    its valid expert-/sequence-parallel variants (:func:`_expand_ep_sp`);
    the defaults ``(1,)`` keep the 5-axis space byte-identical."""
    floor = max(1, int(min_utilization * n_npus))
    out = []
    for used in range(n_npus, floor - 1, -1):
        for mp, rest in ((m, used // m) for m in range(1, used + 1)
                         if used % m == 0):
            for dp, pp in ((d, rest // d) for d in range(1, rest + 1)
                           if rest % d == 0):
                if n_layers is not None and n_layers % pp != 0:
                    continue
                out.extend(_expand_ep_sp(Strategy(mp, dp, pp),
                                         ep_candidates, sp_candidates))
                for wf in range(2, n_wafers + 1):
                    if dp % wf == 0:
                        out.extend(_expand_ep_sp(
                            Strategy(mp, dp, pp, wafers=wf),
                            ep_candidates, sp_candidates))
    return out


# --------------------------------------------------------------------------
# canonical-form dedup (symmetry pruning)
# --------------------------------------------------------------------------

def sim_signature(st: Strategy, w: Workload) -> Tuple:
    """Canonical form of a divisor triple: the exact inputs
    :meth:`Simulator.run` reads for ``w`` under ``st``.

    Two strategies with equal signatures produce bit-identical Breakdowns
    (and sweep objectives) on *any* fabric/shape, so the sweep simulates
    only one representative per signature and replicates the result.

    Note the often-assumed mp↔dp swap symmetry does NOT hold in this
    model — (mp=9, dp=2) and (mp=2, dp=9) differ in compute shard, MP
    collective group, DP gradient bytes AND both Pareto objectives
    (tests/test_autostrategy.py pins a numeric counterexample) — which is
    exactly why the dedup keys on the simulation inputs instead of a
    syntactic (sorted-triple) canonical form: pruning can never change
    the Pareto front, only skip provably redundant simulator calls.
    """
    layers_per_stage = -(-w.n_layers // st.pp)
    microbatches = 8 if (st.pp > 1 and w.execution == "stationary") else \
        max(st.pp, 1)
    act_bytes = w.act_bytes_per_sample * w.samples_per_dp
    # components are guarded exactly as Simulator.run guards the terms, so
    # a skipped term contributes nothing to the canonical form
    ep_active = st.ep > 1 and w.a2a_bytes_per_sample_layer > 0
    mp_ar = w.mp_allreduce_per_layer
    if ep_active and mp_ar:       # the A2A subsumes one MP sync (run())
        mp_ar = mp_ar - 1
    mp_term = (st.mp, st.dp * st.pp, act_bytes, mp_ar) \
        if (st.mp > 1 and mp_ar) else None
    pp_term = (act_bytes, microbatches, st.pp, st.sp) if st.pp > 1 else None
    dp_term = ((st.dp, st.mp, st.pp, w.params_per_layer / st.mp)
               if (st.dp > 1 and w.execution == "stationary") else None)
    ep_term = ((st.ep, st.mp * st.pp,
                st.mp * st.pp * st.dp // (st.ep * st.wafers),
                w.a2a_bytes_per_sample_layer * w.samples_per_dp)
               if ep_active else None)
    stream_term = ((w.param_bytes_total / st.pp,
                    w.minibatch * w.act_bytes_per_sample)
                   if w.execution == "streaming" else None)
    return (
        w.name, w.execution, st.wafers,
        # compute: per-NPU FLOPs share and pipeline pacing
        w.flops_fwd_per_sample_layer * w.samples_per_dp / st.mp,
        layers_per_stage, microbatches,
        mp_term, pp_term, dp_term, ep_term, stream_term,
        # normalizers / objectives (incl. the memory-model inputs: seq,
        # per-MP-shard layer params, KV bytes, the EP/SP memory factors —
        # exact under any MemoryModel)
        w.samples_per_dp, w.minibatch, w.seq,
        w.params_per_layer / st.mp, w.kv_bytes_per_sample_layer,
        w.param_bytes_total / (st.mp * st.pp),
        st.ep, st.sp, w.expert_param_fraction,
    )


# --------------------------------------------------------------------------
# sweep
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    fabric: str
    shape: Tuple[int, int]            # (rows, cols) or (n_groups, group_size)
    strategy: Strategy
    breakdown: Breakdown
    minibatch: int
    param_bytes_per_npu: float
    routable: Optional[bool] = None   # FRED only, when check_routing=True
    pareto: bool = False
    n_wafers: int = 1                 # wafers in the cluster (shape is
                                      # per wafer; total NPUs scale with it)
    inter_wafer_bw: float = 0.0       # aggregate wafer↔wafer B/s (0 ≡ n/a)
    memory_bytes_per_npu: float = 0.0  # per-NPU footprint under the sweep's
                                       # MemoryModel (0 when none given)
    feasible: Optional[bool] = None    # fits npu_hbm_bytes; None = not
                                       # evaluated (no MemoryModel)
    hierarchy: Tuple[int, ...] = (1,)  # inter-level counts, innermost
                                       # first ((4,) = flat ring of 4
                                       # wafers, (2, 2) = rack×pod)
    inter_topology: str = ""           # ring | fully_connected | switch;
                                       # "" on a single wafer
    defect_rate: float = 0.0           # dead-NPU fraction of the sweep's
                                       # DefectMask (0.0 = defect-free)
    defect_seed: int = -1              # mask sampler seed; -1 = no mask
                                       # (or a hand-built one)
    degraded_time_s: float = 0.0       # breakdown.total under the mask;
                                       # 0.0 on a defect-free sweep

    @property
    def total(self) -> float:
        return self.breakdown.total

    @property
    def time_per_sample(self) -> float:
        return self.breakdown.total / max(self.minibatch, 1)

    @property
    def n_npus(self) -> int:
        return self.shape[0] * self.shape[1] * self.n_wafers


def scaled_n_io(n_npus: int) -> int:
    """I/O controllers at the paper's per-NPU density (18 on 20), kept ≥ 1.
    Used for EVERY fabric in the sweep so cross-fabric comparisons share
    one I/O budget (at 20 NPUs this equals the 5×4 mesh's border-derived
    18, so the paper point is unchanged)."""
    return max(1, round(18 * n_npus / 20))


def _simulator(fabric: str, shape: Tuple[int, int], n_npus: int,
               cache: dict, compute_efficiency: float,
               n_wafers: int = 1,
               hierarchy: Optional[Tuple[int, ...]] = None,
               inter_topology: str = "",
               defects: Optional[DefectMask] = None,
               comm_overlap_fraction: float = 0.0,
               **inter_kw) -> Simulator:
    """``n_npus`` is per wafer; ``inter_kw`` forwards the inter-wafer link
    parameters (inter_wafer_links/bw/latency) when n_wafers > 1, and
    ``hierarchy``/``inter_topology`` shape the inter levels (single ring
    level when unset — the PR-2 model).  Construction goes through the
    consolidated FabricSpec/ClusterSpec API (core/specs.py)."""
    spec = FabricSpec(
        mesh_shape=shape if fabric == "baseline" else None,
        fred_shape=None if fabric == "baseline" else shape,
        n_io=scaled_n_io(n_npus), defects=defects)
    cluster_spec = None
    if n_wafers > 1:
        ckw = dict(n_wafers=n_wafers, **inter_kw)
        if hierarchy is not None:
            ckw["hierarchy"] = hierarchy
        if inter_topology:
            ckw["inter_topology"] = inter_topology
        cluster_spec = ClusterSpec(**ckw)
    return Simulator(fabric, compute_efficiency=compute_efficiency,
                     spec=spec, cluster_spec=cluster_spec,
                     collective_cache=cache,
                     comm_overlap_fraction=comm_overlap_fraction)


def sweep(workload_fn: Callable[[Strategy], Workload], n_npus: int,
          fabrics: Sequence[str] = ("baseline", "FRED-C", "FRED-D"),
          strategies: Optional[Sequence[Strategy]] = None,
          n_layers: Optional[int] = None,
          min_utilization: float = 0.9,
          check_routing: bool = False,
          compute_efficiency: float = 0.45,
          max_wafers: int = 1,
          inter_wafer_links: int = 32,
          inter_wafer_bw: float = 400e9,
          inter_wafer_latency: float = 5e-7,
          inter_topologies: Sequence[str] = ("ring",),
          max_levels: int = 1,
          memory: Optional[MemoryModel] = None,
          prune_symmetric: bool = False,
          engine: str = "batched",
          defects: Optional[DefectMask] = None,
          ep_candidates: Sequence[int] = (1,),
          sp_candidates: Sequence[int] = (1,),
          comm_overlap_fraction: float = 0.0) -> List[SweepResult]:
    """Run the full (fabric × wafer shape × wafer count × strategy)
    cross-product.

    ``workload_fn`` builds the workload for a candidate strategy (the
    minibatch scales with DP, so the workload is strategy-dependent).
    One memo dict spans the whole sweep — collective times are keyed by
    the fabric's physical identity (Simulator._fabric_tag), so strategies
    sharing a collective on the same wafer hit the cache while distinct
    fabrics/shapes never collide.  Pareto flags are set per fabric on
    (time_per_sample, param_bytes_per_npu).

    ``n_npus`` is per wafer; ``max_wafers > 1`` adds clusters of 2..max
    wafers joined by ``inter_wafer_links × inter_wafer_bw`` links (see
    core/cluster.py), with DP replicas placed across wafers and
    wafer-split strategies tagged ``Strategy.wafers``.  ``max_wafers=1``
    (the default) is bit-identical to the single-wafer sweep.

    ``inter_topologies`` crosses every multi-wafer configuration with the
    listed inter-level collective models (ring / fully_connected /
    switch — core/cluster.py), and ``max_levels=2`` additionally sweeps
    the rack/pod stackings of each wafer count (:func:`hierarchy_specs`:
    4 wafers → flat (4,) and (2, 2)); every level shares the
    ``inter_wafer_*`` link budget.  The defaults (ring, 1 level) are
    bit-identical to the PR-2 sweep, row for row.

    FRED routability (``check_routing=True``) is checked per (strategy,
    shape): the memo is keyed on both, and the actual (n_groups,
    group_size) shape is passed to :func:`repro.core.routing
    .strategy_routable` — for clusters, the per-wafer sub-strategy is
    what must route on the wafer switch.

    ``memory`` (a :class:`~repro.core.workloads.MemoryModel`) turns on the
    per-NPU memory-feasibility objective: every result carries
    ``memory_bytes_per_npu`` and ``feasible``, and the Pareto front is
    computed on (time_per_sample, memory_bytes_per_npu) over *feasible*
    points only — an infeasible strategy is never flagged pareto.

    ``prune_symmetric`` dedupes candidate strategies by canonical
    simulation signature (:func:`sim_signature`) before simulating and
    replicates results onto the pruned twins, so the returned point set
    and Pareto front are identical to the unpruned sweep by construction
    (pinned at 20 NPUs in tests/test_autostrategy.py).

    ``engine`` selects the evaluator: ``"batched"`` (the default)
    evaluates all strategies of each (fabric, shape, wafer count) as
    vectorized NumPy ops via :class:`repro.core.batch_engine.BatchEngine`
    — with the memory model vectorized alongside, so feasibility is
    masked in array math before any per-point Python runs — while
    ``"scalar"`` walks :meth:`Simulator.run` per point as the reference
    oracle.  Both produce bit-identical Breakdowns and Pareto fronts
    (enforced by hypothesis property tests in tests/test_batch_engine.py);
    batched is ≥10× faster on multi-wafer sweeps and is what makes
    exhaustive 500+-NPU sweeps fit the CI budget (BENCH_sweep.json).

    ``defects`` (a :class:`~repro.core.defects.DefectMask`, applied to
    every wafer) evaluates the whole sweep under the mask: placement
    compacts onto healthy NPUs, mesh rings detour dead links, FRED spine
    bandwidth shrinks with severed uplinks, and candidates needing more
    healthy NPUs per wafer than the mask leaves are skipped.  Results
    carry ``defect_rate``/``defect_seed``/``degraded_time_s``; a None (or
    empty) mask is bit-identical to the defect-free sweep.

    ``ep_candidates``/``sp_candidates`` expand each enumerated strategy
    with expert- and sequence-parallel variants (see
    :func:`strategy_space`); the defaults (1,)/(1,) are bit-identical to
    the 5-axis sweep.  ``comm_overlap_fraction`` sets the Simulator's
    compute/communication overlap knob for every evaluated point (0.0,
    the default, is the fully-exposed PR-7 model)."""
    if n_npus < 1:
        raise ValueError(f"n_npus must be ≥ 1, got {n_npus}")
    defects = normalize(defects)
    if defects is not None and defects.n_npus != n_npus:
        raise ValueError(
            f"defect mask covers {defects.n_npus} NPUs but the sweep's "
            f"wafer has {n_npus}")
    n_healthy = n_npus if defects is None else defects.n_healthy
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")
    if not 1 <= max_levels <= 2:
        raise ValueError(f"max_levels must be 1 or 2 (wafer → rack → "
                         f"pod), got {max_levels}")
    # explicitly passed strategies always run: widen the wafer-count
    # enumeration to cover the largest split they ask for
    if strategies:
        max_wafers = max(max_wafers, max(st.wafers for st in strategies))
    # strategy space per wafer count (the utilization floor applies to the
    # cluster's total NPU count); strategy_space emits the wafer-split
    # variants, the per-shape capacity check happens in the loop below
    space: Dict[int, Sequence[Strategy]] = {}
    if strategies is None:
        for wf in range(1, max_wafers + 1):
            # under a defect mask the wafer only offers its healthy NPUs —
            # the utilization floor (and the enumeration ceiling) anchor
            # to the degraded capacity, so a 2%-dead wafer still sweeps
            # near-full strategies instead of returning nothing
            space[wf] = [st for st in
                         strategy_space(wf * n_healthy, n_layers=n_layers,
                                        min_utilization=min_utilization,
                                        n_wafers=wf,
                                        ep_candidates=ep_candidates,
                                        sp_candidates=sp_candidates)
                         if st.wafers == wf]
    results: List[SweepResult] = []
    cache = LRUCache(COLLECTIVE_CACHE_SIZE)
    route_memo: Dict[Tuple[Strategy, Tuple[int, int], int], bool] = {}
    inter_kw = dict(inter_wafer_links=inter_wafer_links,
                    inter_wafer_bw=inter_wafer_bw,
                    inter_wafer_latency=inter_wafer_latency)
    agg_inter_bw = inter_wafer_links * inter_wafer_bw

    # the valid candidate list, its symmetry-pruned representatives, the
    # packed parameter tensors, and the vectorized memory predicate are
    # all fabric/shape-independent — build them once per wafer count and
    # reuse across every (fabric, shape) below (workload_fn is assumed
    # pure: it was already called per (fabric, shape) with the same
    # strategy before this memo existed)
    per_wf: Dict[int, Tuple] = {}

    def _candidates(wf: int):
        ent = per_wf.get(wf)
        if ent is not None:
            return ent
        cands = ([st for st in strategies if st.wafers == wf]
                 if strategies is not None else space[wf])
        evals: List[Tuple[Strategy, Workload]] = []
        for st in cands:
            if st.n_workers > wf * n_npus or \
                    st.dp % st.wafers != 0 or \
                    st.mp * st.pp * (st.dp // st.wafers) > n_healthy:
                continue
            w = workload_fn(st)
            if st.pp > w.n_layers:        # stages must hold whole layers
                continue
            evals.append((st, w))
        # canonical-form dedup: one simulation per signature per
        # (fabric, shape, wafer count); twins replicate the breakdown
        if prune_symmetric:
            sig_index: Dict[Tuple, int] = {}
            rep_of: List[int] = []
            rep_idx: List[int] = []
            for i, (st, w) in enumerate(evals):
                sig = sim_signature(st, w)
                j = sig_index.get(sig)
                if j is None:
                    j = len(rep_idx)
                    sig_index[sig] = j
                    rep_idx.append(i)
                rep_of.append(j)
        else:
            rep_idx = list(range(len(evals)))
            rep_of = rep_idx
        rep_pack = mem_list = feas_list = None
        if engine == "batched":
            from .batch_engine import CandidateBatch, feasible_batch
            pack = CandidateBatch([w for _st, w in evals])
            rep_pack = (pack.take(rep_idx)
                        if len(rep_idx) != len(evals) else pack)
            if memory is not None:
                # vectorized feasibility — infeasible points are masked
                # on arrays before any per-point Python runs; bulk-
                # converted to Python scalars once per wafer count
                mem_arr, feas_arr = feasible_batch(pack, memory)
                mem_list = mem_arr.tolist()
                feas_list = feas_arr.tolist()
        ent = (evals, rep_idx, rep_of, rep_pack, mem_list, feas_list)
        per_wf[wf] = ent
        return ent

    def _emit(fabric, wf, shape, hier, topo, sim, evals, rep_of, rep_brs,
              mem_list, feas_list):
        """One SweepResult row per candidate of this (fabric, shape,
        wafer count, hierarchy, inter topology) — shared by both engines
        so row order, Pareto and CSV output are engine-independent.
        Construction bypasses the dataclass __init__ — this loop runs
        once per sweep point and is the hottest shared Python in a
        500+-NPU sweep."""
        check_route = check_routing and fabric != "baseline"
        inter_bw = agg_inter_bw if wf > 1 else 0.0
        defect_rate = 0.0 if defects is None else defects.dead_npu_rate
        defect_seed = -1 if defects is None else defects.seed
        new = SweepResult.__new__
        for i, (st, w) in enumerate(evals):
            mem_bytes = 0.0
            feas: Optional[bool] = None
            if memory is not None:
                if mem_list is not None:
                    mem_bytes = mem_list[i]
                    feas = feas_list[i]
                else:
                    mem_bytes = memory_bytes_per_npu(w, memory)
                    feas = is_feasible(w, memory)
            routable = None
            if check_route:
                # uplink count depends on the FRED config, so it is
                # part of the memo key alongside (strategy, shape)
                up = sim.fred.uplinks_per_l1()
                key = (st, shape, up)
                if key not in route_memo:
                    from .routing import strategy_routable
                    sub = st if st.wafers == 1 else \
                        Strategy(st.mp, st.dp // st.wafers, st.pp)
                    route_memo[key] = strategy_routable(sub, shape,
                                                        uplinks=up,
                                                        defects=defects)
                routable = route_memo[key]
            br = rep_brs[rep_of[i]]
            r = new(SweepResult)
            r.__dict__ = {
                "fabric": fabric, "shape": shape, "strategy": st,
                "breakdown": br,
                "minibatch": w.minibatch,
                "param_bytes_per_npu": w.param_bytes_total /
                (st.mp * st.pp),
                "routable": routable, "pareto": False, "n_wafers": wf,
                "inter_wafer_bw": inter_bw,
                "memory_bytes_per_npu": mem_bytes, "feasible": feas,
                "hierarchy": hier, "inter_topology": topo,
                "defect_rate": defect_rate, "defect_seed": defect_seed,
                "degraded_time_s": (0.0 if defects is None
                                    else br.total)}
            results.append(r)

    for fabric in fabrics:
        shape_fn = mesh_shapes if fabric == "baseline" else fred_shapes
        configs = hierarchy_configs(n_npus, max_wafers, shape_fn,
                                    inter_topologies, max_levels)
        if defects is not None and fabric == "baseline":
            # a mesh shape whose healthy sub-mesh the mask disconnects
            # cannot host collectives at all — drop it (FRED trees stay
            # connected through the spine for any placeable mask)
            from .defects import mesh_connected
            configs = [c for c in configs
                       if mesh_connected(defects, c[1][0], c[1][1])]
        if engine == "batched":
            import numpy as np
            from .batch_engine import BatchEngine, CandidateBatch, InterLane
            # fuse configurations into as few vectorized runs as the
            # kernels allow: the wafer count, hierarchy spans and inter
            # topology are all per-lane inputs (InterLane), so every
            # (wafer count, hierarchy, topology) of a shape shares one
            # run; FRED shapes additionally fuse across shapes
            # (group_size is the only shape-dependent kernel input,
            # passed per lane)
            if fabric == "baseline":
                by_shape: Dict[Tuple[int, int], List] = {}
                for c in configs:
                    by_shape.setdefault(c[1], []).append(c)
                grp_list = list(by_shape.values())
            else:
                grp_list = [configs]
            brs_by_config: Dict[Tuple, list] = {}
            sim_by_config: Dict[Tuple, Simulator] = {}
            for grp in grp_list:
                max_wf = max(c[0] for c in grp)
                # one single-level cluster serves every fused lane: the
                # sweep's levels share one link budget, and the per-lane
                # InterLane carries each configuration's topology/spans
                sim = _simulator(fabric, grp[0][1], n_npus, cache,
                                 compute_efficiency, n_wafers=max_wf,
                                 defects=defects,
                                 comm_overlap_fraction=comm_overlap_fraction,
                                 **inter_kw)
                parts, gs_parts, il_parts, metas = [], [], [], []
                for wf, shape, hier, topo in grp:
                    _e, _ri, _ro, rep_pack, _m, _f2 = _candidates(wf)
                    parts.append(rep_pack)
                    metas.append(((wf, shape, hier, topo), len(rep_pack)))
                    il_parts.append(InterLane.for_config(
                        len(rep_pack), wf, hier if wf > 1 else (), topo))
                    if fabric != "baseline":
                        gs_parts.append(np.full(len(rep_pack), shape[1],
                                                dtype=np.int64))
                fused = CandidateBatch.concat(parts)
                gs_lane = np.concatenate(gs_parts) if gs_parts else None
                il_lane = (InterLane.concat(il_parts) if max_wf > 1
                           else None)
                brs = BatchEngine(sim).run_batch(fused, gs_lane=gs_lane,
                                                 inter_lane=il_lane)
                off = 0
                for key, nrep in metas:
                    brs_by_config[key] = brs[off:off + nrep]
                    sim_by_config[key] = sim
                    off += nrep
            # emit in the same configuration order as the scalar engine
            # so row order, Pareto and CSV are engine-independent
            for key in configs:
                wf, shape, hier, topo = key
                evals, _ri, rep_of, _rp, mem_arr, feas_arr = \
                    _candidates(wf)
                _emit(fabric, wf, shape, hier, topo, sim_by_config[key],
                      evals, rep_of, brs_by_config[key],
                      mem_arr, feas_arr)
        else:
            for wf, shape, hier, topo in configs:
                sim = _simulator(fabric, shape, n_npus, cache,
                                 compute_efficiency, n_wafers=wf,
                                 hierarchy=hier if wf > 1 else None,
                                 inter_topology=topo, defects=defects,
                                 comm_overlap_fraction=comm_overlap_fraction,
                                 **inter_kw)
                evals, rep_idx, rep_of, _rp, mem_arr, feas_arr = \
                    _candidates(wf)
                rep_brs = [sim.run(evals[i][1]) for i in rep_idx]
                _emit(fabric, wf, shape, hier, topo, sim, evals, rep_of,
                      rep_brs, mem_arr, feas_arr)
    # dict.fromkeys, not set(): first-seen order is deterministic across
    # processes, so the pareto flag assignment (and the CSV row order any
    # golden diff sees) cannot depend on PYTHONHASHSEED
    for fabric in dict.fromkeys(r.fabric for r in results):
        subset = [r for r in results if r.fabric == fabric]
        if memory is not None:
            # infeasible points never make the front; the memory objective
            # replaces the weight-only param_bytes proxy
            front = pareto_front([r for r in subset if r.feasible],
                                 keys=("time_per_sample",
                                       "memory_bytes_per_npu"))
        else:
            front = pareto_front(subset)
        for r in front:
            r.pareto = True
    return results


# --------------------------------------------------------------------------
# Pareto reporting
# --------------------------------------------------------------------------

def pareto_front(results: Sequence[SweepResult],
                 keys: Tuple[str, str] = ("time_per_sample",
                                          "param_bytes_per_npu")
                 ) -> List[SweepResult]:
    """Results not dominated on the (minimize, minimize) objective pair.

    Sort-based O(n log n) scan (cluster sweeps multiply point counts):
    sorted by the first key, a point survives iff its second key is the
    minimum within its first-key tie group AND strictly below every
    earlier group's minimum.  Exact duplicates don't dominate each other,
    so they all survive together; input order is preserved."""
    n = len(results)
    vals = list(map(operator.attrgetter(*keys), results))
    order = sorted(range(n), key=vals.__getitem__)
    keep = [False] * n
    best2 = float("inf")            # min 2nd key over strictly-lower groups
    i = 0
    while i < n:
        j = i
        while j < n and vals[order[j]][0] == vals[order[i]][0]:
            j += 1
        group = order[i:j]
        gmin = min(vals[idx][1] for idx in group)
        if gmin < best2:
            for idx in group:
                if vals[idx][1] == gmin:
                    keep[idx] = True
            best2 = gmin
        i = j
    return [r for r, k in zip(results, keep) if k]


CSV_HEADER = ("workload,fabric,shape_a,shape_b,n_wafers,n_npus,"
              "inter_wafer_bw,hierarchy,inter_topology,mp,dp,pp,ep,sp,"
              "minibatch,"
              "compute_s,input_load_s,mp_s,ep_s,dp_s,dp_intra_s,dp_inter_s,"
              "dp_level_1_s,dp_level_2_s,"
              "pp_s,stream_s,exposed_comm_s,total_s,"
              "time_per_sample_s,param_bytes_per_npu,"
              "memory_bytes_per_npu,feasible,routable,pareto,"
              "defect_rate,defect_seed,degraded_time_s")


def to_csv_rows(results: Sequence[SweepResult]) -> List[str]:
    """One row per sweep point; schema in benchmarks/README.md.  shape_a/b
    are rows/cols (baseline) or n_groups/group_size (FRED), per wafer;
    n_npus = shape_a·shape_b·n_wafers; hierarchy is the level stacking
    ("4" = flat, "2x2" = rack×pod) and dp_level_1_s/dp_level_2_s the raw
    per-inter-level DP time (0 where a level is absent)."""
    rows = []
    for r in results:
        br = r.breakdown
        lv = br.dp_levels + (0.0, 0.0)
        rows.append(
            f"{br.workload},{r.fabric},{r.shape[0]},{r.shape[1]},"
            f"{r.n_wafers},{r.n_npus},{r.inter_wafer_bw:.9g},"
            f"{'x'.join(map(str, r.hierarchy))},{r.inter_topology},"
            f"{r.strategy.mp},{r.strategy.dp},{r.strategy.pp},"
            f"{r.strategy.ep},{r.strategy.sp},"
            f"{r.minibatch},"
            f"{br.compute:.9g},{br.input_load:.9g},{br.mp:.9g},"
            f"{br.ep_s:.9g},"
            f"{br.dp:.9g},{br.dp_intra:.9g},{br.dp_inter:.9g},"
            f"{lv[0]:.9g},{lv[1]:.9g},"
            f"{br.pp:.9g},{br.stream:.9g},{br.exposed_comm_s:.9g},"
            f"{br.total:.9g},"
            f"{r.time_per_sample:.9g},{r.param_bytes_per_npu:.9g},"
            f"{r.memory_bytes_per_npu:.9g},"
            f"{'' if r.feasible is None else int(r.feasible)},"
            f"{'' if r.routable is None else int(r.routable)},"
            f"{int(r.pareto)},"
            f"{r.defect_rate:.9g},{r.defect_seed},"
            f"{r.degraded_time_s:.9g}")
    return rows


# --------------------------------------------------------------------------
# canonical workload templates
# --------------------------------------------------------------------------

def transformer_17b(strategy: Strategy) -> Workload:
    """Turing-NLG 17B (Table V) parameterized by strategy — the paper's
    Fig. 2 co-exploration subject."""
    return transformer("Transformer-17B", 78, 4256, 1024, strategy,
                       "stationary")


def transformer_17b_sweep(n_npus: int = 20, **kw) -> List[SweepResult]:
    """The headline sweep: Transformer-17B over every strategy and wafer
    shape at ``n_npus``."""
    return sweep(transformer_17b, n_npus, n_layers=78, **kw)
