"""Strategy/topology sweep engine (LIBRA/WATOS-style co-exploration).

The paper's core claim is that FRED stays efficient across *arbitrary*
parallelization strategies; this module makes that explorable.  For a given
NPU count it enumerates

  * every valid (mp, dp, pp) 3D-parallel strategy (divisor triples, with
    an optional utilization floor so near-full wafers count too — the
    paper's Transformer-17B uses 18 of 20 NPUs), and
  * every wafer shape realizing that NPU count: rows×cols meshes for the
    baseline, n_groups×group_size almost-fat-trees for FRED,

then runs :class:`repro.core.simulator.Simulator` over the cross-product.
Collective times are memoized per (fabric, shape) — strategies share
collective calls heavily (the same wafer-wide or per-group All-Reduce
appears in many strategies), so the sweep is near-free beyond the first
strategy per group shape.

Reporting: :func:`pareto_front` extracts the strategies not dominated on
(time-per-sample, parameter-bytes-per-NPU) — the throughput/memory
trade-off DP replication buys — and :func:`to_csv_rows` emits the schema
documented in ``benchmarks/README.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .placement import Strategy
from .simulator import Breakdown, Simulator
from .workloads import Workload, transformer

FABRICS = ("baseline", "FRED-A", "FRED-B", "FRED-C", "FRED-D")


# --------------------------------------------------------------------------
# search spaces
# --------------------------------------------------------------------------

def factor_pairs(n: int) -> List[Tuple[int, int]]:
    """(a, b) with a·b = n and a ≥ b (orientation is symmetric for both
    fabric models)."""
    out = []
    b = 1
    while b * b <= n:
        if n % b == 0:
            out.append((n // b, b))
        b += 1
    return out


def mesh_shapes(n_npus: int) -> List[Tuple[int, int]]:
    """rows×cols meshes realizing ``n_npus`` (degenerate 1×N included —
    the model handles it; the sweep ranks it out on its own merits)."""
    return factor_pairs(n_npus)


def fred_shapes(n_npus: int) -> List[Tuple[int, int]]:
    """n_groups×group_size almost-fat-trees realizing ``n_npus``.  The
    single-group shape (1, n) is a pure crossbar — valid but excluded:
    the 2-level tree needs ≥ 2 L1 groups."""
    out: List[Tuple[int, int]] = []
    for a, b in factor_pairs(n_npus):
        for g, k in ((b, a), (a, b)):       # narrow groups first
            if g >= 2 and (g, k) not in out:
                out.append((g, k))
    return out


def strategy_space(n_npus: int, n_layers: Optional[int] = None,
                   min_utilization: float = 0.9) -> List[Strategy]:
    """All (mp, dp, pp) with mp·dp·pp ≤ n_npus and utilization ≥ the floor.

    ``n_layers`` (when given) keeps only pp that divide the layer count —
    GPipe stages must hold whole layers.  Deterministic order: descending
    worker count, then (mp, dp, pp) lexicographic."""
    floor = max(1, int(min_utilization * n_npus))
    out = []
    for used in range(n_npus, floor - 1, -1):
        for mp, rest in ((m, used // m) for m in range(1, used + 1)
                         if used % m == 0):
            for dp, pp in ((d, rest // d) for d in range(1, rest + 1)
                           if rest % d == 0):
                if n_layers is not None and n_layers % pp != 0:
                    continue
                out.append(Strategy(mp, dp, pp))
    return out


# --------------------------------------------------------------------------
# sweep
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    fabric: str
    shape: Tuple[int, int]            # (rows, cols) or (n_groups, group_size)
    strategy: Strategy
    breakdown: Breakdown
    minibatch: int
    param_bytes_per_npu: float
    routable: Optional[bool] = None   # FRED only, when check_routing=True
    pareto: bool = False

    @property
    def total(self) -> float:
        return self.breakdown.total

    @property
    def time_per_sample(self) -> float:
        return self.breakdown.total / max(self.minibatch, 1)


def scaled_n_io(n_npus: int) -> int:
    """I/O controllers at the paper's per-NPU density (18 on 20), kept ≥ 1.
    Used for EVERY fabric in the sweep so cross-fabric comparisons share
    one I/O budget (at 20 NPUs this equals the 5×4 mesh's border-derived
    18, so the paper point is unchanged)."""
    return max(1, round(18 * n_npus / 20))


def _simulator(fabric: str, shape: Tuple[int, int], n_npus: int,
               cache: dict, compute_efficiency: float) -> Simulator:
    if fabric == "baseline":
        return Simulator(fabric, compute_efficiency=compute_efficiency,
                         mesh_shape=shape, n_io=scaled_n_io(n_npus),
                         collective_cache=cache)
    return Simulator(fabric, compute_efficiency=compute_efficiency,
                     fred_shape=shape, n_io=scaled_n_io(n_npus),
                     collective_cache=cache)


def sweep(workload_fn: Callable[[Strategy], Workload], n_npus: int,
          fabrics: Sequence[str] = ("baseline", "FRED-C", "FRED-D"),
          strategies: Optional[Sequence[Strategy]] = None,
          n_layers: Optional[int] = None,
          min_utilization: float = 0.9,
          check_routing: bool = False,
          compute_efficiency: float = 0.45) -> List[SweepResult]:
    """Run the full (fabric × shape × strategy) cross-product.

    ``workload_fn`` builds the workload for a candidate strategy (the
    minibatch scales with DP, so the workload is strategy-dependent).
    One memo dict spans the whole sweep — collective times are keyed by
    the fabric's physical identity (Simulator._fabric_tag), so strategies
    sharing a collective on the same wafer hit the cache while distinct
    fabrics/shapes never collide.  Pareto flags are set per fabric on
    (time_per_sample, param_bytes_per_npu)."""
    if n_npus < 1:
        raise ValueError(f"n_npus must be ≥ 1, got {n_npus}")
    if strategies is None:
        strategies = strategy_space(n_npus, n_layers=n_layers,
                                    min_utilization=min_utilization)
    results: List[SweepResult] = []
    cache: dict = {}
    route_memo: Dict[Strategy, bool] = {}   # routability is shape-agnostic
    for fabric in fabrics:
        shapes = mesh_shapes(n_npus) if fabric == "baseline" \
            else fred_shapes(n_npus)
        for shape in shapes:
            sim = _simulator(fabric, shape, n_npus, cache,
                             compute_efficiency)
            for st in strategies:
                if st.n_workers > sim.n_npus:
                    continue
                w = workload_fn(st)
                br = sim.run(w)
                routable = None
                if check_routing and fabric != "baseline":
                    if st not in route_memo:
                        from .routing import strategy_routable
                        route_memo[st] = strategy_routable(st, n_npus)
                    routable = route_memo[st]
                results.append(SweepResult(
                    fabric=fabric, shape=shape, strategy=st, breakdown=br,
                    minibatch=w.minibatch,
                    param_bytes_per_npu=w.param_bytes_total /
                    (st.mp * st.pp),
                    routable=routable))
    for fabric in set(r.fabric for r in results):
        subset = [r for r in results if r.fabric == fabric]
        for r in pareto_front(subset):
            r.pareto = True
    return results


# --------------------------------------------------------------------------
# Pareto reporting
# --------------------------------------------------------------------------

def pareto_front(results: Sequence[SweepResult],
                 keys: Tuple[str, str] = ("time_per_sample",
                                          "param_bytes_per_npu")
                 ) -> List[SweepResult]:
    """Results not dominated on the (minimize, minimize) objective pair."""
    vals = [(tuple(getattr(r, k) for k in keys), r) for r in results]

    def dominated(v):
        return any(all(o <= x for o, x in zip(ov, v)) and
                   any(o < x for o, x in zip(ov, v)) for ov, _ in vals)

    return [r for v, r in vals if not dominated(v)]


CSV_HEADER = ("workload,fabric,shape_a,shape_b,n_npus,mp,dp,pp,minibatch,"
              "compute_s,input_load_s,mp_s,dp_s,pp_s,stream_s,total_s,"
              "time_per_sample_s,param_bytes_per_npu,routable,pareto")


def to_csv_rows(results: Sequence[SweepResult]) -> List[str]:
    """One row per sweep point; schema in benchmarks/README.md.  shape_a/b
    are rows/cols (baseline) or n_groups/group_size (FRED)."""
    rows = []
    for r in results:
        br = r.breakdown
        rows.append(
            f"{br.workload},{r.fabric},{r.shape[0]},{r.shape[1]},"
            f"{r.shape[0] * r.shape[1]},"
            f"{r.strategy.mp},{r.strategy.dp},{r.strategy.pp},"
            f"{r.minibatch},"
            f"{br.compute:.9g},{br.input_load:.9g},{br.mp:.9g},"
            f"{br.dp:.9g},{br.pp:.9g},{br.stream:.9g},{br.total:.9g},"
            f"{r.time_per_sample:.9g},{r.param_bytes_per_npu:.9g},"
            f"{'' if r.routable is None else int(r.routable)},"
            f"{int(r.pareto)}")
    return rows


# --------------------------------------------------------------------------
# canonical workload templates
# --------------------------------------------------------------------------

def transformer_17b(strategy: Strategy) -> Workload:
    """Turing-NLG 17B (Table V) parameterized by strategy — the paper's
    Fig. 2 co-exploration subject."""
    return transformer("Transformer-17B", 78, 4256, 1024, strategy,
                       "stationary")


def transformer_17b_sweep(n_npus: int = 20, **kw) -> List[SweepResult]:
    """The headline sweep: Transformer-17B over every strategy and wafer
    shape at ``n_npus``."""
    return sweep(transformer_17b, n_npus, n_layers=78, **kw)
