"""Batched array-programming evaluation of the analytical cost model.

The scalar reference (:meth:`repro.core.simulator.Simulator.run`) walks
one strategy at a time: it materializes a full device placement, extracts
the representative collective groups, and dispatches per-strategy Python
calls into the fabric models.  That is ~1.8 s for a 64-NPU × 4-wafer
sweep and prohibitive at 500+-NPU wafers.  This module evaluates *all*
strategies of one (fabric, wafer shape, wafer count) configuration as
vectorized NumPy ops over ``float64``/``int64`` arrays, reproducing the
scalar engine's floating-point results **bit-for-bit** by performing the
exact same IEEE-754 operations in the exact same order (pinned by the
hypothesis property tests in tests/test_batch_engine.py).

Three facts make full vectorization possible without placement surgery:

  1. Under the canonical placements (``fred_placement`` /
     ``mesh_placement`` / ``cluster_placement``) every representative
     group the simulator reads is an arithmetic progression from NPU 0:
     the first MP group is ``strided_group(mp, 1)`` and the first DP
     group (per wafer) is ``strided_group(dp_per_wafer, mp·pp)``.
  2. The only *group-dependent* inputs of the fabric models are small
     integer structures — the mesh ring's (congestion, mean X-Y hops)
     and the FRED tree's (L1 span g, max members per L1 k) — computed
     once per distinct (topology, count, stride) pattern via
     :meth:`MeshFabric.ring_structure` / :meth:`FredFabric
     .span_structure`, memoized at module level, and broadcast into the
     array math.
  3. The per-candidate workload parameters are fabric-independent, so a
     :class:`CandidateBatch` packs them into tensors once per wafer
     count and every (fabric, shape) configuration reuses the pack.

Term map onto the paper's Sec. VII cost model (and the scalar code):

  * **compute** (Sec. VII-A): per-layer FLOPs / (peak·efficiency),
    MP-sharded — ``flops · samples / mp / eff`` — times the GPipe bubble
    ``(M + S − 1)/M`` (Sec. VII-C) with M = 8 microbatches for
    weight-stationary pipelines;
  * **MP comm** (Sec. VII-B): blocking per-layer All-Reduces, fwd + bwd,
    at the fabric's effective bandwidth — mesh rings: ``2(n−1)`` steps of
    ``2(n−1)/n·D`` endpoint traffic over congested X-Y routes (the
    wafer-wide case switches to the hierarchical-2D algorithm exactly
    where ``n == rows·cols``); FRED trees: 4 fabric traversals of
    (in-network: halved) traffic (Sec. V/VIII) — with ``dp·pp/wafers``
    groups contending for the spine;
  * **EP comm** (MoE lanes with ``ep > 1``): expert dispatch/combine
    All-to-All over the ep-sized strided DP subgroup (stride mp·pp) —
    the same memoized structural tables serve the A2A, whose Table-I
    traffic equals the All-Gather's; one per-layer MP All-Reduce is
    subsumed (``mp_ar − 1``), and a ``comm_overlap_fraction`` share of
    the compute hides EP then MP time (``max(0, comm − overlap)`` per
    phase, identity ops at the 0.0 default);
  * **PP comm** (Sec. VII-C): boundary activation transfer per
    microbatch (SP shards the boundary a further ``sp``-way), exposed
    for the ``M + S − 1`` bubble slots;
  * **DP comm** (Sec. VII-B): per-layer gradient All-Reduce — on
    clusters the hierarchical RS(intra) → per-inter-level collectives →
    AG(intra) decomposition of core/cluster.py, with the level topology
    (ring / fully-connected / switch) and the spanned unit counts
    supplied per lane (:class:`InterLane`) so 1- and 2-level hierarchies
    of every topology fuse into one vectorized run — water-filled
    against the remaining backward compute.  The scalar engine
    accumulates the per-layer All-Reduce with repeated float adds; the
    batch engine replays that *iterated* sum (deduplicated over distinct
    (time, layers) tuples), because collapsing it to a multiply would
    round differently;
  * **weight streaming + input load** (Sec. III-A, VIII): model streamed
    at the wafer's sustainable I/O rate overlapped with compute + MP;
    minibatch load exposed while I/O is busy.

The engine also vectorizes the per-NPU memory-feasibility model
(:func:`repro.core.workloads.memory_bytes_per_npu`) so sweeps mask
infeasible points in array math before any per-point Python runs, and
``repro.core.sweep.sweep(engine="batched")`` rides it by default with
the scalar path retained as the reference oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .cluster import TOPOLOGY_CODES, hierarchy_spans
from .simulator import Breakdown, NPU_PEAK_FLOPS, Simulator
from .workloads import (ACT_REMAT_MULT, BYTES, MemoryModel, Workload,
                        optimizer_bytes_per_param)

# module-level structural memos — keyed by the *topology* identity only
# (mesh rows×cols / FRED group_size), so FRED-C and FRED-D of one shape,
# and every wafer count of a cluster, share entries.  Under a DefectMask
# the key additionally carries the (hashable, frozen) mask, since the
# compacted groups' structure depends on where the holes are.
_RING_STRUCTS: Dict[tuple, Tuple[int, float]] = {}
_SPAN_STRUCTS: Dict[tuple, Tuple[int, int]] = {}
_MASKED_SPAN_STRUCTS: Dict[tuple, Tuple[int, int, float]] = {}


def _f(a) -> np.ndarray:
    return np.asarray(a, dtype=np.float64)


def _unique_rows(arrs: Sequence[np.ndarray]
                 ) -> Tuple[List[Tuple[int, ...]], np.ndarray]:
    """(unique rows as int tuples, inverse indices) for parallel int64
    columns — bytewise void-view dedup, far faster than unique(axis=0)."""
    key = np.stack(arrs, axis=1)
    kv = np.ascontiguousarray(key).view(
        np.dtype((np.void, key.shape[1] * 8))).ravel()
    _, first, inv = np.unique(kv, return_index=True, return_inverse=True)
    return [tuple(r) for r in key[first].tolist()], inv


def _ring_structures_np(rows: int, cols: int, counts: np.ndarray,
                        strides: np.ndarray
                        ) -> List[Tuple[int, float]]:
    """NumPy twin of :meth:`MeshFabric.ring_structure` for a *batch* of
    strided rings on one rows×cols mesh.

    Counts the directed X-Y unit links of every ring edge with
    difference-array sweeps over four (pattern × row/col × direction)
    planes — exact integer congestion and the same ``tot / n`` mean-hops
    ratio, so each result is identical to the scalar walk (pinned in
    tests/test_batch_engine.py); a constant number of array ops covers
    every pattern of a 500-NPU sweep at once."""
    counts = np.asarray(counts, dtype=np.int64)
    strides = np.asarray(strides, dtype=np.int64)
    n_pat = len(counts)
    pid = np.repeat(np.arange(n_pat), counts)
    idx = np.arange(counts.sum()) - np.repeat(counts.cumsum() - counts,
                                              counts)
    s_rep = np.repeat(strides, counts)
    v = idx * s_rep
    nxt = np.where(idx + 1 < np.repeat(counts, counts), idx + 1, 0) * s_rep
    r0, c0 = v // cols, v % cols
    r1, c1 = nxt // cols, nxt % cols
    dh = c1 - c0
    dv = r1 - r0
    tot = np.bincount(pid, weights=_f(np.abs(dh) + np.abs(dv)),
                      minlength=n_pat)
    cong = np.zeros(n_pat, dtype=np.int64)
    # horizontal links live on row r0 (X first), vertical on column c1
    for sel, axis_idx, lo, hi, n_axes, width in (
            (dh > 0, r0, c0, c1, rows, cols),
            (dh < 0, r0, c1, c0, rows, cols),
            (dv > 0, c1, r0, r1, cols, rows),
            (dv < 0, c1, r1, r0, cols, rows)):
        if not sel.any():
            continue
        diff = np.zeros((n_pat, n_axes, width + 1), dtype=np.int64)
        np.add.at(diff, (pid[sel], axis_idx[sel], lo[sel]), 1)
        np.add.at(diff, (pid[sel], axis_idx[sel], hi[sel]), -1)
        cong = np.maximum(cong, diff.cumsum(axis=2).max(axis=(1, 2)))
    cong = np.maximum(cong, 1)
    hops = np.maximum(tot / counts, 1.0)
    return list(zip(cong.tolist(), hops.tolist()))


def _span_structures_np(group_size: int, counts: np.ndarray,
                        strides: np.ndarray) -> List[Tuple[int, int]]:
    """NumPy twin of :meth:`FredFabric.span_structure` for a batch of
    strided groups: (L1 switches spanned, max members under one L1)."""
    counts = np.asarray(counts, dtype=np.int64)
    strides = np.asarray(strides, dtype=np.int64)
    n_pat = len(counts)
    pid = np.repeat(np.arange(n_pat), counts)
    idx = np.arange(counts.sum()) - np.repeat(counts.cumsum() - counts,
                                              counts)
    l1 = (idx * np.repeat(strides, counts)) // group_size
    n_l1 = int(l1.max()) + 1
    per = np.bincount(pid * n_l1 + l1,
                      minlength=n_pat * n_l1).reshape(n_pat, n_l1)
    g = (per > 0).sum(axis=1)
    k = per.max(axis=1)
    return list(zip(g.tolist(), k.tolist()))


@dataclasses.dataclass
class InterLane:
    """Per-lane inter-level structure for fused cluster runs.

    One entry per candidate lane: how many inter levels its configuration
    stacks (``n_levels``: 0 = single wafer), the topology code of each
    level (``TOPOLOGY_CODES``), and the units spanned at each level by
    the lane's cross-wafer DP group (``span1``/``span2`` — precomputed
    via :func:`repro.core.cluster.hierarchy_spans`, 1 where a level is
    absent or not crossed).  The engine supports up to two inter levels
    (wafer → rack → pod), matching the sweep's ``max_levels`` cap."""
    n_levels: np.ndarray
    topo1: np.ndarray
    topo2: np.ndarray
    span1: np.ndarray
    span2: np.ndarray

    @classmethod
    def for_config(cls, n_lanes: int, wafers: int, counts: Sequence[int],
                   topology: str) -> "InterLane":
        """Constant lanes for one (wafer count, hierarchy, topology)
        configuration — every candidate of a sweep configuration spans
        the same ``wafers``."""
        if len(counts) > 2:
            raise NotImplementedError(
                f"batched engine supports ≤ 2 inter levels, got {counts}")
        spans = hierarchy_spans(wafers, counts) + [1, 1]
        code = TOPOLOGY_CODES[topology] if topology else 0
        full = lambda v: np.full(n_lanes, v, dtype=np.int64)
        return cls(full(len(counts)), full(code), full(code),
                   full(spans[0]), full(spans[1]))

    @classmethod
    def concat(cls, parts: Sequence["InterLane"]) -> "InterLane":
        if len(parts) == 1:
            return parts[0]
        return cls(*(np.concatenate([getattr(p, f.name) for p in parts])
                     for f in dataclasses.fields(cls)))

    def take(self, indices: Sequence[int]) -> "InterLane":
        idx = np.asarray(indices, dtype=np.int64)
        return InterLane(*(getattr(self, f.name)[idx]
                           for f in dataclasses.fields(InterLane)))


class CandidateBatch:
    """Fabric-independent per-candidate parameter tensors.

    Packs the strategy and workload scalars :meth:`Simulator.run` reads
    into ``int64``/``float64`` arrays once; the sweep builds one pack per
    wafer count and reuses it across every (fabric, shape) it visits.
    """

    _ARRAYS = ("mp", "dp", "pp", "wafers", "ep", "sp", "n_layers", "mp_ar",
               "samples", "minibatch", "seq", "params_layer", "flops",
               "abps", "pbt", "kv_layer", "a2a_layer", "expert_frac",
               "streaming")
    __slots__ = ("workloads",) + _ARRAYS

    def __init__(self, workloads: Sequence[Workload]):
        self.workloads = list(workloads)
        n = len(self.workloads)
        ints = np.empty((11, n), dtype=np.int64)
        flts = np.empty((7, n), dtype=np.float64)
        streaming = np.empty(n, dtype=bool)
        for i, w in enumerate(self.workloads):
            st = w.strategy
            ints[0, i] = st.mp
            ints[1, i] = st.dp
            ints[2, i] = st.pp
            ints[3, i] = st.wafers
            ints[4, i] = st.ep
            ints[5, i] = st.sp
            ints[6, i] = w.n_layers
            ints[7, i] = w.mp_allreduce_per_layer
            ints[8, i] = w.samples_per_dp
            ints[9, i] = w.minibatch
            ints[10, i] = w.seq
            flts[0, i] = w.params_per_layer
            flts[1, i] = w.flops_fwd_per_sample_layer
            flts[2, i] = w.act_bytes_per_sample
            flts[3, i] = w.param_bytes_total
            flts[4, i] = w.kv_bytes_per_sample_layer
            flts[5, i] = w.a2a_bytes_per_sample_layer
            flts[6, i] = w.expert_param_fraction
            streaming[i] = w.execution == "streaming"
        (self.mp, self.dp, self.pp, self.wafers, self.ep, self.sp,
         self.n_layers, self.mp_ar, self.samples, self.minibatch,
         self.seq) = ints
        (self.params_layer, self.flops, self.abps, self.pbt,
         self.kv_layer, self.a2a_layer, self.expert_frac) = flts
        self.streaming = streaming

    def __len__(self) -> int:
        return len(self.workloads)

    def take(self, indices: Sequence[int]) -> "CandidateBatch":
        """Sub-batch at ``indices`` (used to evaluate only the symmetry-
        pruned representatives)."""
        sub = object.__new__(CandidateBatch)
        sub.workloads = [self.workloads[i] for i in indices]
        idx = np.asarray(indices, dtype=np.int64)
        for name in self._ARRAYS:
            setattr(sub, name, getattr(self, name)[idx])
        return sub

    @classmethod
    def concat(cls, parts: Sequence["CandidateBatch"]) -> "CandidateBatch":
        """Fuse several packs into one lane space — the sweep evaluates
        every (shape, wafer count) configuration of a fabric in a single
        vectorized call and slices the results back per configuration."""
        if len(parts) == 1:
            return parts[0]
        fused = object.__new__(cls)
        fused.workloads = [w for p in parts for w in p.workloads]
        for name in cls._ARRAYS:
            setattr(fused, name,
                    np.concatenate([getattr(p, name) for p in parts]))
        return fused


@dataclasses.dataclass
class BatchEngine:
    """Vectorized evaluator bound to one :class:`Simulator` (one fabric ×
    wafer shape × wafer count).  ``run_batch`` maps a list of Workloads
    (each carrying its strategy) to Breakdowns bit-identical to
    ``[sim.run(w) for w in workloads]``."""

    sim: Simulator

    def __post_init__(self):
        if getattr(self.sim, "wafer_defects", None) is not None:
            raise NotImplementedError(
                "per-wafer defect masks (ClusterSpec.wafer_defects) are a "
                "scalar-Simulator feature, not a sweep axis — the batched "
                "engine only models the uniform FabricSpec.defects mask")
        self._io_rate = self.sim._io_rate()
        self._gs_lane: Optional[np.ndarray] = None   # per-lane FRED group
                                                     # sizes in fused runs

    # ---- structural tables (one batched computation per missing pattern) ---
    def _ring_structs(self, counts: np.ndarray, strides: np.ndarray,
                      needed: Optional[np.ndarray] = None,
                      used: Optional[np.ndarray] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        mesh = self.sim.mesh
        rows, cols = mesh.rows, mesh.cols
        d = mesh.defects
        if d is not None:
            # masked structures come from the scalar defect-aware walk on
            # the compacted group family (detours and congestion depend on
            # where the holes are, not just on the (count, stride)
            # pattern).  ``used`` is the per-lane NPUs-used-per-wafer the
            # strided concurrent-group family tiles (meshnet
            # strided_ring_family) — the evaluated ring pays the max
            # shared-link load over the whole family, exactly like the
            # scalar path's concurrent_rings; None falls back to the
            # single representative ring.  ``needed`` marks the lanes the
            # scalar engine actually evaluates: a hole-disconnected ring
            # must raise exactly when the scalar path would route it, and
            # stay silent (neutral structure, result masked out
            # downstream) when it would not.
            from .meshnet import strided_ring_family
            if used is None:
                used = np.zeros_like(counts)
            uniq, inv = _unique_rows((counts, strides, used))
            healthy = d.healthy()
            m = len(uniq)
            if needed is None:
                pat_needed = np.ones(m, dtype=bool)
            else:
                pat_needed = np.bincount(inv[np.asarray(needed, bool)],
                                         minlength=m) > 0
            cong = np.empty(m, dtype=np.int64)
            hops = np.empty(m, dtype=np.float64)
            for j, (c, s, u) in enumerate(uniq):
                if c <= 1 or not pat_needed[j]:
                    cong[j], hops[j] = 1, 1.0
                    continue
                key = (rows, cols, c, s, u, d)
                st = _RING_STRUCTS.get(key)
                if st is None:
                    fam = strided_ring_family(healthy, c, s, u)
                    st = (max(mesh.ring_max_congestion(fam), 1),
                          mesh._ring_hops(fam[0]))
                    _RING_STRUCTS[key] = st
                cong[j], hops[j] = st
            return cong[inv], hops[inv]
        uniq, inv = _unique_rows((counts, strides))
        missing = [(c, s) for c, s in uniq
                   if c > 1 and (rows, cols, c, s) not in _RING_STRUCTS]
        if missing:
            mc = np.array([p[0] for p in missing], dtype=np.int64)
            ms = np.array([p[1] for p in missing], dtype=np.int64)
            for p, st in zip(missing, _ring_structures_np(rows, cols,
                                                          mc, ms)):
                _RING_STRUCTS[(rows, cols) + p] = st
        m = len(uniq)
        cong = np.empty(m, dtype=np.int64)
        hops = np.empty(m, dtype=np.float64)
        for j, (c, s) in enumerate(uniq):
            cong[j], hops[j] = (_RING_STRUCTS[(rows, cols, c, s)]
                                if c > 1 else (1, 1.0))
        return cong[inv], hops[inv]

    def _span_structs(self, counts: np.ndarray, strides: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        """(g, k, uplink-factor) lanes; the factor lane is ``None`` without
        a defect mask so the zero-defect kernels never see it."""
        gsl = self._gs_lane
        fred = self.sim.fred
        if gsl is None:
            gs0 = fred.group_size
            uniq, inv = _unique_rows((counts, strides))
            triples = [(gs0, c, s) for c, s in uniq]
        else:
            uniq, inv = _unique_rows((gsl, counts, strides))
            triples = [tuple(t) for t in uniq]
        d = fred.defects
        if d is not None:
            m = len(triples)
            g = np.empty(m, dtype=np.int64)
            k = np.empty(m, dtype=np.int64)
            fac = np.empty(m, dtype=np.float64)
            for j, t in enumerate(triples):
                g[j], k[j], fac[j] = (self._masked_span(t, d) if t[1] > 1
                                      else (1, 1, 1.0))
            return g[inv], k[inv], fac[inv]
        missing = [t for t in triples
                   if t[1] > 1 and t not in _SPAN_STRUCTS]
        if missing:
            by_gs: Dict[int, List[Tuple[int, ...]]] = {}
            for t in missing:
                by_gs.setdefault(t[0], []).append(t)
            for gs, pats in by_gs.items():
                mc = np.array([t[1] for t in pats], dtype=np.int64)
                ms = np.array([t[2] for t in pats], dtype=np.int64)
                for t, st in zip(pats, _span_structures_np(gs, mc, ms)):
                    _SPAN_STRUCTS[t] = st
        m = len(triples)
        g = np.empty(m, dtype=np.int64)
        k = np.empty(m, dtype=np.int64)
        for j, t in enumerate(triples):
            g[j], k[j] = _SPAN_STRUCTS[t] if t[1] > 1 else (1, 1)
        return g[inv], k[inv], None

    def _masked_span(self, triple: Tuple[int, int, int], d
                     ) -> Tuple[int, int, float]:
        """(g, k, uplink factor) of one (group_size, count, stride) pattern
        compacted onto the mask's healthy NPUs — the same quantities
        :meth:`FredFabric.span_structure` / :meth:`FredFabric
        .uplink_factor` derive for the compacted group, with the lane's
        group size standing in for the bound fabric's (fused runs)."""
        key = triple + (d,)
        st = _MASKED_SPAN_STRUCTS.get(key)
        if st is None:
            gs, c, s = triple
            healthy = d.healthy()
            span: Dict[int, int] = {}
            for i in range(c):
                l1 = healthy[i * s] // gs
                span[l1] = span.get(l1, 0) + 1
            f = 1.0
            if d.dead_uplinks:
                up = self.sim.fred.uplinks_per_l1()
                for l1 in span:
                    f = min(f, max(1, up - d.dead_uplinks_of(l1)) / up)
            st = (len(span), max(span.values()), f)
            _MASKED_SPAN_STRUCTS[key] = st
        return st

    # ---- vectorized fabric kernels (op-for-op the scalar formulas) ----------
    def _mesh_coll(self, kind: str, n: np.ndarray, cong: np.ndarray,
                   hops: np.ndarray, nbytes: np.ndarray) -> np.ndarray:
        """:meth:`MeshFabric.collective_time` over arrays — wafer-wide
        hierarchical-2D branch selected exactly where ``n == mesh.n``."""
        mesh = self.sim.mesh
        nf = _f(n)
        if kind == "all_reduce":
            traffic = 2.0 * (nf - 1) / nf * nbytes
        else:
            traffic = (nf - 1) / nf * nbytes
        # the hierarchical-2D algorithm needs the full defect-free
        # rectangle — any hole degrades to the generic ring branch
        wafer = (n == mesh.n if mesh.defects is None
                 else np.zeros_like(n, dtype=bool))
        steps_w = 2 * ((mesh.cols - 1) + (mesh.rows - 1))
        if kind != "all_reduce":
            steps_w //= 2
        steps_r = 2 * (n - 1) if kind == "all_reduce" else (n - 1)
        steps = np.where(wafer, steps_w, steps_r)
        bw = np.where(wafer, mesh.wafer_wide_allreduce_bw(),
                      mesh.link_bw / _f(cong))
        h = np.where(wafer, 1.0, hops)
        chunk = traffic / np.maximum(steps, 1)
        per_step = (chunk / bw + mesh.latency_per_hop * h +
                    mesh.step_overhead)
        return np.where((n <= 1) | (nbytes <= 0), 0.0, steps * per_step)

    def _fred_coll(self, kind: str, n: np.ndarray, g: np.ndarray,
                   k: np.ndarray, conc: np.ndarray, nbytes: np.ndarray,
                   l2f: Optional[np.ndarray] = None) -> np.ndarray:
        """:meth:`FredFabric.collective_time` (incl. ``effective_npu_bw``)
        over arrays.  ``l2f`` is the per-lane uplink surviving-fraction
        under a defect mask (None without one — the raw-constant spine
        bandwidth path is kept byte-for-byte)."""
        cfg = self.sim.fred.config
        nf = _f(n)
        if cfg.in_network:
            if kind == "all_reduce":
                traffic = nbytes
            else:
                traffic = (nf - 1) / nf * nbytes
            steps = np.where(g > 1, 4, 2)
        else:
            if kind == "all_reduce":
                traffic = 2.0 * (nf - 1) / nf * nbytes
            else:
                traffic = (nf - 1) / nf * nbytes
            steps = np.where(g > 1, 2 * (k - 1) + 2 * (g - 1), 2 * (n - 1))
            steps = np.maximum(steps, 2)
            if kind != "all_reduce":
                steps = np.maximum(steps // 2, 1)
        if l2f is None:
            l2 = cfg.l1_l2_bw
        else:                  # severed uplinks shrink the spine BW —
            # same op order as the scalar branch (multiply, then divide)
            l2 = np.where(l2f != 1.0, cfg.l1_l2_bw * l2f, cfg.l1_l2_bw)
        share = l2 / np.maximum(k * conc, 1)
        if cfg.in_network:
            bw_multi = np.minimum(cfg.npu_l1_bw,
                                  l2 / np.maximum(conc, 1))
        else:
            bw_multi = np.where(k > 1,
                                np.minimum(cfg.npu_l1_bw, share * (1 + k)),
                                np.minimum(cfg.npu_l1_bw, share))
        bw = np.where(g <= 1, cfg.npu_l1_bw, bw_multi)
        per_step = ((traffic / np.maximum(steps, 1)) / bw +
                    cfg.switch_latency + cfg.step_overhead)
        return np.where((n <= 1) | (nbytes <= 0), 0.0, steps * per_step)

    def _wafer_coll(self, kind: str, counts: np.ndarray, strides: np.ndarray,
                    conc: np.ndarray, nbytes: np.ndarray,
                    needed: Optional[np.ndarray] = None,
                    used: Optional[np.ndarray] = None) -> np.ndarray:
        """One intra-wafer collective over the (count, stride) pattern.

        Healthy mesh rings ignore concurrency exactly like the scalar
        path (disjoint X-Y rings); under a defect mask ``used`` (per-lane
        NPUs used per wafer) keys the concurrent-ring family whose
        shared-link detour congestion the evaluated ring pays — the
        scalar path's ``concurrent_rings``, bit-for-bit.  FRED already
        models concurrency via the ``conc`` bandwidth share."""
        if self.sim.mesh is not None:
            cong, hops = self._ring_structs(counts, strides, needed=needed,
                                            used=used)
            return self._mesh_coll(kind, counts, cong, hops, nbytes)
        g, k, fac = self._span_structs(counts, strides)
        return self._fred_coll(kind, counts, g, k, conc, nbytes, l2f=fac)

    def _level_coll(self, kind: str, topo: np.ndarray, n: np.ndarray,
                    conc: np.ndarray, nbytes: np.ndarray, agg_bw: float,
                    latency: float) -> np.ndarray:
        """:func:`repro.core.cluster.level_collective_time` over arrays —
        every topology branch evaluated with the scalar op order and
        selected per lane by ``topo`` code."""
        nf = _f(n)
        bw = agg_bw / np.maximum(conc, 1)
        ar = kind == "all_reduce"
        # ring: endpoint traffic over 2(n−1) (AR) / (n−1) (RS/AG) steps
        tr_ring = (2.0 * (nf - 1) / nf if ar else (nf - 1) / nf) * nbytes
        steps_ring = 2 * (n - 1) if ar else (n - 1)
        t_ring = steps_ring * ((tr_ring / np.maximum(steps_ring, 1)) / bw +
                               latency)
        # fully connected: the D/n shard moves over n−1 parallel peer
        # links; 2 latency steps (RS + AG phase) for the All-Reduce
        shard = nbytes / nf
        per_link_bw = bw / np.maximum(nf - 1, 1)
        steps_fc = 2 if ar else 1
        t_fc = steps_fc * (shard / per_link_bw + latency)
        # switch: in-network reduction — All-Reduce injects D, not
        # 2(n−1)/n·D (core/switch.py R/D µswitch semantics)
        tr_sw = nbytes if ar else (nf - 1) / nf * nbytes
        steps_sw = 2 if ar else 1
        t_sw = steps_sw * ((tr_sw / steps_sw) / bw + latency)
        t = np.where(topo == 0, t_ring, np.where(topo == 1, t_fc, t_sw))
        return np.where((n <= 1) | (nbytes <= 0), 0.0, t)

    def _derived_inter_lane(self, wafers: np.ndarray) -> InterLane:
        """InterLane from the bound cluster's own levels (direct
        ``run_batch`` calls outside the sweep): spans depend only on the
        lane's wafer count — computed once per distinct value."""
        levels = self.sim.cluster.levels
        if len(levels) > 2:
            raise NotImplementedError(
                f"batched engine supports ≤ 2 inter levels, got "
                f"{len(levels)}")
        n = len(wafers)
        full = lambda v: np.full(n, v, dtype=np.int64)
        topo1 = full(TOPOLOGY_CODES[levels[0].topology])
        topo2 = full(TOPOLOGY_CODES[levels[-1].topology])
        span1 = np.ones(n, dtype=np.int64)
        span2 = np.ones(n, dtype=np.int64)
        for w in np.unique(wafers).tolist():
            spans = self.sim.cluster.spans_for(int(w)) + [1, 1]
            sel = wafers == w
            span1[sel] = spans[0]
            span2[sel] = spans[1]
        return InterLane(full(len(levels)), topo1, topo2, span1, span2)

    # ---- validation (scalar-path error parity) ------------------------------
    def _validate(self, b: CandidateBatch) -> None:
        sim = self.sim
        npw = (sim.cluster.npus_per_wafer if sim.cluster is not None
               else sim.n_npus)
        per_wafer_arr = b.mp * b.pp * (b.dp // np.maximum(b.wafers, 1))
        dpw_arr = b.dp // np.maximum(b.wafers, 1)
        bad = (per_wafer_arr > npw) | \
            (b.pp > b.n_layers) | (b.dp % np.maximum(b.wafers, 1) != 0) | \
            ((b.ep > 1) & (dpw_arr % np.maximum(b.ep, 1) != 0)) | \
            ((b.sp > 1) & (b.mp % np.maximum(b.sp, 1) != 0))
        if sim.cluster is None:
            bad |= b.wafers > 1
        else:
            bad |= b.wafers > sim.n_wafers
        if sim.defects is not None:
            bad |= per_wafer_arr > sim.defects.n_healthy
        if not bad.any():
            return
        for w in b.workloads:            # re-derive the precise message
            st = w.strategy
            if sim.cluster is None and st.wafers > 1:
                raise ValueError(
                    f"{st} spans {st.wafers} wafers but this "
                    f"simulator models a single wafer (n_wafers=1)")
            if sim.cluster is not None:
                if st.wafers > sim.n_wafers:
                    raise ValueError(f"{st} spans {st.wafers} wafers, "
                                     f"cluster has {sim.n_wafers}")
                if st.dp % st.wafers != 0:
                    raise ValueError(
                        f"{st}: dp={st.dp} not divisible by wafers="
                        f"{st.wafers} — DP replicas map whole onto wafers")
            per_wafer = st.mp * st.pp * (st.dp // st.wafers)
            if per_wafer > npw:
                raise ValueError(f"{st} needs {per_wafer} NPUs per wafer, "
                                 f"wafer has {npw}")
            if sim.defects is not None and per_wafer > sim.defects.n_healthy:
                raise ValueError(
                    f"{st} needs {per_wafer} healthy NPUs per wafer, "
                    f"defect mask leaves {sim.defects.n_healthy}")
            if st.pp > w.n_layers:
                raise ValueError(
                    f"{st} has pp={st.pp} stages but {w.name} only "
                    f"{w.n_layers} layers — stages must hold whole layers")
            if st.ep > 1 and st.dp_per_wafer % st.ep != 0:
                raise ValueError(
                    f"{st}: ep={st.ep} must divide the per-wafer DP degree "
                    f"{st.dp_per_wafer} — EP groups stay within a wafer")
            if st.sp > 1 and st.mp % st.sp != 0:
                raise ValueError(
                    f"{st}: sp={st.sp} must divide mp={st.mp} — sequence "
                    f"parallelism splits activations across MP peers")

    # ---- main ----------------------------------------------------------------
    def run_batch(self, batch: Union[CandidateBatch, Sequence[Workload]],
                  indices: Optional[Sequence[int]] = None,
                  gs_lane: Optional[np.ndarray] = None,
                  inter_lane: Optional[InterLane] = None) -> List[Breakdown]:
        """Evaluate every candidate (with its own strategy) on this fabric.

        ``batch`` is a :class:`CandidateBatch` or a plain Workload list
        (packed on the fly); ``indices`` restricts evaluation to a
        sub-batch.  ``gs_lane`` supplies per-lane FRED group sizes when
        the batch fuses several wafer shapes of one FRED config (the
        only shape-dependent input of the FRED kernels); ``inter_lane``
        supplies the per-lane inter-level structure when the batch fuses
        several (hierarchy, inter topology) configurations of one cluster
        (absent, it is derived from the bound cluster's own levels).
        Returns Breakdowns bit-identical to the scalar reference — the
        same IEEE-754 ops in the same order."""
        sim = self.sim
        if not isinstance(batch, CandidateBatch):
            batch = CandidateBatch(batch)
        if indices is not None:
            batch = batch.take(indices)
            if gs_lane is not None:
                gs_lane = np.asarray(gs_lane)[np.asarray(indices)]
            if inter_lane is not None:
                inter_lane = inter_lane.take(indices)
        if not len(batch):
            return []
        self._gs_lane = gs_lane
        self._validate(batch)
        b = batch
        mp, dp, pp, wafers = b.mp, b.dp, b.pp, b.wafers
        streaming = b.streaming
        stationary = ~streaming

        layers = -(-b.n_layers // pp)                 # ceil(n_layers / pp)

        # ---- compute (Sec. VII-A + GPipe bubble, Sec. VII-C) ---------------
        eff_flops = NPU_PEAK_FLOPS * sim.compute_efficiency
        fwd_layer = b.flops * b.samples / mp / eff_flops
        bwd_layer = 2 * fwd_layer
        fwd_stage = fwd_layer * layers
        bwd_stage = bwd_layer * layers
        mb = np.where((pp > 1) & stationary, 8, np.maximum(pp, 1))
        bubble = np.where(pp > 1, (mb + pp - 1) / mb, 1.0)
        compute = (fwd_stage + bwd_stage) * bubble

        # ---- MP comm (Sec. VII-B): per-layer All-Reduce, fwd + bwd ---------
        # with EP active the expert-dispatch All-to-All subsumes the FFN
        # All-Reduce — one fewer MP sync per layer (scalar: mp_ar − 1)
        ep_mask = (b.ep > 1) & (b.a2a_layer > 0.0)
        mp_ar = np.where(ep_mask & (b.mp_ar > 0), b.mp_ar - 1, b.mp_ar)
        act_bytes = b.abps * b.samples
        mp_mask = (mp > 1) & (mp_ar > 0)
        mp_conc = np.maximum(1, (dp * pp) // wafers)
        # per-lane NPUs used per wafer — the strided concurrent-group
        # family extent every axis' masked ring congestion is keyed on
        used = mp * pp * (dp // np.maximum(wafers, 1))
        per_layer = self._wafer_coll("all_reduce", mp, np.ones_like(mp),
                                     mp_conc, act_bytes, needed=mp_mask,
                                     used=used)
        mp_time = np.where(mp_mask,
                           per_layer * mp_ar * 2 * layers * bubble, 0.0)

        # ---- EP comm: expert dispatch/combine All-to-All -------------------
        # EP groups are ep consecutive DP peers (stride mp·pp), always
        # within one wafer — the same strided structural tables as MP/DP
        # serve the All-to-All per lane
        a2a_bytes = b.a2a_layer * b.samples
        ep_conc = np.maximum(1, (mp * pp * dp) // (b.ep * wafers))
        per_layer_ep = self._wafer_coll("all_to_all", b.ep, mp * pp,
                                        ep_conc, a2a_bytes, needed=ep_mask,
                                        used=used)
        ep_raw = np.where(ep_mask,
                          per_layer_ep * 2 * 2 * layers * bubble, 0.0)

        # ---- compute/comm overlap (EP first, then MP) ----------------------
        overlappable = sim.comm_overlap_fraction * compute
        ep_time = np.maximum(0.0, ep_raw - overlappable)
        rem = np.maximum(0.0, overlappable - ep_raw)
        mp_time = np.maximum(0.0, mp_time - rem)
        exposed_comm = mp_time + ep_time

        # ---- PP comm (Sec. VII-C): boundary transfer per microbatch --------
        pp_bw = (sim.mesh.link_bw if sim.mesh is not None
                 else sim.fred.config.npu_l1_bw)
        per_mb = 2 * ((act_bytes / mb / b.sp) / pp_bw)
        pp_time = np.where(pp > 1, per_mb * (mb + pp - 1), 0.0)

        # ---- DP comm (Sec. VII-B, hierarchical on clusters) ----------------
        grad = b.params_layer * BYTES / mp
        dp_mask = (dp > 1) & stationary
        n_dp_groups = mp * pp
        stride = mp * pp
        n_lvl = np.zeros_like(dp)
        if sim.cluster is not None:
            if inter_lane is None:
                inter_lane = self._derived_inter_lane(wafers)
            n_lvl = inter_lane.n_levels
            multi = wafers > 1
            dpw = dp // wafers
            counts = np.where(multi, dpw, dp)
            # one structural lookup serves AR, RS and AG (same pattern);
            # RS and AG are bit-equal by construction (the kernels only
            # branch on all_reduce vs not), mirroring the scalar engine
            # computing both to the same value
            if sim.mesh is not None:
                cong, hops = self._ring_structs(counts, stride,
                                                needed=dp_mask, used=used)
                t_ar = self._mesh_coll("all_reduce", counts, cong, hops,
                                       grad)
                t_rs = self._mesh_coll("reduce_scatter", counts, cong,
                                       hops, grad)
            else:
                g, k, fac = self._span_structs(counts, stride)
                t_ar = self._fred_coll("all_reduce", counts, g, k,
                                       n_dp_groups, grad, l2f=fac)
                t_rs = self._fred_coll("reduce_scatter", counts, g, k,
                                       n_dp_groups, grad, l2f=fac)
            intra_multi = np.where(counts > 1, t_rs + t_rs, 0.0)
            ti = np.where(multi, intra_multi, t_ar)
            # per-level inter terms — level 1 runs RS+AG when a spanned
            # level sits above it, All-Reduce when it is the outermost
            # (the scalar decomposition of WaferCluster._level_times);
            # only the mp groups of one pipeline stage contend on the
            # inter links (inter_concurrent = mp, as in the scalar path)
            agg1, lat1 = sim.cluster.level_params(0)
            agg2, lat2 = sim.cluster.level_params(1)
            s1, s2 = inter_lane.span1, inter_lane.span2
            ar1 = self._level_coll("all_reduce", inter_lane.topo1, s1, mp,
                                   grad, agg1, lat1)
            rs1 = self._level_coll("reduce_scatter", inter_lane.topo1, s1,
                                   mp, grad, agg1, lat1)
            ag1 = self._level_coll("all_gather", inter_lane.topo1, s1,
                                   mp, grad, agg1, lat1)
            te1 = np.where(multi & (s2 > 1), rs1 + ag1,
                           np.where(multi, ar1, 0.0))
            te2 = np.where(multi,
                           self._level_coll("all_reduce", inter_lane.topo2,
                                            s2, mp, grad, agg2, lat2), 0.0)
        else:
            ti = self._wafer_coll("all_reduce", dp, stride, n_dp_groups,
                                  grad, needed=dp_mask, used=used)
            te1 = np.zeros_like(ti)
            te2 = np.zeros_like(ti)
        dp_intra, lvl1, lvl2 = _iterated_layer_sum(ti, te1, te2, layers,
                                                   dp_mask)
        dp_inter = lvl1 + lvl2
        total_ar = dp_intra + dp_inter
        if sim.overlap_dp:
            exposed_dp = np.maximum(
                0.0, total_ar - bwd_stage * (1 - 1 / np.maximum(layers, 1)))
        else:
            exposed_dp = total_ar
        dp_time = np.where(dp_mask, exposed_dp, 0.0)

        # ---- weight streaming + input load (Sec. III-A, VIII) --------------
        stream_bytes = b.pbt * (2 + 1) / pp
        io_time = stream_bytes / self._io_rate
        stream_time = np.where(
            streaming, np.maximum(0.0, io_time - compute - mp_time), 0.0)
        in_bytes = b.minibatch * b.abps
        input_load = np.where(streaming,
                              in_bytes / (self._io_rate * wafers), 0.0)

        # bulk-convert to Python floats once (tolist is C-speed) and
        # bypass the dataclass __init__ — Breakdown construction is the
        # hottest remaining per-point Python in a 500+-NPU sweep
        cols = [a.tolist() for a in
                (compute, input_load, mp_time, dp_time, pp_time,
                 stream_time, dp_intra, dp_inter, ep_time, exposed_comm)]
        l1s, l2s = lvl1.tolist(), lvl2.tolist()
        nls = n_lvl.tolist()
        fabric = sim.fabric_name
        new = Breakdown.__new__
        out = []
        for i, w in enumerate(b.workloads):
            nl = nls[i]
            br = new(Breakdown)
            br.__dict__ = {
                "workload": w.name, "fabric": fabric,
                "compute": cols[0][i], "input_load": cols[1][i],
                "mp": cols[2][i], "dp": cols[3][i], "pp": cols[4][i],
                "stream": cols[5][i], "dp_intra": cols[6][i],
                "dp_inter": cols[7][i],
                "dp_levels": (() if nl == 0 else
                              (l1s[i],) if nl == 1 else (l1s[i], l2s[i])),
                "ep_s": cols[8][i], "exposed_comm_s": cols[9][i]}
            out.append(br)
        return out


def _iterated_layer_sum(ti: np.ndarray, te1: np.ndarray, te2: np.ndarray,
                        layers: np.ndarray, mask: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-layer DP accumulation replayed as *iterated* float adds.

    The scalar engine adds the per-layer All-Reduce time ``layers`` times
    in a loop; ``layers · t`` would round differently after the third
    add, so bit-parity requires replaying the additions — for the intra
    part and each inter level separately.  Distinct (tᵢ, tₑ₁, tₑ₂,
    layers) tuples are deduplicated first — strategies sharing a DP
    group pattern collapse to one replay lane each."""
    n = ti.shape[0]
    dp_intra = np.zeros(n)
    lvl1 = np.zeros(n)
    lvl2 = np.zeros(n)
    idx = np.nonzero(mask)[0]
    if not len(idx):
        return dp_intra, lvl1, lvl2
    key = np.empty((len(idx), 4), dtype=np.int64)
    key[:, 0] = ti[idx].view(np.int64)
    key[:, 1] = te1[idx].view(np.int64)
    key[:, 2] = te2[idx].view(np.int64)
    key[:, 3] = layers[idx]
    # bytewise row dedup (void view) — much faster than unique(axis=0)
    kv = np.ascontiguousarray(key).view(np.dtype((np.void, 32))).ravel()
    _, first, inv = np.unique(kv, return_index=True, return_inverse=True)
    uniq = key[first]
    uti = uniq[:, 0].copy().view(np.float64)
    ue1 = uniq[:, 1].copy().view(np.float64)
    ue2 = uniq[:, 2].copy().view(np.float64)
    ul = uniq[:, 3]
    milestones = set(ul.tolist())
    m = len(uniq)
    acc_i = np.zeros(m)
    acc_1 = np.zeros(m)
    acc_2 = np.zeros(m)
    out_i = np.zeros(m)
    out_1 = np.zeros(m)
    out_2 = np.zeros(m)
    for step in range(1, int(ul.max()) + 1):
        acc_i = acc_i + uti
        acc_1 = acc_1 + ue1
        acc_2 = acc_2 + ue2
        if step in milestones:
            hit = ul == step
            out_i[hit] = acc_i[hit]
            out_1[hit] = acc_1[hit]
            out_2[hit] = acc_2[hit]
    dp_intra[idx] = out_i[inv]
    lvl1[idx] = out_1[inv]
    lvl2[idx] = out_2[inv]
    return dp_intra, lvl1, lvl2


# --------------------------------------------------------------------------
# vectorized memory-feasibility model
# --------------------------------------------------------------------------

def memory_bytes_batch(batch: Union[CandidateBatch, Sequence[Workload]],
                       mem: MemoryModel) -> np.ndarray:
    """Vectorized :func:`repro.core.workloads.memory_bytes_per_npu` —
    identical op order, so each element is bit-equal to the scalar call."""
    if not isinstance(batch, CandidateBatch):
        batch = CandidateBatch(batch)
    b = batch
    if not len(b):
        return np.zeros(0)
    mp = b.mp
    streaming = b.streaming
    stationary = ~streaming
    layers = -(-b.n_layers // b.pp)
    buffers = 3 if mem.training else 2
    # expert share of the params divides by ep (scalar: ep_share factor;
    # (1−f)+f is not bitwise 1.0, so inactive lanes select the literal)
    ep_on = (b.ep > 1) & (b.expert_frac != 0.0)
    ep_share = np.where(ep_on,
                        (1.0 - b.expert_frac) + b.expert_frac / b.ep, 1.0)
    resident = np.where(streaming,
                        buffers * b.params_layer * ep_share / mp,
                        b.params_layer * ep_share * layers / mp)
    opt_per_param = optimizer_bytes_per_param(mem.master, mem.moments_dtype)
    if mem.training:
        opt_bytes = np.where(stationary, resident * opt_per_param, 0.0)
        grad_bytes = np.where(stationary, resident * BYTES, 0.0)
    else:
        opt_bytes = np.zeros_like(resident)
        grad_bytes = np.zeros_like(resident)
    weight_bytes = resident * BYTES

    mult = ACT_REMAT_MULT[mem.remat] if mem.training else 1.0
    act_layers = layers if mem.training else np.ones_like(layers)
    act_bytes = mult * act_layers * b.abps * np.maximum(b.seq, 1) / mp / b.sp

    kv_bytes = np.zeros_like(resident)
    if not mem.training:
        kv_bytes = np.where(b.kv_layer != 0.0,
                            b.kv_layer * b.samples * layers / mp, 0.0)
    return weight_bytes + grad_bytes + opt_bytes + act_bytes + kv_bytes


def feasible_batch(batch: Union[CandidateBatch, Sequence[Workload]],
                   mem: MemoryModel) -> Tuple[np.ndarray, np.ndarray]:
    """(memory_bytes_per_npu, feasible) arrays for a candidate batch —
    the sweep masks infeasible points on these before any cost math."""
    mem_bytes = memory_bytes_batch(batch, mem)
    return mem_bytes, mem_bytes <= mem.npu_hbm_bytes
