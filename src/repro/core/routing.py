"""Conflict-free collective routing on a FRED switch (paper Sec. V-B/C).

Routing treats a *flow* as the unit: flows that share an input or output
µswitch must traverse different middle-stage subnetworks.  The protocol:

  1. Build the conflict graph (node = flow, edge = shared input/output
     µswitch).
  2. Color it with m colors (m = number of middle subnetworks).  We use
     greedy (largest-degree-first) with backtracking up to a node budget —
     the paper computes routes at compile time and stores them in the
     switch control unit, so routing cost is off the critical path.
  3. Activate reduction on input µswitches whose both ports belong to one
     flow; distribution on output µswitches whose both ports belong to one
     flow.
  4. Recurse into each middle subnetwork with the flows assigned to it
     (port ids remapped to the subnetwork's ports).

Failure to color ⇒ *routing conflict* (Fig. 7(j): four specific flows on
FRED_2(8) cannot route; FRED_3(8) routes them).  The caller picks one of
the paper's four mitigations; FRED itself uses m=3 + placement (Sec. V-C).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .flows import Flow
from .switch import FredSwitch


class RoutingConflict(Exception):
    """Raised when the conflict graph is not m-colorable."""

    def __init__(self, flows, level: int):
        self.flows = flows
        self.level = level
        super().__init__(
            f"routing conflict among {len(flows)} flows at recursion "
            f"level {level}")


@dataclasses.dataclass
class RoutingAssignment:
    """Result of routing one level of the switch."""
    colors: Dict[Flow, int]                  # flow → middle subnetwork
    reduce_at: List[Tuple[int, Flow]]        # input µswitch idx, flow
    distribute_at: List[Tuple[int, Flow]]    # output µswitch idx, flow
    sub_assignments: List["RoutingAssignment"]


def conflict_graph(switch: FredSwitch, flows: Sequence[Flow]
                   ) -> Dict[Flow, set]:
    """Edges between flows sharing an input or output µswitch.

    Two ports of the *same* flow sharing a µswitch is not a conflict —
    that is exactly where reduction/distribution activates."""
    adj: Dict[Flow, set] = {f: set() for f in flows}
    for a, b in itertools.combinations(flows, 2):
        shared = False
        ia = {switch.input_switch_of(p) for p in a.ips} - {None}
        ib = {switch.input_switch_of(p) for p in b.ips} - {None}
        if ia & ib:
            shared = True
        oa = {switch.output_switch_of(p) for p in a.ops} - {None}
        ob = {switch.output_switch_of(p) for p in b.ops} - {None}
        if oa & ob:
            shared = True
        if shared:
            adj[a].add(b)
            adj[b].add(a)
    return adj


def color_graph(adj: Dict[Flow, set], m: int,
                max_backtrack: int = 200_000) -> Optional[Dict[Flow, int]]:
    """m-coloring: greedy largest-degree-first, then backtracking."""
    nodes = sorted(adj, key=lambda f: (-len(adj[f]), sorted(f.ips)))
    colors: Dict[Flow, int] = {}

    # greedy first — succeeds for almost all training communication sets
    ok = True
    for nd in nodes:
        used = {colors[nb] for nb in adj[nd] if nb in colors}
        free = [c for c in range(m) if c not in used]
        if not free:
            ok = False
            break
        colors[nd] = free[0]
    if ok:
        return colors

    # full backtracking (bounded)
    colors = {}
    budget = [max_backtrack]

    def bt(i: int) -> bool:
        if budget[0] <= 0:
            return False
        if i == len(nodes):
            return True
        nd = nodes[i]
        used = {colors[nb] for nb in adj[nd] if nb in colors}
        for c in range(m):
            if c in used:
                continue
            colors[nd] = c
            budget[0] -= 1
            if bt(i + 1):
                return True
            del colors[nd]
        return False

    return dict(colors) if bt(0) else None


def _remap_flow(switch: FredSwitch, f: Flow) -> Flow:
    """Map a flow's ports onto the middle subnetwork's port ids."""
    return Flow(frozenset(switch.middle_port_of(p) for p in f.ips),
                frozenset(switch.middle_port_of(p) for p in f.ops),
                f.bytes, f.tag)


def route(switch: FredSwitch, flows: Sequence[Flow], *, level: int = 0
          ) -> RoutingAssignment:
    """Recursively route ``flows``; raises RoutingConflict on failure."""
    flows = [f for f in flows if f.ips or f.ops]
    if switch.is_base or not flows:
        return RoutingAssignment(colors={f: 0 for f in flows},
                                 reduce_at=[], distribute_at=[],
                                 sub_assignments=[])

    adj = conflict_graph(switch, flows)
    colors = color_graph(adj, switch.m)
    if colors is None:
        raise RoutingConflict(flows, level)

    reduce_at, distribute_at = [], []
    for f in flows:
        by_in: Dict[int, int] = {}
        for p in f.ips:
            sw = switch.input_switch_of(p)
            if sw is not None:
                by_in[sw] = by_in.get(sw, 0) + 1
        for sw, cnt in by_in.items():
            if cnt == 2 and switch.input_switches[sw].can_reduce:
                reduce_at.append((sw, f))
        by_out: Dict[int, int] = {}
        for p in f.ops:
            sw = switch.output_switch_of(p)
            if sw is not None:
                by_out[sw] = by_out.get(sw, 0) + 1
        for sw, cnt in by_out.items():
            if cnt == 2 and switch.output_switches[sw].can_distribute:
                distribute_at.append((sw, f))

    subs = []
    for mid_idx, mid in enumerate(switch.middles):
        assigned = [_remap_flow(switch, f) for f, c in colors.items()
                    if c == mid_idx]
        subs.append(route(mid, assigned, level=level + 1))
    return RoutingAssignment(colors=colors, reduce_at=reduce_at,
                             distribute_at=distribute_at,
                             sub_assignments=subs)


def routable(switch: FredSwitch, flows: Sequence[Flow]) -> bool:
    try:
        route(switch, flows)
        return True
    except RoutingConflict:
        return False


def strategy_routable(strategy, shape, m: int = 3,
                      uplinks: Optional[int] = None,
                      defects=None) -> bool:
    """True iff every parallelism phase of ``strategy`` routes conflict-free
    under the MP-consecutive placement.

    ``shape`` is either an int — the legacy single-crossbar check on one
    FRED_m(n_ports) switch — or the actual fabric shape ``(n_groups,
    group_size)``, in which case the check is hierarchical and shape-aware:
    each L1 switch routes its local flow segments (local NPU ports, plus an
    uplink port for flows spanning other groups, assigned round-robin over
    the ``uplinks`` physical uplink ports — pass
    :meth:`FredFabric.uplinks_per_l1`; defaults to ``group_size``, the
    almost-fat-tree upper bound) on a FRED_m(group_size+uplinks) switch,
    and the L2 spine routes the group-spanning flows over every L1's
    uplink ports.  Flows of ONE parallelism type run at a time (they occur
    in different phases of the training step — Sec. III Metric 4).

    ``defects`` (a :class:`~repro.core.defects.DefectMask`) re-runs the
    whole check under the mask's compacted placement: flows take the
    *healthy* NPU ports (never a dead one), and spanning flows only get a
    surviving uplink's port per L1 — a strategy that needs more workers
    than healthy NPUs (or more spanning flows than surviving uplinks can
    take conflict-free) is simply not routable."""
    from .defects import normalize
    from .flows import all_reduce
    from .placement import (defect_placement, fred_placement,
                            placement_groups)

    defects = normalize(defects)
    if isinstance(shape, tuple):
        return _shape_routable(strategy, shape[0], shape[1], m,
                               uplinks=uplinks, defects=defects)
    n_ports = shape
    if strategy.n_workers > n_ports:
        return False
    if defects is not None and strategy.n_workers > defects.n_healthy:
        return False
    if strategy.n_workers < 2:
        return True
    sw = FredSwitch.build(max(n_ports, 2), m)
    pl = (fred_placement(strategy, n_ports) if defects is None
          else defect_placement(strategy, defects, n_ports))
    groups = placement_groups(strategy, pl)
    for kind in ("mp", "dp", "pp"):
        flows = [all_reduce(g)[0][0] for g in groups[kind] if len(g) > 1]
        if flows and not routable(sw, flows):
            return False
    return True


def _shape_routable(strategy, n_groups: int, group_size: int,
                    m: int = 3, uplinks: Optional[int] = None,
                    defects=None) -> bool:
    """Hierarchical routability on an (n_groups, group_size) FRED fabric:
    per-L1 routing of local flow segments, then L2-spine routing of the
    spanning flows.  Each L1 exposes ``uplinks`` physical uplink ports;
    spanning flows are assigned uplinks round-robin per L1 (the compile-
    time router is free to pick, round-robin is its canonical choice).
    A defect mask compacts the placement onto healthy NPUs and removes
    each L1's dead uplink ports from the round-robin pool."""
    from .placement import defect_placement, fred_placement, placement_groups

    n = n_groups * group_size
    if strategy.n_workers > n:
        return False
    if defects is not None and strategy.n_workers > defects.n_healthy:
        return False
    if strategy.n_workers < 2:
        return True
    up = uplinks if uplinks is not None else group_size
    up = max(1, up)
    live_up = [up] * n_groups
    if defects is not None:
        live_up = [max(1, up - defects.dead_uplinks_of(l1))
                   for l1 in range(n_groups)]
    pl = (fred_placement(strategy, n) if defects is None
          else defect_placement(strategy, defects, n))
    groups = placement_groups(strategy, pl)
    l1_switch = FredSwitch.build(max(group_size + up, 2), m)
    spine = FredSwitch.build(max(n_groups * up, 2), m)
    for kind in ("mp", "dp", "pp"):
        colls = [cg for cg in groups[kind] if len(cg) > 1]
        if not colls:
            continue
        # uplink assignment: per L1, spanning flows take uplink ports
        # round-robin in enumeration order
        upidx: Dict[Tuple[int, int], int] = {}    # (l1, flow idx) → uplink
        counters = [0] * n_groups
        for ci, cg in enumerate(colls):
            l1s = sorted({nid // group_size for nid in cg})
            if len(l1s) < 2:
                continue
            for l1 in l1s:
                upidx[(l1, ci)] = counters[l1] % live_up[l1]
                counters[l1] += 1
        for l1 in range(n_groups):
            local_flows = []
            for ci, cg in enumerate(colls):
                local = [nid - l1 * group_size for nid in cg
                         if nid // group_size == l1]
                if not local:
                    continue
                ports = list(local)
                if (l1, ci) in upidx:             # spans other L1s
                    ports.append(group_size + upidx[(l1, ci)])
                if len(ports) >= 2:
                    local_flows.append(
                        Flow.make(ports, ports, tag=f"{kind}{ci}"))
            if local_flows and not routable(l1_switch, local_flows):
                return False
        spine_flows = []
        for ci, cg in enumerate(colls):
            ports = sorted(l1 * up + idx for (l1, c), idx in upidx.items()
                           if c == ci)
            if len(ports) > 1:
                spine_flows.append(
                    Flow.make(ports, ports, tag=f"{kind}{ci}"))
        if spine_flows and not routable(spine, spine_flows):
            return False
    return True


# --------------------------------------------------------------------------
# the paper's Fig. 7(j) example
# --------------------------------------------------------------------------

def fig7j_flows() -> List[Flow]:
    """Four flows with circular µswitch dependencies among flows 0,1,2:
    not routable on FRED_2(8), routable on FRED_3(8) (footnote 4)."""
    return [
        Flow.make([0, 2], [0, 2], tag="f0"),
        Flow.make([1, 4], [1, 4], tag="f1"),
        Flow.make([3, 5], [3, 5], tag="f2"),
        Flow.make([6, 7], [6, 7], tag="f3"),
    ]
