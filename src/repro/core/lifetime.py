"""Lifetime goodput: MTBF-driven failures, checkpoints, elastic decay.

A strategy tuned for the pristine wafer is only the right choice if it
still wins over the *lifetime* of a training run: hardware fails at some
MTBF, every failure costs a recovery plus the work since the last
checkpoint, and each checkpoint itself steals wall-clock.  This module
converts the simulator's per-iteration times into **lifetime goodput** —
useful samples per wall-clock second over a whole mission — so
auto-strategy can rank a slightly-slower-but-survivable strategy above a
fragile healthy-time winner (``choose_strategy(objective="goodput")``).

Three layers:

  1. **Checkpoint math** (Young–Daly style, closed form).  With
     exponential failures at system MTBF ``M``, checkpoint write cost
     ``δ`` and restart cost ``R``, the expected wall time to commit one
     segment of ``τ`` useful work is ``(M + R)·(e^{(τ+δ)/M} − 1)``
     (memoryless restart-from-checkpoint renewal).  ``optimal_interval``
     maximizes the useful fraction (seeded by Young–Daly
     ``τ* ≈ √(2δM)``), and ``time_fractions`` decomposes wall-clock into
     useful / checkpoint / lost-work / recovery exactly.

  2. **Degradation chain** (yield_study-style).  Failures don't return
     the run to a pristine wafer: each one kills hardware and the run
     re-plans onto the survivors.  ``degradation_chain`` draws a seeded
     kill order, asks :func:`~repro.core.yield_study._winner_survives`
     whether the candidate still runs (degraded) after ``k`` failures,
     and re-sweeps under the cumulative mask when it doesn't — the same
     fallback decision the auto-strategy would make on that wafer.  A
     chain that hits "no feasible fallback" is dead: the remaining
     mission produces zero goodput, which is exactly what makes fragile
     winners lose.

  3. **Mission estimate / event simulation**.  ``estimate_lifetime``
     walks the expected failure states deterministically (state ``k``
     lasts one system-MTBF on average) and averages goodput over the
     mission; ``simulate_lifetime`` is the seeded event-driven
     cross-check the tests compare against the closed form.

The checkpoint write cost is derived from the :class:`MemoryModel`'s
persistent state bytes (weights + optimizer — activations are
recomputed, not checkpointed) pushed through the fabric's wafer I/O
rate, so a bigger optimizer or a slimmer fabric genuinely changes the
optimal interval.

At ``mtbf = ∞`` (or zero checkpoint cost) the useful fraction is exactly
1.0 and goodput reduces to ``1 / time_per_sample`` — ranking by goodput
is then *bit-identical* to ranking by time, which is how the pre-lifetime
goldens stay byte-identical (pinned by ``tests/test_lifetime.py``).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .defects import DefectMask, normalize
from .sweep import SweepResult, _simulator, sweep
from .workloads import MemoryModel, Workload, BYTES, optimizer_bytes_per_param
from .yield_study import _winner_survives

HOUR_S = 3600.0                 # repro: unit[s]

# Mission / recovery defaults shared by choose_strategy(objective=
# "goodput"), benchmarks.run --only lifetimesweep, and the golden
# generator — one month of training, a one-minute restart (process
# respawn + re-shard + data-pipeline rewind).
DEFAULT_MISSION_HOURS = 720.0
DEFAULT_RESTART_S = 60.0        # repro: unit[s]


# --------------------------------------------------------------------------
# failure model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Exponential failure rates plus the mission the run must survive.

    ``mtbf_npu_hours`` is the per-NPU mean time between failures;
    ``mtbf_wafer_hours`` covers whole-wafer events (power, cooling,
    host).  The system rate adds one exponential clock per *used* NPU
    and per used wafer — idle spares don't take the run down when they
    die (they become unavailable for later re-planning, which the
    degradation chain's cumulative kill order models)."""
    mtbf_npu_hours: float = math.inf
    mtbf_wafer_hours: float = math.inf
    restart_s: float = DEFAULT_RESTART_S     # repro: unit[s]
    mission_hours: float = DEFAULT_MISSION_HOURS

    @property
    def mission_s(self) -> float:            # repro: unit[s]
        return self.mission_hours * HOUR_S

    def system_mtbf_s(self, n_npus: int, n_wafers: int = 1) -> float:
        """MTBF of the whole system, seconds.  ``inf`` when nothing
        fails."""
        rate = 0.0
        if not math.isinf(self.mtbf_npu_hours):
            rate += n_npus / (self.mtbf_npu_hours * HOUR_S)
        if not math.isinf(self.mtbf_wafer_hours):
            rate += n_wafers / (self.mtbf_wafer_hours * HOUR_S)
        return math.inf if rate == 0.0 else 1.0 / rate


# --------------------------------------------------------------------------
# checkpoint cost from the memory model
# --------------------------------------------------------------------------

def checkpoint_state_bytes(w: Workload, mem: MemoryModel) -> float:
    """Total persistent state one checkpoint must capture, bytes.

    Weights plus optimizer state for the whole model (summed over all
    shards — the write crosses the wafer I/O either way); activations
    are recomputed on restore, never written.  Weight-streaming runs
    keep the optimizer near storage but the checkpoint still has to
    commit it — same byte count, same I/O path."""
    params = w.params_per_layer * w.n_layers
    per_param = float(BYTES)
    if mem.training:
        per_param += optimizer_bytes_per_param(mem.master, mem.moments_dtype)
    return params * per_param


def checkpoint_write_s(w: Workload, mem: MemoryModel,
                       wafer_io_rate: float) -> float:
    """Seconds per checkpoint: state bytes over the aggregate I/O rate
    of the wafers the strategy actually spans (wafers write their shards
    in parallel)."""
    bw = wafer_io_rate * max(w.strategy.wafers, 1)
    return checkpoint_state_bytes(w, mem) / bw


# --------------------------------------------------------------------------
# Young–Daly checkpoint-interval math (closed form)
# --------------------------------------------------------------------------

def young_daly_interval(ckpt_s: float, mtbf_s: float) -> float:
    """The classic first-order optimum ``τ* = √(2δM)``, seconds."""
    if math.isinf(mtbf_s) or ckpt_s <= 0.0:
        return math.inf if math.isinf(mtbf_s) else 0.0
    return math.sqrt(2.0 * ckpt_s * mtbf_s)


def useful_fraction(interval_s: float, ckpt_s: float, restart_s: float,
                    mtbf_s: float) -> float:
    """Expected fraction of wall-clock doing useful work at a fixed
    checkpoint interval — exact under exponential failures.

    A segment is ``τ`` useful work + the ``δ`` checkpoint write; a
    failure at any point restarts the segment after ``R`` recovery.  The
    renewal expectation for one committed segment is
    ``E = (M + R)·(e^{(τ+δ)/M} − 1)``, so the fraction is ``τ / E``.
    ``mtbf = ∞`` degenerates to ``τ/(τ+δ)`` and zero checkpoint cost to
    exactly 1.0."""
    if interval_s <= 0.0:
        raise ValueError(f"checkpoint interval must be > 0, got "
                         f"{interval_s}")
    if math.isinf(mtbf_s):
        if ckpt_s == 0.0:
            return 1.0
        return interval_s / (interval_s + ckpt_s)
    length = interval_s + ckpt_s
    return interval_s / ((mtbf_s + restart_s) * math.expm1(length / mtbf_s))


def optimal_interval(ckpt_s: float, restart_s: float, mtbf_s: float, *,
                     min_interval_s: float = 1.0) -> float:
    """The interval maximizing :func:`useful_fraction` (exact model, not
    just the Young–Daly seed), via deterministic ternary search — the
    objective is unimodal in ``τ``.  ``inf`` when nothing ever fails
    (never checkpoint)."""
    if math.isinf(mtbf_s):
        return math.inf
    if ckpt_s <= 0.0:
        return min_interval_s
    seed = young_daly_interval(ckpt_s, mtbf_s)
    lo = min_interval_s
    hi = max(8.0 * seed, 2.0 * lo)
    for _ in range(200):
        m1 = lo + (hi - lo) / 3.0
        m2 = hi - (hi - lo) / 3.0
        if useful_fraction(m1, ckpt_s, restart_s, mtbf_s) \
                < useful_fraction(m2, ckpt_s, restart_s, mtbf_s):
            lo = m1
        else:
            hi = m2
    return (lo + hi) / 2.0


def time_fractions(interval_s: float, ckpt_s: float, restart_s: float,
                   mtbf_s: float) -> Dict[str, float]:
    """Exact wall-clock decomposition at a fixed interval: fractions of
    expected time spent on useful steps, checkpoint writes, recovery
    (restarts), and lost work (progress a failure threw away).  Sums to
    1.0."""
    if math.isinf(mtbf_s):
        length = interval_s + ckpt_s
        if ckpt_s == 0.0:
            return {"useful": 1.0, "checkpoint": 0.0, "lost": 0.0,
                    "recovery": 0.0}
        return {"useful": interval_s / length, "checkpoint": ckpt_s / length,
                "lost": 0.0, "recovery": 0.0}
    length = interval_s + ckpt_s
    fails = math.expm1(length / mtbf_s)     # expected failures per segment
    expected = (mtbf_s + restart_s) * fails
    useful = interval_s / expected
    ckpt = ckpt_s / expected
    recovery = restart_s * fails / expected
    lost = max(0.0, 1.0 - useful - ckpt - recovery)
    return {"useful": useful, "checkpoint": ckpt, "lost": lost,
            "recovery": recovery}


# --------------------------------------------------------------------------
# elastic degradation chain (yield_study-style fallback re-sweeps)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LifetimePoint:
    """One degradation state: the run after ``n_failed`` NPU deaths."""
    n_failed: int
    alive: bool
    time_per_sample_s: float          # repro: unit[s] (0.0 when dead)
    source: str                       # winner | degraded | fallback | dead
    reason: str = ""                  # why the previous plan died
    fallback: Optional[SweepResult] = None


def _rank_key(r: SweepResult):
    """autostrategy's deterministic tiebreak chain (duplicated here to
    keep core/lifetime.py importable without core/autostrategy.py)."""
    from .cluster import TOPOLOGY_CODES
    return (r.time_per_sample, r.memory_bytes_per_npu, r.n_wafers,
            TOPOLOGY_CODES.get(r.inter_topology, -1), len(r.hierarchy),
            r.fabric, r.hierarchy, r.shape,
            (r.strategy.mp, r.strategy.dp, r.strategy.pp,
             r.strategy.ep, r.strategy.sp))


def _same_hardware(a: SweepResult, b: SweepResult) -> bool:
    """True when ``a`` runs on the hardware ``b`` was deployed on — a
    mid-run re-plan can change the parallelization, never the wafer."""
    return (a.fabric, a.shape, a.n_wafers, a.inter_topology, a.hierarchy) \
        == (b.fabric, b.shape, b.n_wafers, b.inter_topology, b.hierarchy)


def _elastic_reachable(a: SweepResult, b: SweepResult) -> bool:
    """True when a mid-run recovery can re-plan deployment ``b`` into
    ``a`` — the cost-model mirror of ``train/elastic.py``'s
    ``plan_shrink``: the DP degree flexes freely, the model axis keeps
    its tensor layout (``mp`` stays or shrinks to a divisor, exactly the
    head/FFN-divisibility story), and the pipeline/expert/sequence axes
    are frozen (re-balancing stages or re-sharding experts mid-run is a
    cold restart, not a recovery)."""
    sa, sb = a.strategy, b.strategy
    return (sa.pp == sb.pp and sa.ep == sb.ep and sa.sp == sb.sp
            and sa.wafers == sb.wafers
            and sa.mp <= sb.mp and sb.mp % sa.mp == 0)


def degradation_chain(workload_fn: Callable, winner: SweepResult,
                      n_npus: int, *,
                      n_states: int = 3,
                      seed: int = 0,
                      compute_efficiency: float = 0.45,
                      sweep_kw: Optional[Dict] = None,
                      inter_kw: Optional[Dict] = None,
                      check_routing: bool = False,
                      uplinks: Optional[int] = None,
                      fallback_cache: Optional[Dict] = None
                      ) -> List[LifetimePoint]:
    """States 0..``n_states``: step time after each cumulative failure.

    A seeded kill order (``random.Random(seed)``) fixes which NPU dies
    at each failure; state ``k`` evaluates the candidate under the
    cumulative ``k``-dead mask exactly the way the yield study does —
    degraded in place when it survives, re-swept onto the survivors when
    it doesn't.  Unlike the yield study's free fallback, the re-sweep is
    pinned to the *deployed hardware* (same fabric, wafer shape, wafer
    count, inter topology — a mid-run failure can re-plan the
    parallelization, not re-wire the wafer) and to the
    *elastic-reachable* strategies (:func:`_elastic_reachable`: DP
    flexes, MP keeps or shrinks to a divisor, PP/EP/SP frozen — the cost
    model mirror of ``train/elastic.py``'s ``plan_shrink``).  That
    restriction is what makes fragility real: an MP(1)-DP(n) deployment
    has nowhere to re-plan to when DP candidates dry up, while an
    MP-heavy sibling can fold its model axis down.  ``fallback_cache``
    shares the per-(mask, hardware, reachability) re-sweeps across
    candidates.  The chain ends early at the first state with no
    feasible fallback; everything after is dead time."""
    sweep_kw = dict(sweep_kw or {})
    inter_kw = dict(inter_kw or {})
    rng = random.Random(seed)
    order = rng.sample(range(n_npus), min(n_states, n_npus - 1))
    points = [LifetimePoint(n_failed=0, alive=True,
                            time_per_sample_s=winner.time_per_sample,
                            source="winner")]
    cache = fallback_cache if fallback_cache is not None else {}
    for k in range(1, len(order) + 1):
        mask = normalize(DefectMask(n_npus, dead_npus=tuple(order[:k])))
        assert mask is not None
        ok, reason, t = _winner_survives(
            winner, workload_fn, mask, n_npus, compute_efficiency,
            check_routing, uplinks, inter_kw)
        if ok:
            scale = t / winner.total if winner.total > 0 else 1.0
            points.append(LifetimePoint(
                n_failed=k, alive=True,
                time_per_sample_s=winner.time_per_sample * scale,
                source="degraded"))
            continue
        st = winner.strategy
        ck = (mask, winner.fabric, winner.shape, winner.n_wafers,
              winner.inter_topology, winner.hierarchy,
              st.mp, st.pp, st.ep, st.sp, st.wafers)
        if ck not in cache:
            try:
                kw = dict(sweep_kw)
                kw["fabrics"] = (winner.fabric,)
                cands = [x for x in sweep(workload_fn, n_npus,
                                          defects=mask, **kw)
                         if x.feasible and _same_hardware(x, winner)
                         and _elastic_reachable(x, winner)]
                cache[ck] = min(cands, key=_rank_key) if cands else None
            except ValueError:
                cache[ck] = None
        fb = cache[ck]
        if fb is None:
            points.append(LifetimePoint(n_failed=k, alive=False,
                                        time_per_sample_s=0.0,
                                        source="dead", reason=reason))
            break
        points.append(LifetimePoint(n_failed=k, alive=True,
                                    time_per_sample_s=fb.time_per_sample,
                                    source="fallback", reason=reason,
                                    fallback=fb))
    return points


# --------------------------------------------------------------------------
# mission-level estimate + event simulation
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LifetimeEstimate:
    """Mission-averaged verdict for one candidate strategy."""
    mtbf_s: float                     # repro: unit[s] (system MTBF)
    ckpt_write_s: float               # repro: unit[s]
    interval_s: float                 # repro: unit[s] (chosen, optimal)
    restart_s: float                  # repro: unit[s]
    mission_s: float                  # repro: unit[s]
    fractions: Dict[str, float]       # useful/checkpoint/lost/recovery
                                      # at the healthy state
    goodput_samples_per_s: float      # mission-averaged useful samples/s
    chain: Tuple[LifetimePoint, ...]  # degradation states traversed
    n_expected_failures: int

    @property
    def survives_mission(self) -> bool:
        return all(p.alive for p in self.chain)

    @property
    def samples_total(self) -> float:
        return self.goodput_samples_per_s * self.mission_s


def estimate_lifetime(chain: Sequence[LifetimePoint], *,
                      ckpt_write_s: float, restart_s: float, mtbf_s: float,
                      mission_s: float,
                      min_interval_s: float = 1.0) -> LifetimeEstimate:
    """Deterministic expectation walk over the degradation chain.

    The mission is partitioned into failure states: state ``k`` lasts
    one system-MTBF in expectation (the last one only to the mission
    end).  Each alive state contributes
    ``useful_fraction(τ*, δ, R, M) / time_per_sample`` samples per
    second; a chain exhausted while still alive holds its last step time
    (further failures keep landing on an already-degraded plan), and a
    dead chain contributes nothing for the rest of the mission."""
    interval = optimal_interval(ckpt_write_s, restart_s, mtbf_s,
                                min_interval_s=min_interval_s)
    if math.isinf(mtbf_s):
        # never fails → never checkpoints: the useful fraction is
        # exactly 1.0, making goodput ranking bit-identical to time
        fr = {"useful": 1.0, "checkpoint": 0.0, "lost": 0.0,
              "recovery": 0.0}
        t0 = chain[0].time_per_sample_s
        goodput = fr["useful"] / t0 if chain[0].alive and t0 > 0 else 0.0
        return LifetimeEstimate(
            mtbf_s=mtbf_s, ckpt_write_s=ckpt_write_s, interval_s=interval,
            restart_s=restart_s, mission_s=mission_s,
            fractions=fr, goodput_samples_per_s=goodput,
            chain=tuple(chain[:1]), n_expected_failures=0)
    fr = time_fractions(interval, ckpt_write_s, restart_s, mtbf_s)
    n_fail = int(mission_s // mtbf_s)
    samples = 0.0
    remaining = mission_s
    traversed: List[LifetimePoint] = []
    for k in range(n_fail + 1):
        duration = min(mtbf_s, remaining) if k < n_fail else remaining
        point = chain[min(k, len(chain) - 1)]
        traversed.append(point)
        if point.alive and point.time_per_sample_s > 0:
            samples += duration * fr["useful"] / point.time_per_sample_s
        remaining -= duration
        if not point.alive:
            break
    return LifetimeEstimate(
        mtbf_s=mtbf_s, ckpt_write_s=ckpt_write_s, interval_s=interval,
        restart_s=restart_s, mission_s=mission_s, fractions=fr,
        goodput_samples_per_s=samples / mission_s if mission_s > 0 else 0.0,
        chain=tuple(traversed), n_expected_failures=n_fail)


def simulate_lifetime(chain: Sequence[LifetimePoint], *,
                      ckpt_write_s: float, restart_s: float, mtbf_s: float,
                      mission_s: float, seed: int = 0,
                      interval_s: Optional[float] = None
                      ) -> Dict[str, float]:
    """Seeded event-driven cross-check of :func:`estimate_lifetime`.

    Draws exponential failure times (``random.Random(seed)``), runs the
    segment/checkpoint/restart loop, advances the degradation chain one
    state per failure, and tallies wall-clock per category.  Returns
    ``{"samples", "useful_s", "checkpoint_s", "lost_s", "recovery_s",
    "n_failures"}`` — the tests assert the long-run averages agree with
    the closed form."""
    rng = random.Random(seed)
    interval = interval_s if interval_s is not None else \
        optimal_interval(ckpt_write_s, restart_s, mtbf_s)
    tallies = {"samples": 0.0, "useful_s": 0.0, "checkpoint_s": 0.0,
               "lost_s": 0.0, "recovery_s": 0.0, "n_failures": 0.0}
    now = 0.0
    state = 0
    next_fail = rng.expovariate(1.0 / mtbf_s) if not math.isinf(mtbf_s) \
        else math.inf
    segment_done = 0.0            # useful seconds since last commit
    while now < mission_s:
        point = chain[min(state, len(chain) - 1)]
        if not point.alive:
            tallies["lost_s"] += mission_s - now
            break
        seg_len = interval if not math.isinf(interval) else mission_s - now
        end = now + (seg_len - segment_done) + ckpt_write_s
        if end <= next_fail or math.isinf(mtbf_s):
            work = seg_len - segment_done
            tallies["useful_s"] += work
            tallies["checkpoint_s"] += min(ckpt_write_s, mission_s - now)
            tallies["samples"] += work / point.time_per_sample_s
            now = end
            segment_done = 0.0
        else:
            lost = next_fail - now
            tallies["lost_s"] += lost
            tallies["recovery_s"] += restart_s
            tallies["n_failures"] += 1
            now = next_fail + restart_s
            segment_done = 0.0
            state += 1
            next_fail = now + rng.expovariate(1.0 / mtbf_s)
    return tallies


# --------------------------------------------------------------------------
# end-to-end candidate evaluation (what choose_strategy ranks by)
# --------------------------------------------------------------------------

def evaluate_candidate(workload_fn: Callable, r: SweepResult, n_npus: int, *,
                       failure: FailureModel, mem: MemoryModel,
                       n_states: int = 3, seed: int = 0,
                       compute_efficiency: float = 0.45,
                       sweep_kw: Optional[Dict] = None,
                       inter_kw: Optional[Dict] = None,
                       fallback_cache: Optional[Dict] = None
                       ) -> LifetimeEstimate:
    """Lifetime estimate for one sweep candidate.

    Derives the checkpoint write cost from the candidate's own workload
    state bytes over its fabric's wafer I/O rate, the system MTBF from
    the NPUs/wafers the strategy actually uses, and the degradation
    chain from seeded cumulative failures with fallback re-sweeps.  At
    ``mtbf = ∞`` the chain is skipped entirely (nothing fails) and the
    estimate reduces to the healthy per-sample rate."""
    st = r.strategy
    w = workload_fn(st)
    mtbf_s = failure.system_mtbf_s(st.mp * st.dp * st.pp,
                                   max(st.wafers, 1))
    inter_kw = dict(inter_kw or {})
    sim = _simulator(r.fabric, r.shape, n_npus, {}, compute_efficiency,
                     n_wafers=r.n_wafers,
                     hierarchy=r.hierarchy if r.n_wafers > 1 else None,
                     inter_topology=r.inter_topology, **inter_kw)
    ckpt_s = checkpoint_write_s(w, mem, sim._io_rate())
    if math.isinf(mtbf_s):
        chain: List[LifetimePoint] = [LifetimePoint(
            n_failed=0, alive=True, time_per_sample_s=r.time_per_sample,
            source="winner")]
    else:
        chain = degradation_chain(
            workload_fn, r, n_npus, n_states=n_states, seed=seed,
            compute_efficiency=compute_efficiency, sweep_kw=sweep_kw,
            inter_kw=inter_kw, fallback_cache=fallback_cache)
    return estimate_lifetime(chain, ckpt_write_s=ckpt_s,
                             restart_s=failure.restart_s, mtbf_s=mtbf_s,
                             mission_s=failure.mission_s)
