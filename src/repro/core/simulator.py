"""End-to-end training-time simulator (ASTRA-SIM-style, paper Sec. VII).

Models one training iteration of a 3D-parallel workload on either the
baseline 2D-mesh or a FRED fabric:

  * compute: per-layer FLOPs / (peak·efficiency), MP-sharded;
  * MP comm: blocking All-Reduces per layer (forward and backward);
  * EP comm (MoE workloads with ``Strategy.ep > 1``): expert dispatch +
    combine All-to-All within each ep-sized DP subgroup, replacing one of
    the per-layer MP All-Reduces (the FFN one the dispatch subsumes); a
    ``comm_overlap_fraction`` share of the compute can hide EP then MP
    time, with the remainder reported as ``exposed_comm_s``;
  * PP: GPipe microbatching — bubble factor (M + S − 1)/M plus boundary
    activation transfers;
  * DP comm: per-layer gradient All-Reduce issued as backward finishes,
    overlapped with remaining backward compute (water-filling); exposed
    remainder is reported;
  * weight streaming: layer weights stream in at the fabric's sustainable
    I/O rate (hotspot-limited on the mesh, line-rate on FRED) overlapped
    with compute; gradients stream out during backward; optimizer runs
    near storage (Sec. III-A);
  * input loading: minibatch activations via I/O, prefetchable except
    under weight streaming (I/O busy ⇒ exposed, Sec. VIII Transformer-1T).

Returned ``Breakdown`` mirrors Fig. 10's stacks: compute + exposed
input-load / MP / DP / PP / weight-stream times.

Multi-wafer clusters (``n_wafers > 1``, core/cluster.py): DP replicas map
across wafers (cluster_placement), MP/PP stay within a wafer; the DP
All-Reduce runs hierarchically — Reduce-Scatter within wafer → per-level
inter collectives → All-Gather within wafer — and the raw per-level times
are reported as ``dp_intra``/``dp_inter``/``dp_levels``.  The inter
levels are configurable: ``hierarchy`` stacks wafer → rack → pod counts
and ``inter_topology`` selects the per-level collective model (``ring`` |
``fully_connected`` | ``switch`` — see core/cluster.py).  ``n_wafers=1``
is bit-identical to the single-wafer model, and a single ``ring`` level
(the defaults) is bit-identical to the PR-2 wafer↔wafer ring.
"""

from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

from .defects import DefectMask
from .fabric import FredFabric
from .meshnet import MeshFabric
from .placement import Strategy, cached_placement_groups
from .specs import ClusterSpec, FabricSpec
from .workloads import Workload, BYTES

NPU_PEAK_FLOPS = 1000e12      # FP16 (Table II)


class LRUCache(collections.OrderedDict):
    """Bounded dict for ``Simulator.collective_cache`` sharing.

    Long multi-wafer sweeps accumulate one entry per distinct
    (fabric tag, kind, group, bytes, concurrency) tuple; unbounded, a
    500+-NPU scalar sweep grows without limit.  Reads refresh recency,
    writes evict the least-recently-used entry past ``maxsize`` — drop-in
    for the plain dict the Simulator expects (``get`` + item assignment).
    """

    def __init__(self, maxsize: int = 1 << 17):
        super().__init__()
        if maxsize < 1:
            raise ValueError(f"maxsize must be ≥ 1, got {maxsize}")
        self.maxsize = maxsize

    def get(self, key, default=None):
        try:
            value = super().__getitem__(key)
        except KeyError:
            return default
        self.move_to_end(key)
        return value

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            self.popitem(last=False)


@dataclasses.dataclass
class Breakdown:
    workload: str
    fabric: str
    compute: float        # repro: unit[s]
    input_load: float     # repro: unit[s]
    mp: float             # repro: unit[s]
    dp: float             # repro: unit[s]
    pp: float             # repro: unit[s]
    stream: float         # repro: unit[s]
    # per-level DP split (informational): raw un-overlapped All-Reduce time
    # spent within wafers vs across the inter-level links.  ``dp`` remains
    # the *exposed* DP time and is what ``total`` counts; on a single wafer
    # dp_intra is the raw AR sum and dp_inter is 0.  ``dp_levels`` splits
    # dp_inter per hierarchy level (wafer↔wafer/rack, rack↔rack/pod, …);
    # empty on a single wafer, one entry per inter level on a cluster.
    dp_intra: float = 0.0             # repro: unit[s]
    dp_inter: float = 0.0             # repro: unit[s]
    dp_levels: Tuple[float, ...] = () # repro: unit[s]
    # exposed expert-parallel All-to-All time (0 unless Strategy.ep > 1 on
    # an MoE workload); counted by ``total``
    ep_s: float = 0.0                 # repro: unit[s]
    # blocking comm left after compute/comm overlap: post-overlap mp + ep_s
    # (informational — ``mp`` and ``ep_s`` already hold the reduced values)
    exposed_comm_s: float = 0.0       # repro: unit[s]

    @property
    def total(self) -> float:
        return (self.compute + self.input_load + self.mp + self.dp +
                self.pp + self.stream + self.ep_s)

    def as_dict(self) -> Dict[str, float]:
        # float-valued only (callers reduce over values); the per-level
        # dp split lives in the ``dp_levels`` attribute, whose sum is
        # ``dp_inter``
        return {"compute": self.compute, "input_load": self.input_load,
                "mp": self.mp, "dp": self.dp, "pp": self.pp,
                "stream": self.stream, "dp_intra": self.dp_intra,
                "dp_inter": self.dp_inter, "ep_s": self.ep_s,
                "exposed_comm_s": self.exposed_comm_s, "total": self.total}


_LEGACY_FABRIC_KW = ("mesh_shape", "fred_shape", "n_io")
_LEGACY_CLUSTER_KW = ("n_wafers", "inter_wafer_links", "inter_wafer_bw",
                      "inter_wafer_latency", "inter_topology", "hierarchy")


@dataclasses.dataclass
class Simulator:
    fabric_name: str                       # "baseline" | "FRED-A".."FRED-D"
    compute_efficiency: float = 0.45
    overlap_dp: bool = True
    # fraction of the compute time available to hide blocking collectives
    # (EP first, then MP): exposed = max(0, comm − fraction·compute).
    # 0.0 (the default) keeps comm fully additive — bit-identical to the
    # pre-overlap model.
    comm_overlap_fraction: float = 0.0
    # ---- consolidated construction specs (core/specs.py) ----------------
    spec: Optional[FabricSpec] = None              # wafer shape/io/defects
    cluster_spec: Optional[ClusterSpec] = None     # inter-wafer scale-out
    collective_cache: Optional[dict] = None        # shared memo for sweeps
    # ---- DEPRECATED kwarg shims: each one, when passed, overrides the
    # matching spec field (with a DeprecationWarning).  After construction
    # the attributes hold the *resolved* values either way, so existing
    # readers keep working.
    mesh_shape: Optional[Tuple[int, int]] = None   # (rows, cols); None → 5×4
    fred_shape: Optional[Tuple[int, int]] = None   # (n_groups, group_size)
    n_io: Optional[int] = None                     # None → derived / paper 18
    n_wafers: Optional[int] = None                 # 1 ≡ single wafer
    inter_wafer_links: Optional[int] = None        # links per unit per level
    inter_wafer_bw: Optional[float] = None         # B/s per link per dir
    inter_wafer_latency: Optional[float] = None    # repro: unit[s] per step
    inter_topology: Optional[str] = None           # ring | fully_connected
                                                   # | switch (every level)
    hierarchy: Optional[Tuple[int, ...]] = None    # level counts, innermost
                                                   # first; None → (n_wafers,)

    def _resolve_specs(self):
        """Merge the deprecated kwargs into FabricSpec/ClusterSpec and
        write the resolved values back onto the legacy attributes."""
        legacy = {k: getattr(self, k)
                  for k in _LEGACY_FABRIC_KW + _LEGACY_CLUSTER_KW
                  if getattr(self, k) is not None}
        if legacy:
            warnings.warn(
                f"Simulator({', '.join(sorted(legacy))}=...) kwargs are "
                f"deprecated; pass spec=FabricSpec(...) / "
                f"cluster_spec=ClusterSpec(...) instead",
                DeprecationWarning, stacklevel=4)
        spec = self.spec if self.spec is not None else FabricSpec()
        fkw = {k: legacy[k] for k in _LEGACY_FABRIC_KW if k in legacy}
        if fkw:
            spec = dataclasses.replace(spec, **fkw)
        cs = (self.cluster_spec if self.cluster_spec is not None
              else ClusterSpec())
        ckw = {k: legacy[k] for k in _LEGACY_CLUSTER_KW if k in legacy}
        if ckw:
            cs = dataclasses.replace(cs, **ckw)
        self.spec, self.cluster_spec = spec, cs
        self.mesh_shape, self.fred_shape = spec.mesh_shape, spec.fred_shape
        self.n_io = spec.n_io
        self.defects: Optional[DefectMask] = spec.defects
        self.wafer_defects = cs.wafer_defects
        if self.wafer_defects is not None:
            if self.defects is not None:
                raise ValueError(
                    "FabricSpec.defects (one mask for every wafer) and "
                    "ClusterSpec.wafer_defects (one mask per wafer) are "
                    "mutually exclusive")
            if cs.n_wafers == 1:
                raise ValueError(
                    "wafer_defects needs a multi-wafer cluster — use "
                    "FabricSpec.defects for a single wafer")
        self.n_wafers = cs.n_wafers
        self.inter_wafer_links = cs.inter_wafer_links
        self.inter_wafer_bw = cs.inter_wafer_bw
        self.inter_wafer_latency = cs.inter_wafer_latency
        self.inter_topology = cs.inter_topology
        self.hierarchy = cs.hierarchy

    def __post_init__(self):
        self._resolve_specs()
        if self.fabric_name == "baseline":
            kw = {} if self.mesh_shape is None else \
                dict(rows=self.mesh_shape[0], cols=self.mesh_shape[1])
            if self.n_io is not None:
                kw["n_io"] = self.n_io
            if self.defects is not None:
                kw["defects"] = self.defects
            self.mesh: Optional[MeshFabric] = MeshFabric(**kw)
            self.fred: Optional[FredFabric] = None
        else:
            from .fabric import CONFIGS
            if self.fabric_name not in CONFIGS:
                raise ValueError(
                    f"unknown fabric {self.fabric_name!r}; expected "
                    f"'baseline' or one of {sorted(CONFIGS)}")
            kw = {} if self.fred_shape is None else \
                dict(n_groups=self.fred_shape[0],
                     group_size=self.fred_shape[1])
            if self.n_io is not None:
                kw["n_io"] = self.n_io
            if self.defects is not None:
                kw["defects"] = self.defects
            self.mesh = None
            self.fred = FredFabric(CONFIGS[self.fabric_name], **kw)
        self.cluster = None
        if self.hierarchy is not None:
            prod = 1
            for c in self.hierarchy:
                prod *= c
            if self.n_wafers == 1:
                self.n_wafers = prod
            elif self.n_wafers != prod:
                raise ValueError(
                    f"n_wafers={self.n_wafers} inconsistent with "
                    f"hierarchy={self.hierarchy} (product {prod})")
        if self.n_wafers < 1:
            raise ValueError(f"n_wafers must be ≥ 1, got {self.n_wafers}")
        if self.n_wafers > 1:
            from .cluster import (HierarchyLevel, LEVEL_NAMES, WaferCluster,
                                  WaferLink)
            base = self.mesh if self.mesh is not None else self.fred
            link = WaferLink(self.inter_wafer_links, self.inter_wafer_bw,
                             self.inter_wafer_latency)
            counts = (self.hierarchy if self.hierarchy is not None
                      else (self.n_wafers,))
            levels = tuple(
                HierarchyLevel(LEVEL_NAMES[min(i, len(LEVEL_NAMES) - 1)],
                               c, self.inter_topology, link)
                for i, c in enumerate(counts))
            self.cluster = WaferCluster(base, self.n_wafers, levels=levels,
                                        wafer_defects=self.wafer_defects)

    @property
    def n_npus(self) -> int:
        if self.cluster is not None:
            return self.cluster.n_npus
        return self.mesh.n if self.mesh is not None else self.fred.n_npus

    @property
    def n_healthy_npus(self) -> int:
        """Usable NPUs after the defect mask (mask applies per wafer)."""
        if self.wafer_defects is not None:
            return self.cluster.n_healthy_npus
        if self.defects is None:
            return self.n_npus
        per_wafer = self.defects.n_healthy
        return per_wafer * (self.n_wafers if self.cluster is not None else 1)

    # ---- fabric dispatch -------------------------------------------------------
    def _groups(self, strategy: Strategy):
        """NPU-id groups for ``strategy`` on this fabric — memoized per
        (strategy, n_wafers, npus_per_wafer): mesh row-major placement
        linearizes to the same ids as fred_placement, so the canonical
        cached groups serve every fabric type (treat them as read-only)."""
        if self.cluster is not None:
            return cached_placement_groups(strategy, self.n_wafers,
                                           self.cluster.npus_per_wafer,
                                           self.defects,
                                           wafer_defects=self.wafer_defects)
        if strategy.wafers > 1:
            raise ValueError(
                f"{strategy} spans {strategy.wafers} wafers but this "
                f"simulator models a single wafer (n_wafers=1)")
        return cached_placement_groups(strategy, 1, self.n_npus,
                                       self.defects)

    def _fabric_tag(self):
        """Physical identity of the fabric, so one collective_cache dict
        can be shared across Simulators of different fabrics/shapes."""
        if self.mesh is not None:
            tag = ("mesh", self.mesh.rows, self.mesh.cols, self.mesh.link_bw,
                   self.mesh.latency_per_hop, self.mesh.step_overhead)
        else:
            c, f = self.fred.config, self.fred
            tag = (c.name, f.n_groups, f.group_size, c.npu_l1_bw, c.l1_l2_bw,
                   c.in_network, c.switch_latency, c.step_overhead)
        if self.defects is not None:
            tag = tag + (self.defects,)
        if self.cluster is not None:
            return self.cluster.tag() + tag
        return tag

    def _coll_time_levels(self, kind: str, group, nbytes: float,
                          concurrent: int,
                          inter_concurrent: Optional[int] = None,
                          ring_family: Optional[Tuple[int, int, int]] = None
                          ) -> Tuple[float, Tuple[float, ...]]:
        """(intra-wafer, per-inter-level) time for one collective; the
        inter tuple is empty on a single wafer and all-zero for groups
        contained within one wafer of a cluster.

        ``ring_family`` is the compact ``(count, stride, n_used)``
        descriptor of the strided concurrent-group family ``group``
        belongs to (see :func:`~repro.core.meshnet.strided_ring_family`).
        Under a defect mask the mesh path materializes the family so the
        evaluated ring pays the real shared-link bandwidth on detour
        paths; healthy meshes ignore it (disjoint X-Y rings)."""
        if self.collective_cache is not None:
            key = (self._fabric_tag(), kind, tuple(group), nbytes,
                   concurrent, inter_concurrent, ring_family)
            hit = self.collective_cache.get(key)
            if hit is not None:
                return hit
        if self.cluster is not None:
            parts = self.cluster.collective_time_levels(
                kind, group, nbytes, concurrent_groups=concurrent,
                inter_concurrent_groups=inter_concurrent,
                ring_family=ring_family)
        elif self.mesh is not None:
            rings: Tuple = ()
            if ring_family is not None and self.defects is not None:
                from .meshnet import strided_ring_family
                rings = strided_ring_family(self.defects.healthy(),
                                            *ring_family)
            parts = (self.mesh.collective_time(kind, group, nbytes,
                                               concurrent_rings=rings), ())
        else:
            parts = (self.fred.collective_time(kind, group, nbytes,
                                               concurrent_groups=concurrent),
                     ())
        if self.collective_cache is not None:
            self.collective_cache[key] = parts
        return parts

    def _coll_time(self, kind: str, group, nbytes: float, concurrent: int,
                   ring_family: Optional[Tuple[int, int, int]] = None
                   ) -> float:
        intra, levels = self._coll_time_levels(kind, group, nbytes,
                                               concurrent,
                                               ring_family=ring_family)
        t = intra
        for x in levels:
            t += x
        return t

    def _pp_time(self, nbytes: float) -> float:
        if self.cluster is not None:
            return self.cluster.pp_transfer_time(nbytes)
        if self.mesh is not None:
            return self.mesh.pp_transfer_time(nbytes)
        return self.fred.pp_transfer_time(nbytes)

    def _io_rate(self) -> float:
        """Per-wafer sustainable I/O rate (wafers stream independently)."""
        if self.cluster is not None:
            return self.cluster.wafer_io_rate()
        if self.mesh is not None:
            return self.mesh.io_stream_rate()
        return self.fred.io_stream_rate()

    # ---- main -------------------------------------------------------------------
    def run(self, w: Workload) -> Breakdown:
        st = w.strategy
        groups = self._groups(st)
        mp_group = groups["mp"][0]
        dp_group = groups["dp"][0]
        n_dp_groups = len(groups["dp"])
        if st.pp > w.n_layers:
            raise ValueError(
                f"{st} has pp={st.pp} stages but {w.name} only "
                f"{w.n_layers} layers — stages must hold whole layers")
        if st.ep > 1 and st.dp_per_wafer % st.ep != 0:
            raise ValueError(
                f"{st}: ep={st.ep} must divide the per-wafer DP degree "
                f"{st.dp_per_wafer} — EP groups stay within a wafer")
        if st.sp > 1 and st.mp % st.sp != 0:
            raise ValueError(
                f"{st}: sp={st.sp} must divide mp={st.mp} — sequence "
                f"parallelism splits activations across MP peers")
        # uneven division: the pipeline is paced by its largest stage, so
        # compute/MP/DP are modeled at ceil(n_layers / pp) layers per stage
        # (exact when pp divides n_layers)
        layers_per_stage = -(-w.n_layers // st.pp)
        samples_per_npu = w.samples_per_dp
        # NPUs used per wafer — the id range the strided concurrent-group
        # families of every parallelism axis tile (meshnet
        # strided_ring_family); descriptors ride to the mesh path so
        # masked collectives see their siblings' detour congestion
        n_used = st.mp * st.pp * st.dp_per_wafer

        # ---- compute ------------------------------------------------------------
        eff_flops = NPU_PEAK_FLOPS * self.compute_efficiency
        fwd_layer = (w.flops_fwd_per_sample_layer * samples_per_npu /
                     st.mp / eff_flops)
        bwd_layer = 2 * fwd_layer
        fwd_stage = fwd_layer * layers_per_stage
        bwd_stage = bwd_layer * layers_per_stage

        # GPipe microbatching (Sec. VII-C: 8 microbatches for T-17B; weight
        # streaming uses pp-many, which suffices to hide the tiny pipeline)
        microbatches = 8 if (st.pp > 1 and w.execution == "stationary") else \
            max(st.pp, 1)
        if st.pp > 1:
            bubble = (microbatches + st.pp - 1) / microbatches
        else:
            bubble = 1.0
        compute = (fwd_stage + bwd_stage) * bubble

        # ---- MP comm --------------------------------------------------------------
        # with EP active, the expert-dispatch All-to-All subsumes the FFN
        # All-Reduce — one fewer MP sync per layer per pass (Megatron/Tutel)
        ep_active = st.ep > 1 and w.a2a_bytes_per_sample_layer > 0.0
        mp_ar = w.mp_allreduce_per_layer
        if ep_active and mp_ar:
            mp_ar = mp_ar - 1
        mp_time = 0.0
        if st.mp > 1 and mp_ar:
            act_bytes = w.act_bytes_per_sample * samples_per_npu
            # MP groups contend within their own wafer only — the fabric-BW
            # share is the per-wafer group count (== total on one wafer)
            mp_conc = max(1, len(groups["mp"]) // st.wafers)
            per_layer = self._coll_time("all_reduce", mp_group, act_bytes,
                                        concurrent=mp_conc,
                                        ring_family=(st.mp, 1, n_used))
            # fwd + bwd, every layer of this stage, all microbatches pipelined
            mp_time = (per_layer * mp_ar * 2 *
                       layers_per_stage * bubble)

        # ---- EP comm (MoE expert dispatch/combine All-to-All) ----------------------
        ep_raw = 0.0
        if ep_active:
            # EP groups are ep consecutive DP peers of the same (mp, pp)
            # coordinate — the first ep members of the first DP group
            # (NPU-id stride mp·pp under the canonical placements, defect
            # remapping included), always within one wafer (ep | dp/wafer)
            ep_group = dp_group[:st.ep]
            ep_conc = max(1, st.mp * st.pp * st.dp // (st.ep * st.wafers))
            a2a_bytes = w.a2a_bytes_per_sample_layer * samples_per_npu
            per_layer = self._coll_time(
                "all_to_all", ep_group, a2a_bytes, concurrent=ep_conc,
                ring_family=(st.ep, st.mp * st.pp, n_used))
            # dispatch + combine (×2), fwd + bwd (×2), every layer, bubbled
            ep_raw = per_layer * 2 * 2 * layers_per_stage * bubble

        # ---- compute/comm overlap --------------------------------------------------
        # a comm_overlap_fraction share of the compute hides blocking
        # collectives: EP first (the dispatch sits right next to the expert
        # FLOPs it feeds), then MP with whatever budget remains
        overlappable = self.comm_overlap_fraction * compute
        ep_time = max(0.0, ep_raw - overlappable)
        rem = max(0.0, overlappable - ep_raw)
        mp_time = max(0.0, mp_time - rem)
        exposed_comm = mp_time + ep_time

        # ---- PP comm ---------------------------------------------------------------
        pp_time = 0.0
        if st.pp > 1:
            act_bytes = w.act_bytes_per_sample * samples_per_npu
            # fwd + bwd boundary transfer per microbatch, on the critical
            # path only for the bubble-exposed fraction; SP shards the
            # boundary tensor a further sp-way
            per_mb = 2 * self._pp_time(act_bytes / microbatches / st.sp)
            pp_time = per_mb * (microbatches + st.pp - 1)

        # ---- DP comm ----------------------------------------------------------------
        dp_time = 0.0
        dp_intra = dp_inter = 0.0
        n_inter_levels = (len(self.cluster.levels)
                          if self.cluster is not None else 0)
        lvl_acc = [0.0] * n_inter_levels
        grad_bytes_per_layer = w.params_per_layer * BYTES / st.mp
        if st.dp > 1 and w.execution == "stationary":
            # inside the wafer all mp·pp DP groups share the fabric, but on
            # the inter-level links only the mp groups of the same pipeline
            # stage contend — GPipe backward staggers the other stages.
            # One model evaluation; the per-layer accumulation stays a sum
            # (not a multiply) so totals match the seed bit-for-bit.
            ti, te_levels = self._coll_time_levels(
                "all_reduce", dp_group, grad_bytes_per_layer,
                concurrent=n_dp_groups, inter_concurrent=st.mp,
                ring_family=(st.dp_per_wafer, st.mp * st.pp, n_used))
            for _ in range(layers_per_stage):
                dp_intra += ti
                for i, te in enumerate(te_levels):
                    lvl_acc[i] += te
            for x in lvl_acc:
                dp_inter += x
            total_ar = dp_intra + dp_inter
            if self.overlap_dp:
                # layer-by-layer ARs overlap with remaining backward compute
                dp_time = max(0.0, total_ar - bwd_stage * (1 - 1 / max(layers_per_stage, 1)))
            else:
                dp_time = total_ar

        # ---- weight streaming ----------------------------------------------------------
        stream_time = 0.0
        input_load = 0.0
        if w.execution == "streaming":
            io_rate = self._io_rate()
            # model in (fwd) + model in again (bwd) + gradients out (bwd);
            # gradient reduction toward I/O happens in-fabric (reverse of
            # Fig. 4); all overlap with compute.  Every wafer streams the
            # same weights through its own I/O, so the time is per-wafer.
            stream_bytes = w.param_bytes_total * (2 + 1) / st.pp
            io_time = stream_bytes / io_rate
            exposed = max(0.0, io_time - compute - mp_time)
            stream_time = exposed
            # input minibatch cannot prefetch while weights stream (Sec
            # VIII); each wafer loads its own DP replicas' share in parallel
            in_bytes = w.minibatch * w.act_bytes_per_sample
            input_load = in_bytes / (io_rate * st.wafers)
        else:
            # input prefetched during previous iteration — not exposed
            input_load = 0.0

        return Breakdown(workload=w.name, fabric=self.fabric_name,
                         compute=compute, input_load=input_load,
                         mp=mp_time, dp=dp_time, pp=pp_time,
                         stream=stream_time, dp_intra=dp_intra,
                         dp_inter=dp_inter, dp_levels=tuple(lvl_acc),
                         ep_s=ep_time, exposed_comm_s=exposed_comm)


def compare(workload: Workload, fabrics=("baseline", "FRED-C", "FRED-D"),
            **kw) -> Dict[str, Breakdown]:
    return {f: Simulator(f, **kw).run(workload) for f in fabrics}


def speedup_table(**kw) -> Dict[str, Dict[str, float]]:
    """Fig. 10 headline: total-time speedup of FRED-C/D over baseline."""
    from .workloads import paper_workloads
    out = {}
    for w in paper_workloads():
        res = compare(w, **kw)
        base = res["baseline"].total
        out[w.name] = {f: base / br.total for f, br in res.items()}
    return out
