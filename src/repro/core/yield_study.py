"""Yield studies: does the auto-chosen strategy survive wafer defects?

Wafer-scale integration ships defective NPUs (the yield argument behind
Hecaton-style chiplet papers and the reason Cerebras reserves spare
cores); a strategy tuned for the pristine wafer is only deployable if it
— or a cheap fallback — still runs on the wafer you actually get.  This
module quantifies that:

  1. run the defect-free sweep and pick the winner exactly the way
     auto-strategy does (same Pareto front, same tiebreak),
  2. draw ``n_masks`` independent defect masks at a target dead-NPU rate
     (seeded: ``seed0 + i`` — the study is reproducible row for row),
  3. for each mask, check whether the winner *survives*: enough healthy
     NPUs per wafer, mesh still connected (baseline), optionally still
     conflict-free-routable (FRED), and the degraded simulation actually
     runs — recording the degraded time and slowdown when it does,
  4. when the winner dies, re-run the sweep *under the mask* and record
     the fallback decision the auto-strategy would pick on that wafer.

The result is a :class:`YieldReport`: survival rate, per-mask outcomes,
slowdown statistics, and the fallback table — ``benchmarks.run --only
faultsweep`` emits it as the CSV artifact and the CI gate pins
:meth:`YieldReport.golden` against ``tests/goldens/faultsweep.json``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import TOPOLOGY_CODES
from .defects import DefectMask, mesh_connected, normalize, sample_mask
from .placement import Strategy
from .routing import strategy_routable
from .sweep import SweepResult, _simulator, sweep
from .workloads import Workload

DEFAULT_FABRICS = ("baseline", "FRED-C", "FRED-D")


def pick_winner(results: Sequence[SweepResult]) -> SweepResult:
    """Deterministic choice from a sweep's Pareto front — the same
    tiebreak chain as ``autostrategy._pick`` (fastest, then smallest
    footprint, fewest wafers, cheapest inter-wafer topology, lexical)."""
    front = [r for r in results if r.pareto]
    if not front:
        raise ValueError("sweep produced no Pareto point (no feasible "
                         "candidate under the mask/memory model)")
    return min(front, key=lambda r: (
        r.time_per_sample, r.memory_bytes_per_npu, r.n_wafers,
        TOPOLOGY_CODES.get(r.inter_topology, -1), len(r.hierarchy),
        r.fabric, r.hierarchy, r.shape,
        (r.strategy.mp, r.strategy.dp, r.strategy.pp)))


@dataclasses.dataclass(frozen=True)
class MaskOutcome:
    """One defect draw's verdict on the defect-free winner."""
    seed: int
    n_dead: int                       # dead NPUs in the draw (after the
                                      # sampler's connectivity demotion)
    survived: bool
    reason: str                       # "" when survived; else capacity |
                                      # disconnected | unroutable | eval: …
    degraded_time_s: float            # winner's iteration time under the
                                      # mask (0.0 when it died)
    slowdown: float                   # degraded / healthy time (0.0 dead)
    fallback: Optional[SweepResult] = None   # degraded re-sweep winner
                                             # (None: survived, fallback
                                             # disabled, or none feasible)


@dataclasses.dataclass
class YieldReport:
    """Aggregate verdict of one yield study."""
    workload: str
    n_npus: int                       # per wafer
    dead_npu_rate: float              # sampler target rate
    winner: SweepResult               # defect-free choice
    outcomes: List[MaskOutcome]
    study_seconds: float

    @property
    def n_masks(self) -> int:
        return len(self.outcomes)

    @property
    def n_survived(self) -> int:
        return sum(1 for o in self.outcomes if o.survived)

    @property
    def survival_rate(self) -> float:
        return self.n_survived / max(self.n_masks, 1)

    @property
    def n_fallback(self) -> int:
        return sum(1 for o in self.outcomes
                   if not o.survived and o.fallback is not None)

    @property
    def mean_slowdown(self) -> float:
        """Mean degraded/healthy ratio over the surviving draws (1.0 ≡
        defects cost nothing on this winner's communication paths)."""
        s = [o.slowdown for o in self.outcomes if o.survived]
        return sum(s) / len(s) if s else 0.0

    @property
    def worst_slowdown(self) -> float:
        return max((o.slowdown for o in self.outcomes if o.survived),
                   default=0.0)

    def golden(self) -> Dict[str, object]:
        """The decisions the CI fault gate pins: the defect-free winner,
        the survival tally, and every degraded fallback decision."""
        w = self.winner
        out: Dict[str, object] = {
            "winner": {"fabric": w.fabric, "mp": w.strategy.mp,
                       "dp": w.strategy.dp, "pp": w.strategy.pp,
                       "wafers": w.strategy.wafers,
                       "inter_topology": w.inter_topology},
            "survived": f"{self.n_survived}/{self.n_masks}",
        }
        fb: Dict[str, object] = {}
        for o in self.outcomes:
            if o.survived or o.fallback is None:
                continue
            f = o.fallback
            fb[str(o.seed)] = {"fabric": f.fabric, "mp": f.strategy.mp,
                               "dp": f.strategy.dp, "pp": f.strategy.pp,
                               "wafers": f.strategy.wafers}
        out["fallbacks"] = fb
        return out

    def summary(self) -> str:
        w = self.winner
        lines = [
            f"{self.workload}: winner {w.fabric} {w.shape[0]}x{w.shape[1]} "
            f"mp={w.strategy.mp} dp={w.strategy.dp} pp={w.strategy.pp} "
            f"wafers={w.strategy.wafers}",
            f"  {self.n_masks} masks at {self.dead_npu_rate:.1%} dead NPUs: "
            f"{self.n_survived} survive ({self.survival_rate:.1%}), "
            f"{self.n_fallback} recover via fallback",
        ]
        if self.n_survived:
            lines.append(f"  slowdown when surviving: mean "
                         f"{self.mean_slowdown:.3f}x, worst "
                         f"{self.worst_slowdown:.3f}x")
        for o in self.outcomes:
            if not o.survived and o.fallback is None:
                lines.append(f"  seed {o.seed}: DEAD ({o.reason}), "
                             f"no feasible fallback")
        return "\n".join(lines)


YIELD_CSV_HEADER = (
    "workload,n_npus,dead_npu_rate,seed,n_dead,survived,reason,"
    "healthy_time_s,degraded_time_s,slowdown,"
    "fallback_fabric,fallback_mp,fallback_dp,fallback_pp,fallback_wafers,"
    "fallback_time_s")


def yield_csv_rows(report: YieldReport) -> List[str]:
    """One row per sampled mask; schema in benchmarks/README.md."""
    rows = []
    healthy = report.winner.total
    for o in report.outcomes:
        f = o.fallback
        rows.append(
            f"{report.workload},{report.n_npus},"
            f"{report.dead_npu_rate:.9g},{o.seed},{o.n_dead},"
            f"{int(o.survived)},{o.reason.split(',')[0]},"
            f"{healthy:.9g},{o.degraded_time_s:.9g},{o.slowdown:.9g},"
            + (f"{f.fabric},{f.strategy.mp},{f.strategy.dp},"
               f"{f.strategy.pp},{f.strategy.wafers},{f.total:.9g}"
               if f is not None else ",,,,,"))
    return rows


def _winner_survives(winner: SweepResult, workload_fn, mask: DefectMask,
                     n_npus: int, compute_efficiency: float,
                     check_routing: bool, uplinks: Optional[int],
                     inter_kw: Dict[str, float]
                     ) -> Tuple[bool, str, float]:
    """(survived, reason, degraded_time_s) for the winner under ``mask``."""
    st = winner.strategy
    per_wafer = st.mp * st.pp * (st.dp // max(st.wafers, 1))
    if per_wafer > mask.n_healthy:
        return False, (f"capacity: needs {per_wafer} healthy NPUs/wafer, "
                       f"mask leaves {mask.n_healthy}"), 0.0
    if winner.fabric == "baseline" \
            and not mesh_connected(mask, *winner.shape):
        return False, "disconnected: mask severs this mesh shape", 0.0
    if check_routing and winner.fabric != "baseline" \
            and not strategy_routable(st, winner.shape, uplinks=uplinks,
                                      defects=mask):
        return False, "unroutable: conflict-free routing fails", 0.0
    sim = _simulator(
        winner.fabric, winner.shape, n_npus, {}, compute_efficiency,
        n_wafers=winner.n_wafers,
        hierarchy=winner.hierarchy if winner.n_wafers > 1 else None,
        inter_topology=winner.inter_topology, defects=mask, **inter_kw)
    try:
        br = sim.run(workload_fn(st))
    except ValueError as e:
        return False, f"eval: {e}", 0.0
    return True, "", br.total


def yield_study(workload_fn: Callable[[Strategy], Workload], n_npus: int,
                *,
                fabrics: Sequence[str] = DEFAULT_FABRICS,
                n_masks: int = 32,
                dead_npu_rate: float = 0.02,
                dead_link_rate: float = 0.0,
                dead_uplink_rate: float = 0.0,
                seed0: int = 0,
                masks: Optional[Sequence[DefectMask]] = None,
                n_layers: Optional[int] = None,
                min_utilization: float = 0.9,
                max_wafers: int = 1,
                inter_topologies: Sequence[str] = ("ring",),
                max_levels: int = 1,
                memory=None,
                prune_symmetric: bool = False,
                check_routing: bool = False,
                fallback: bool = True,
                compute_efficiency: float = 0.45,
                engine: str = "batched",
                inter_wafer_links: int = 32,
                inter_wafer_bw: float = 400e9,
                inter_wafer_latency: float = 5e-7) -> YieldReport:
    """Run the yield study for one workload at ``n_npus`` NPUs per wafer.

    The defect-free sweep (same knobs auto-strategy uses) picks the
    winner; each of ``n_masks`` draws (``sample_mask`` at
    ``dead_npu_rate`` / ``dead_link_rate`` / ``dead_uplink_rate``, seeds
    ``seed0 .. seed0+n_masks-1``) then tests it.  Pass ``masks``
    explicitly to study hand-built draws instead of sampling (``n_masks``
    and the rates are ignored).  ``fallback=True`` re-sweeps under every
    killing mask to record the degraded auto-strategy decision.

    Mask sampling is fabric-aware: a baseline winner samples with its
    mesh shape (so link kills land on real edges and stranded NPUs are
    demoted), a FRED winner with its group count and physical uplink
    multiplicity.
    """
    t0 = time.perf_counter()  # repro: ignore[DETERMINISM] duration metric only
    sweep_kw = dict(
        fabrics=fabrics, n_layers=n_layers,
        min_utilization=min_utilization, max_wafers=max_wafers,
        inter_topologies=inter_topologies, max_levels=max_levels,
        memory=memory, prune_symmetric=prune_symmetric,
        compute_efficiency=compute_efficiency, engine=engine,
        inter_wafer_links=inter_wafer_links,
        inter_wafer_bw=inter_wafer_bw,
        inter_wafer_latency=inter_wafer_latency)
    inter_kw = dict(inter_wafer_links=inter_wafer_links,
                    inter_wafer_bw=inter_wafer_bw,
                    inter_wafer_latency=inter_wafer_latency)
    healthy = sweep(workload_fn, n_npus, **sweep_kw)
    winner = pick_winner(healthy)
    healthy_t = winner.total

    uplinks = None
    sample_kw: Dict[str, object] = {}
    if winner.fabric == "baseline":
        sample_kw["mesh_shape"] = winner.shape
    else:
        sim0 = _simulator(winner.fabric, winner.shape, n_npus, {},
                          compute_efficiency)
        uplinks = sim0.fred.uplinks_per_l1()
        sample_kw["n_groups"] = winner.shape[0]
        sample_kw["uplinks_per_l1"] = uplinks

    if masks is None:
        masks = [sample_mask(n_npus, dead_npu_rate=dead_npu_rate,
                             dead_link_rate=dead_link_rate,
                             dead_uplink_rate=dead_uplink_rate,
                             seed=seed0 + i, **sample_kw)
                 for i in range(n_masks)]

    outcomes: List[MaskOutcome] = []
    for mask in masks:
        seed = mask.seed
        mask = normalize(mask)
        if mask is None:
            # an all-healthy draw trivially survives at the healthy time
            outcomes.append(MaskOutcome(seed=seed, n_dead=0, survived=True,
                                        reason="",
                                        degraded_time_s=healthy_t,
                                        slowdown=1.0))
            continue
        ok, reason, t = _winner_survives(
            winner, workload_fn, mask, n_npus, compute_efficiency,
            check_routing, uplinks, inter_kw)
        fb: Optional[SweepResult] = None
        if not ok and fallback:
            try:
                fb = pick_winner(sweep(workload_fn, n_npus, defects=mask,
                                       **sweep_kw))
            except ValueError:
                fb = None               # nothing feasible on this wafer
        outcomes.append(MaskOutcome(
            seed=seed, n_dead=len(mask.dead_npus), survived=ok,
            reason=reason, degraded_time_s=t,
            slowdown=(t / healthy_t if ok and healthy_t > 0 else 0.0),
            fallback=fb))
    return YieldReport(workload=workload_fn(winner.strategy).name,
                       n_npus=n_npus, dead_npu_rate=dead_npu_rate,
                       winner=winner, outcomes=outcomes,
                       study_seconds=time.perf_counter() - t0)  # repro: ignore[DETERMINISM] never feeds goldens


def model_yield_study(arch: str, shape_name: str = "train_4k", *,
                      n_npus: int = 20, **kw) -> YieldReport:
    """Yield study for a registry model under the policy's frozen
    defaults — the memory model and workload are exactly what
    ``autostrategy.choose_strategy`` would use.  Tries weight-stationary
    execution first, weight-streaming if nothing stationary is feasible
    (mirroring the auto-strategy fallback chain)."""
    from repro.configs.registry import get_config
    from repro.models.config import SHAPES_BY_NAME
    from repro.parallel.policy import paper_defaults
    from .workloads import MemoryModel, adapter_n_layers, from_model_config

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    pcfg, ocfg = paper_defaults(cfg, shape)
    mem = MemoryModel(master=ocfg.master, moments_dtype=ocfg.moments_dtype,
                      remat=pcfg.remat, training=shape.kind == "train")
    kw.setdefault("memory", mem)
    kw.setdefault("n_layers", adapter_n_layers(cfg))
    last: Optional[ValueError] = None
    for execution in ("stationary", "streaming"):
        def wl(st: Strategy, _e=execution) -> Workload:
            return from_model_config(cfg, shape, st, execution=_e)
        try:
            return yield_study(wl, n_npus, **kw)
        except ValueError as e:
            last = e
    raise ValueError(f"{arch}/{shape_name}: no feasible strategy at "
                     f"{n_npus} NPUs in either execution mode") from last
