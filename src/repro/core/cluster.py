"""Inter-wafer fabric level: a cluster of wafers joined by parameterized
wafer↔wafer links (ROADMAP "multi-wafer scale-out"; LIBRA-style multi-level
hierarchy, Hecaton-style wafer scale-out).

:class:`WaferCluster` wraps ``n_wafers`` identical wafer fabrics — either
the baseline :class:`~repro.core.meshnet.MeshFabric` or a
:class:`~repro.core.fabric.FredFabric` — connected by a
:class:`WaferLink` (link count × per-link BW × latency).  The wafer is the
manufacturing unit, so scale-out *adds* NPUs: a 2-wafer cluster of 5×4
wafers has 40 NPUs.

Collectives that span wafers run the classic hierarchical decomposition:

  1. Reduce-Scatter among the group members *within* each wafer (on the
     wafer's own fabric — FRED trees or mesh rings);
  2. All-Reduce of the per-member shard *across* wafers over the
     wafer↔wafer links (endpoint ring — there is no FRED switch between
     wafers);
  3. All-Gather within each wafer.

``collective_time_parts`` returns the (intra-wafer, inter-wafer) split so
the simulator can report per-level DP time; groups contained in one wafer
delegate straight to the wafer fabric and the inter part is 0.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Union

from .fabric import FredFabric
from .flows import endpoint_traffic_bytes
from .meshnet import MeshFabric

WaferFabric = Union[MeshFabric, FredFabric]


@dataclasses.dataclass(frozen=True)
class WaferLink:
    """Wafer↔wafer interconnect budget, per wafer (Dojo-style wafer-edge
    bridges: many moderate links rather than one fat pipe — Dojo training
    tiles publish 9 TB/s per edge, 36 TB/s aggregate; the default 32×400
    GB/s = 12.8 TB/s sits inside that envelope)."""
    n_links: int = 32
    link_bw: float = 400e9            # B/s per link per direction
    latency: float = 5e-7             # per inter-wafer ring step

    def __post_init__(self):
        if self.n_links < 1 or self.link_bw <= 0:
            raise ValueError(f"wafer link needs ≥1 link of positive BW, "
                             f"got {self.n_links}×{self.link_bw}")

    @property
    def agg_bw(self) -> float:
        """Aggregate wafer↔wafer bandwidth per wafer, one direction."""
        return self.n_links * self.link_bw


@dataclasses.dataclass
class WaferCluster:
    """``n_wafers`` identical wafers + the inter-wafer level."""
    wafer: WaferFabric
    n_wafers: int
    link: WaferLink = dataclasses.field(default_factory=WaferLink)

    def __post_init__(self):
        if self.n_wafers < 1:
            raise ValueError(f"cluster needs ≥ 1 wafer, got {self.n_wafers}")
        # wafer.n_npus is a property chain hit on every id translation —
        # hot enough to show in sweep profiles, so snapshot it once (the
        # wafer shape is fixed for the cluster's lifetime)
        self._npus_per_wafer = self.wafer.n_npus

    # ---- id space --------------------------------------------------------------
    @property
    def npus_per_wafer(self) -> int:
        return self._npus_per_wafer

    @property
    def n_npus(self) -> int:
        return self.n_wafers * self.npus_per_wafer

    def wafer_of(self, gid: int) -> int:
        return gid // self.npus_per_wafer

    def local_id(self, gid: int) -> int:
        return gid % self.npus_per_wafer

    def split_by_wafer(self, group: Sequence[int]) -> Dict[int, List[int]]:
        """wafer idx → local NPU ids of the group members on that wafer."""
        by: Dict[int, List[int]] = {}
        for gid in group:
            by.setdefault(self.wafer_of(gid), []).append(self.local_id(gid))
        return by

    # ---- collectives -----------------------------------------------------------
    def _wafer_coll(self, kind: str, local_group: Sequence[int],
                    nbytes: float, concurrent_groups: int) -> float:
        if isinstance(self.wafer, MeshFabric):
            return self.wafer.collective_time(kind, local_group, nbytes)
        return self.wafer.collective_time(kind, local_group, nbytes,
                                          concurrent_groups=concurrent_groups)

    def inter_ring_params(self) -> Tuple[float, float]:
        """(aggregate wafer↔wafer BW, per-step latency) — the only
        cluster-level inputs :meth:`inter_allreduce_time` consumes besides
        the span/payload.  The batched sweep engine reads these once and
        evaluates the inter-wafer ring for every strategy as array ops."""
        return self.link.agg_bw, self.link.latency

    def inter_allreduce_time(self, n_wafers_spanned: int, nbytes: float,
                             concurrent_groups: int = 1) -> float:
        """Ring All-Reduce across wafers: 2(w−1) steps over the aggregate
        wafer↔wafer BW, shared by groups crossing wafers concurrently."""
        w = n_wafers_spanned
        if w <= 1 or nbytes <= 0:
            return 0.0
        traffic = endpoint_traffic_bytes("all_reduce", w, nbytes)
        steps = 2 * (w - 1)
        bw = self.link.agg_bw / max(concurrent_groups, 1)
        return steps * ((traffic / steps) / bw + self.link.latency)

    def collective_time_parts(self, kind: str, group: Sequence[int],
                              nbytes: float, concurrent_groups: int = 1,
                              inter_concurrent_groups: "int | None" = None
                              ) -> Tuple[float, float]:
        """(intra-wafer, inter-wafer) time split for one collective.

        Wafers run their intra phases in parallel, so the intra part is the
        widest wafer's Reduce-Scatter + All-Gather; only All-Reduce is
        supported across wafers (MP/PP groups are placed within one wafer
        by ``cluster_placement``).  ``inter_concurrent_groups`` lets the
        caller model a different contention level on the wafer↔wafer links
        than inside the wafer (GPipe staggers the DP exchanges of distinct
        pipeline stages, so only same-stage groups contend inter-wafer
        while the wafer-internal fabric is shared by all of them);
        defaults to ``concurrent_groups``."""
        if len(group) <= 1 or nbytes <= 0:
            return 0.0, 0.0
        by_wafer = self.split_by_wafer(group)
        if len(by_wafer) == 1:
            local = next(iter(by_wafer.values()))
            return (self._wafer_coll(kind, local, nbytes, concurrent_groups),
                    0.0)
        if kind != "all_reduce":
            raise NotImplementedError(
                f"cross-wafer {kind!r} not modeled: placement keeps MP/PP "
                f"within a wafer, only the DP All-Reduce spans wafers")
        inter_conc = (concurrent_groups if inter_concurrent_groups is None
                      else inter_concurrent_groups)
        widest = max(by_wafer.values(), key=len)
        k = len(widest)
        intra = 0.0
        if k > 1:
            intra += self._wafer_coll("reduce_scatter", widest, nbytes,
                                      concurrent_groups)
        # the k per-member shard rings run concurrently but share the same
        # wafer↔wafer links, so the group's boundary traffic stays
        # 2(w−1)/w · nbytes regardless of k — bill the full payload (the
        # reduce-scatter avoids the k× redundancy a flat per-member
        # All-Reduce would push across, it does not shrink the cut bytes)
        inter = self.inter_allreduce_time(len(by_wafer), nbytes, inter_conc)
        if k > 1:
            intra += self._wafer_coll("all_gather", widest, nbytes,
                                      concurrent_groups)
        return intra, inter

    def collective_time(self, kind: str, group: Sequence[int], nbytes: float,
                        concurrent_groups: int = 1) -> float:
        intra, inter = self.collective_time_parts(kind, group, nbytes,
                                                  concurrent_groups)
        return intra + inter

    # ---- PP / I/O (both stay within a wafer) -----------------------------------
    def pp_transfer_time(self, nbytes: float) -> float:
        return self.wafer.pp_transfer_time(nbytes)

    def wafer_io_rate(self) -> float:
        """Per-wafer sustainable I/O streaming rate — each wafer has its
        own I/O controllers and streams its replicas' weights locally."""
        return self.wafer.io_stream_rate()

    def tag(self) -> Tuple:
        """Physical identity of the inter-wafer level for collective
        memo keys (the wafer fabric contributes its own tag)."""
        return ("cluster", self.n_wafers, self.link.n_links,
                self.link.link_bw, self.link.latency)
