"""Scale-out fabric levels: wafers stacked into a multi-level hierarchy
(ROADMAP "multi-wafer scale-out"; LIBRA-style multi-dimensional topology
optimization, Hecaton/Dragonfly-on-wafers-style scale-out variants).

:class:`WaferCluster` wraps identical wafer fabrics — either the baseline
:class:`~repro.core.meshnet.MeshFabric` or a
:class:`~repro.core.fabric.FredFabric` — joined by a stack of
:class:`HierarchyLevel` s (wafer → rack → pod):

  * level 1 joins ``count`` wafers into a rack,
  * level 2 joins ``count`` racks into a pod, …

each with its own :class:`WaferLink` budget and an **inter-level topology**
``topology ∈ {ring, fully_connected, switch}`` selecting the collective
model for that level:

  * ``ring``            — endpoint ring over the level's aggregate links:
                          2(n−1) steps of 2(n−1)/n·D endpoint traffic for
                          All-Reduce (the PR-2 model, bit-identical);
  * ``fully_connected`` — single-hop direct exchange (Dragonfly-style
                          all-to-all wiring): the same endpoint traffic
                          leaves each node, but split across n−1 parallel
                          peer links, so only 2 latency steps are paid;
  * ``switch``          — an in-switch reduction stage between the units,
                          reusing the FRED R/D µswitch semantics of
                          ``core/switch.py`` (reduce on the way in,
                          distribute on the way out, paper Sec. IV/V):
                          All-Reduce traffic drops to D per node — the
                          paper's ≈2× claim vs the 2(n−1)/n·D ring.

The wafer is the manufacturing unit, so scale-out *adds* NPUs: a 2×2
(rack×pod) cluster of 5×4 wafers has 80 NPUs.

Collectives that span wafers run the classic hierarchical decomposition:

  1. Reduce-Scatter among the group members *within* each wafer;
  2. per inter level, innermost first: Reduce-Scatter across the level's
     spanned units — or All-Reduce at the outermost spanned level;
  3. All-Gather back down (per level, then within each wafer).

``collective_time_levels`` returns the (intra-wafer, per-level) split so
the simulator can report ``dp_levels``; a single ring level reproduces the
PR-2 ``(intra, inter)`` model bit-for-bit, and groups contained in one
wafer delegate straight to the wafer fabric.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .fabric import FredFabric
from .flows import endpoint_traffic_bytes, innetwork_traffic_bytes
from .meshnet import MeshFabric

WaferFabric = Union[MeshFabric, FredFabric]

#: valid inter-level topologies, in deterministic sweep order
INTER_TOPOLOGIES = ("ring", "fully_connected", "switch")

#: integer codes shared with the batched engine's per-lane topology arrays
TOPOLOGY_CODES = {t: i for i, t in enumerate(INTER_TOPOLOGIES)}

#: default names of the stacked levels (level 1 joins wafers into a rack…)
LEVEL_NAMES = ("rack", "pod", "row", "hall")


@dataclasses.dataclass(frozen=True)
class WaferLink:
    """Inter-level interconnect budget, per unit (Dojo-style wafer-edge
    bridges: many moderate links rather than one fat pipe — Dojo training
    tiles publish 9 TB/s per edge, 36 TB/s aggregate; the default 32×400
    GB/s = 12.8 TB/s sits inside that envelope)."""
    n_links: int = 32
    link_bw: float = 400e9            # B/s per link per direction
    latency: float = 5e-7             # repro: unit[s] (per inter-level step)

    def __post_init__(self):
        if self.n_links < 1 or self.link_bw <= 0:
            raise ValueError(f"wafer link needs ≥1 link of positive BW, "
                             f"got {self.n_links}×{self.link_bw}")

    @property
    def agg_bw(self) -> float:
        """Aggregate inter-level bandwidth per unit, one direction."""
        return self.n_links * self.link_bw


@dataclasses.dataclass(frozen=True)
class HierarchyLevel:
    """One inter level of the scale-out hierarchy: ``count`` units of the
    level below joined by ``link`` under ``topology``."""
    name: str
    count: int
    topology: str = "ring"
    link: WaferLink = dataclasses.field(default_factory=WaferLink)

    def __post_init__(self):
        if self.count < 1:
            raise ValueError(f"level {self.name!r} needs count ≥ 1, "
                             f"got {self.count}")
        if self.topology not in INTER_TOPOLOGIES:
            raise ValueError(
                f"level {self.name!r}: unknown topology "
                f"{self.topology!r}; expected one of {INTER_TOPOLOGIES}")


def level_collective_time(topology: str, kind: str, n: int, nbytes: float,
                          agg_bw: float, latency: float,
                          concurrent_groups: int = 1) -> float:
    """Time of one collective across ``n`` units of an inter level.

    ``agg_bw`` is the per-unit aggregate link bandwidth, shared by
    ``concurrent_groups`` groups crossing the level at once.  The ring
    branch is op-for-op the PR-2 inter-wafer ring (bit-identical);
    ``fully_connected`` splits the same aggregate across n−1 direct peer
    links (2 latency steps instead of 2(n−1)); ``switch`` reduces
    in-network (R µswitches in, D µswitches out — Sec. IV), so an
    All-Reduce injects D instead of 2(n−1)/n·D per unit."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    bw = agg_bw / max(concurrent_groups, 1)
    if topology == "ring":
        traffic = endpoint_traffic_bytes(kind, n, nbytes)
        steps = 2 * (n - 1) if kind == "all_reduce" else (n - 1)
        return steps * ((traffic / steps) / bw + latency)
    if topology == "fully_connected":
        # direct exchange: each unit moves its D/n shard to every peer in
        # parallel over n−1 links of bw/(n−1) each — same endpoint bytes
        # as the ring, 2 latency steps (RS phase + AG phase) instead of
        # 2(n−1)
        shard = nbytes / n
        per_link_bw = bw / (n - 1)
        steps = 2 if kind == "all_reduce" else 1
        return steps * (shard / per_link_bw + latency)
    if topology == "switch":
        # in-switch reduction/distribution (core/switch.py semantics):
        # one traversal up (reduce), one down (broadcast)
        traffic = innetwork_traffic_bytes(kind, n, nbytes)
        steps = 2 if kind == "all_reduce" else 1
        return steps * ((traffic / steps) / bw + latency)
    raise ValueError(f"unknown inter-level topology {topology!r}; "
                     f"expected one of {INTER_TOPOLOGIES}")


def hierarchy_spans(n_wafers_spanned: int,
                    counts: Sequence[int]) -> List[int]:
    """Units spanned at each level by ``n_wafers_spanned`` consecutive
    wafers under the level ``counts`` — the closed form of
    :meth:`WaferCluster.spans_for` (the sweep and the batched engine
    broadcast these per configuration without building a cluster)."""
    rem = max(n_wafers_spanned, 1)
    spans: List[int] = []
    for c in counts:
        spans.append(min(rem, c))
        rem = -(-rem // c)
    return spans


def inter_traffic_bytes(topology: str, n: int, nbytes: float,
                        kind: str = "all_reduce") -> float:
    """Per-unit bytes injected onto an inter level's links.  Ring and
    fully-connected are endpoint algorithms (All-Reduce: 2(n−1)/n·D);
    the switch reduces in-network, dropping that to D — the ≈2× claim."""
    if topology in ("ring", "fully_connected"):
        return endpoint_traffic_bytes(kind, n, nbytes)
    if topology == "switch":
        return innetwork_traffic_bytes(kind, n, nbytes)
    raise ValueError(f"unknown inter-level topology {topology!r}; "
                     f"expected one of {INTER_TOPOLOGIES}")


@dataclasses.dataclass
class WaferCluster:
    """Identical wafers + the stacked inter levels.

    Backwards-compatible constructor: ``WaferCluster(wafer, n_wafers,
    link, topology)`` builds the single-level hierarchy (the PR-2 model;
    ``topology="ring"`` is bit-identical to it).  Pass ``levels`` for
    rack/pod stacks — ``n_wafers`` must then equal the product of the
    level counts (or be left at 1 to be derived)."""
    wafer: WaferFabric
    n_wafers: int = 1
    link: WaferLink = dataclasses.field(default_factory=WaferLink)
    topology: str = "ring"
    levels: Optional[Sequence[HierarchyLevel]] = None
    # one DefectMask (or None = pristine) per wafer — the cluster stops
    # pretending every wafer shipped with the same holes.  Mutually
    # exclusive with a mask on the base ``wafer`` fabric; None keeps the
    # uniform-wafer fast path bit-identical.
    wafer_defects: Optional[Sequence] = None

    def __post_init__(self):
        if self.levels is not None:
            self.levels = tuple(self.levels)
            prod = 1
            for lvl in self.levels:
                prod *= lvl.count
            if self.n_wafers == 1:
                self.n_wafers = prod
            elif self.n_wafers != prod:
                raise ValueError(
                    f"n_wafers={self.n_wafers} inconsistent with level "
                    f"counts {tuple(l.count for l in self.levels)} "
                    f"(product {prod})")
        else:
            self.levels = (HierarchyLevel(LEVEL_NAMES[0], self.n_wafers,
                                          self.topology, self.link),)
        if self.n_wafers < 1:
            raise ValueError(f"cluster needs ≥ 1 wafer, got {self.n_wafers}")
        # wafer.n_npus is a property chain hit on every id translation —
        # hot enough to show in sweep profiles, so snapshot it once (the
        # wafer shape is fixed for the cluster's lifetime)
        self._npus_per_wafer = self.wafer.n_npus
        self._wafer_variants: Optional[Tuple[WaferFabric, ...]] = None
        if self.wafer_defects is not None:
            from .defects import normalize
            masks = tuple(normalize(m) for m in self.wafer_defects)
            if all(m is None for m in masks):
                self.wafer_defects = None
            else:
                if len(masks) != self.n_wafers:
                    raise ValueError(
                        f"wafer_defects has {len(masks)} entries for a "
                        f"{self.n_wafers}-wafer cluster — one mask (or "
                        f"None) per wafer")
                if self.wafer.defects is not None:
                    raise ValueError(
                        "per-wafer wafer_defects and a defect mask on the "
                        "base wafer fabric are mutually exclusive — pass "
                        "one or the other")
                for w, m in enumerate(masks):
                    if m is not None and m.n_npus != self._npus_per_wafer:
                        raise ValueError(
                            f"wafer {w} mask covers {m.n_npus} NPUs but "
                            f"each wafer has {self._npus_per_wafer}")
                self.wafer_defects = masks
                self._wafer_variants = tuple(
                    self.wafer if m is None
                    else dataclasses.replace(self.wafer, defects=m)
                    for m in masks)

    def wafer_fabric(self, wafer_idx: int) -> WaferFabric:
        """The fabric of one specific wafer — the base fabric unless a
        per-wafer defect mask replaces it with a degraded variant."""
        if self._wafer_variants is None:
            return self.wafer
        return self._wafer_variants[wafer_idx]

    @property
    def n_healthy_npus(self) -> int:
        """Usable NPUs across the cluster under the per-wafer masks (the
        base fabric's own mask counts uniformly when no per-wafer list is
        set)."""
        if self.wafer_defects is not None:
            npw = self._npus_per_wafer
            return sum(npw if m is None else m.n_healthy
                       for m in self.wafer_defects)
        return self.wafer.n_healthy * self.n_wafers

    # ---- id space --------------------------------------------------------------
    @property
    def npus_per_wafer(self) -> int:
        return self._npus_per_wafer

    @property
    def n_npus(self) -> int:
        return self.n_wafers * self.npus_per_wafer

    @property
    def hierarchy(self) -> Tuple[int, ...]:
        """Level counts, innermost first — e.g. (2, 2) for 2 wafers/rack
        × 2 racks/pod."""
        return tuple(lvl.count for lvl in self.levels)

    def wafer_of(self, gid: int) -> int:
        return gid // self.npus_per_wafer

    def local_id(self, gid: int) -> int:
        return gid % self.npus_per_wafer

    def split_by_wafer(self, group: Sequence[int]) -> Dict[int, List[int]]:
        """wafer idx → local NPU ids of the group members on that wafer."""
        by: Dict[int, List[int]] = {}
        for gid in group:
            by.setdefault(self.wafer_of(gid), []).append(self.local_id(gid))
        return by

    # ---- hierarchy geometry ----------------------------------------------------
    def level_spans(self, wafer_idxs: Iterable[int]) -> List[int]:
        """Units spanned at each level by a set of wafer indices (widest
        parent at each level — wafers are numbered rack-major, so DP
        groups placed by ``cluster_placement`` fill the innermost level
        before spilling to the next)."""
        idxs = set(wafer_idxs)
        spans: List[int] = []
        for lvl in self.levels:
            by_parent: Dict[int, int] = {}
            for i in idxs:
                by_parent[i // lvl.count] = by_parent.get(i // lvl.count,
                                                          0) + 1
            spans.append(max(by_parent.values()) if by_parent else 1)
            idxs = set(by_parent)
        return spans

    def spans_for(self, n_wafers_spanned: int) -> List[int]:
        """``level_spans`` of ``n_wafers_spanned`` *consecutive* wafers —
        what a cross-wafer DP group placed by ``cluster_placement``
        occupies.  The batched engine broadcasts these per configuration."""
        return self.level_spans(range(max(n_wafers_spanned, 1)))

    # ---- collectives -----------------------------------------------------------
    def _wafer_coll(self, kind: str, local_group: Sequence[int],
                    nbytes: float, concurrent_groups: int,
                    ring_family: "Tuple[int, int, int] | None" = None,
                    wafer_idx: int = 0) -> float:
        """Intra-wafer collective on wafer ``wafer_idx``'s fabric (the
        per-wafer degraded variant when ``wafer_defects`` is set).
        ``ring_family`` is the compact ``(count, stride, n_used)``
        descriptor of the strided concurrent local-group family (one per
        wafer); under a defect mask the mesh materializes it so detoured
        sibling rings charge the evaluated ring the real shared-link
        bandwidth (healthy meshes keep the single-ring model — their X-Y
        rings are disjoint)."""
        fab = self.wafer_fabric(wafer_idx)
        if isinstance(fab, MeshFabric):
            rings: Sequence[Sequence[int]] = ()
            if ring_family is not None and fab.defects is not None:
                from .meshnet import strided_ring_family
                rings = strided_ring_family(fab.defects.healthy(),
                                            *ring_family)
            return fab.collective_time(kind, local_group, nbytes,
                                       concurrent_rings=rings)
        return fab.collective_time(kind, local_group, nbytes,
                                   concurrent_groups=concurrent_groups)

    def inter_ring_params(self) -> Tuple[float, float]:
        """(aggregate level-1 BW, per-step latency) — kept for the PR-2
        API; :meth:`level_params` generalizes to deeper levels."""
        return self.levels[0].link.agg_bw, self.levels[0].link.latency

    def level_params(self, i: int) -> Tuple[float, float]:
        """(aggregate BW, per-step latency) of inter level ``i`` — what
        the batched engine reads once per run.  Levels past the stack
        reuse the outermost level's link (uniform-link sweeps fuse 1- and
        2-level configurations under one cluster object)."""
        lvl = self.levels[min(i, len(self.levels) - 1)]
        return lvl.link.agg_bw, lvl.link.latency

    def inter_allreduce_time(self, n_wafers_spanned: int, nbytes: float,
                             concurrent_groups: int = 1) -> float:
        """All-Reduce across ``n_wafers_spanned`` units of level 1 under
        that level's topology (ring: 2(w−1) steps over the aggregate
        links, shared by groups crossing concurrently — the PR-2 model)."""
        lvl = self.levels[0]
        return level_collective_time(lvl.topology, "all_reduce",
                                     n_wafers_spanned, nbytes,
                                     lvl.link.agg_bw, lvl.link.latency,
                                     concurrent_groups)

    def _level_times(self, spans: Sequence[int], nbytes: float,
                     concurrent_groups: int) -> Tuple[float, ...]:
        """Per-level collective time for the hierarchical decomposition:
        Reduce-Scatter + All-Gather at every spanned level below the
        outermost spanned one, All-Reduce at the outermost.  Each level
        is billed the full payload — the concurrent per-shard exchanges
        of the level below share the same links, so the boundary traffic
        does not shrink with the local fan-in (see
        ``collective_time_levels``)."""
        out: List[float] = []
        for i, (lvl, s) in enumerate(zip(self.levels, spans)):
            if s <= 1 or nbytes <= 0:
                out.append(0.0)
                continue
            bw, lat = lvl.link.agg_bw, lvl.link.latency
            if any(s2 > 1 for s2 in spans[i + 1:]):
                t = (level_collective_time(lvl.topology, "reduce_scatter",
                                           s, nbytes, bw, lat,
                                           concurrent_groups) +
                     level_collective_time(lvl.topology, "all_gather",
                                           s, nbytes, bw, lat,
                                           concurrent_groups))
            else:
                t = level_collective_time(lvl.topology, "all_reduce",
                                          s, nbytes, bw, lat,
                                          concurrent_groups)
            out.append(t)
        return tuple(out)

    def collective_time_levels(self, kind: str, group: Sequence[int],
                               nbytes: float, concurrent_groups: int = 1,
                               inter_concurrent_groups: "int | None" = None,
                               ring_family: "Tuple[int, int, int] | None" = None
                               ) -> Tuple[float, Tuple[float, ...]]:
        """(intra-wafer, per-inter-level) time split for one collective.

        Wafers run their intra phases in parallel, so the intra part is
        the widest wafer's Reduce-Scatter + All-Gather; only All-Reduce
        is supported across wafers (MP/PP groups are placed within one
        wafer by ``cluster_placement``).  ``inter_concurrent_groups``
        lets the caller model a different contention level on the inter
        links than inside the wafer (GPipe staggers the DP exchanges of
        distinct pipeline stages, so only same-stage groups contend on
        the inter links while the wafer-internal fabric is shared by all
        of them); defaults to ``concurrent_groups``."""
        zeros = (0.0,) * len(self.levels)
        if len(group) <= 1 or nbytes <= 0:
            return 0.0, zeros
        by_wafer = self.split_by_wafer(group)
        if len(by_wafer) == 1:
            w = next(iter(by_wafer))
            return (self._wafer_coll(kind, by_wafer[w], nbytes,
                                     concurrent_groups,
                                     ring_family=ring_family, wafer_idx=w),
                    zeros)
        inter_conc = (concurrent_groups if inter_concurrent_groups is None
                      else inter_concurrent_groups)
        if kind == "all_to_all":
            # no reduction involved, so no RS/AG sandwich: each member
            # exchanges the wafer-local k/n share of its payload inside
            # the wafer, and the full payload crosses each spanned level
            # (same full-payload-per-level convention as ``_level_times``)
            n = len(group)
            intra = 0.0
            if self._wafer_variants is not None:
                # per-wafer masks: each wafer runs its local exchange on
                # its *own* degraded fabric in parallel — slowest gates
                for w, local in by_wafer.items():
                    kw = len(local)
                    if kw > 1:
                        intra = max(intra, self._wafer_coll(
                            "all_to_all", local, nbytes * kw / n,
                            concurrent_groups, ring_family=ring_family,
                            wafer_idx=w))
            else:
                widest = max(by_wafer.values(), key=len)
                k = len(widest)
                if k > 1:
                    intra = self._wafer_coll("all_to_all", widest,
                                             nbytes * k / n,
                                             concurrent_groups,
                                             ring_family=ring_family)
            spans = self.level_spans(by_wafer.keys())
            levels_t = tuple(
                level_collective_time(lvl.topology, "all_to_all", s, nbytes,
                                      lvl.link.agg_bw, lvl.link.latency,
                                      inter_conc) if s > 1 else 0.0
                for lvl, s in zip(self.levels, spans))
            return intra, levels_t
        if kind != "all_reduce":
            raise NotImplementedError(
                f"cross-wafer {kind!r} not modeled: placement keeps MP/PP "
                f"within a wafer, only the DP All-Reduce and the expert "
                f"All-to-All span wafers")
        if self._wafer_variants is not None:
            # per-wafer masks: the RS/AG sandwich runs concurrently on
            # every spanned wafer's own degraded fabric; the slowest
            # wafer's sandwich gates the hierarchical All-Reduce
            intra = 0.0
            for w, local in by_wafer.items():
                if len(local) <= 1:
                    continue
                t = (self._wafer_coll("reduce_scatter", local, nbytes,
                                      concurrent_groups,
                                      ring_family=ring_family, wafer_idx=w) +
                     self._wafer_coll("all_gather", local, nbytes,
                                      concurrent_groups,
                                      ring_family=ring_family, wafer_idx=w))
                intra = max(intra, t)
            spans = self.level_spans(by_wafer.keys())
            return intra, self._level_times(spans, nbytes, inter_conc)
        widest = max(by_wafer.values(), key=len)
        k = len(widest)
        intra = 0.0
        if k > 1:
            intra += self._wafer_coll("reduce_scatter", widest, nbytes,
                                      concurrent_groups,
                                      ring_family=ring_family)
        # the k per-member shard exchanges run concurrently but share the
        # same inter links at every level, so the group's boundary traffic
        # at a level is set by its full payload regardless of k (the
        # reduce-scatter avoids the k× redundancy a flat per-member
        # All-Reduce would push across, it does not shrink the cut bytes)
        spans = self.level_spans(by_wafer.keys())
        levels_t = self._level_times(spans, nbytes, inter_conc)
        if k > 1:
            intra += self._wafer_coll("all_gather", widest, nbytes,
                                      concurrent_groups,
                                      ring_family=ring_family)
        return intra, levels_t

    def collective_time_parts(self, kind: str, group: Sequence[int],
                              nbytes: float, concurrent_groups: int = 1,
                              inter_concurrent_groups: "int | None" = None,
                              ring_family: "Tuple[int, int, int] | None" = None
                              ) -> Tuple[float, float]:
        """(intra-wafer, total-inter) split — the PR-2 two-way view of
        :meth:`collective_time_levels` (single-level clusters are
        bit-identical; deeper stacks sum their levels)."""
        intra, levels_t = self.collective_time_levels(
            kind, group, nbytes, concurrent_groups, inter_concurrent_groups,
            ring_family=ring_family)
        inter = 0.0
        for t in levels_t:
            inter += t
        return intra, inter

    def collective_time(self, kind: str, group: Sequence[int], nbytes: float,
                        concurrent_groups: int = 1) -> float:
        intra, inter = self.collective_time_parts(kind, group, nbytes,
                                                  concurrent_groups)
        return intra + inter

    # ---- PP / I/O (both stay within a wafer) -----------------------------------
    def pp_transfer_time(self, nbytes: float) -> float:
        return self.wafer.pp_transfer_time(nbytes)

    def wafer_io_rate(self) -> float:
        """Per-wafer sustainable I/O streaming rate — each wafer has its
        own I/O controllers and streams its replicas' weights locally."""
        return self.wafer.io_stream_rate()

    # ---- accounting ------------------------------------------------------------
    def inter_switch_hw(self) -> List[Dict[str, float]]:
        """HW accounting of the in-network reduction switches (one
        ``FredSwitch`` with as many ports as units joined, per ``switch``
        level) — Table-III-style area/power via ``core.switch``; empty
        when no level uses the switch topology."""
        from .switch import FredSwitch, hw_overhead
        out = []
        for lvl in self.levels:
            if lvl.topology == "switch" and lvl.count >= 2:
                o = hw_overhead(FredSwitch.build(lvl.count, 3))
                o["level"] = lvl.name
                out.append(o)
        return out

    def tag(self) -> Tuple:
        """Physical identity of the inter levels for collective memo keys
        (the wafer fabric contributes its own tag; per-wafer defect masks
        are part of the identity — two clusters with different hole
        patterns must never share collective memo entries)."""
        t = ("cluster", self.n_wafers) + tuple(
            (lvl.count, lvl.topology, lvl.link.n_links, lvl.link.link_bw,
             lvl.link.latency) for lvl in self.levels)
        if self.wafer_defects is not None:
            t = t + (tuple(self.wafer_defects),)
        return t
