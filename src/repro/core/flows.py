"""Communication *flows* and Table-I collective decompositions (Sec. V-A).

A flow on FRED_m(P) is (IPs, OPs): reduce the data arriving on the input
ports IPs and broadcast the result to the output ports OPs.  Simple
collectives are one flow; compound collectives decompose into serial flow
steps exactly as Table I prescribes.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Flow:
    """One reduction-distribution flow."""
    ips: FrozenSet[int]
    ops: FrozenSet[int]
    bytes: float = 0.0          # payload carried by this flow
    tag: str = ""               # which collective/group it belongs to

    @staticmethod
    def make(ips: Sequence[int], ops: Sequence[int], nbytes: float = 0.0,
             tag: str = "") -> "Flow":
        return Flow(frozenset(ips), frozenset(ops), nbytes, tag)

    def __repr__(self):
        return (f"Flow({sorted(self.ips)}→{sorted(self.ops)}"
                f"{', ' + self.tag if self.tag else ''})")


# --------------------------------------------------------------------------
# Table I — simple collectives: exactly one flow
# --------------------------------------------------------------------------

def unicast(src: int, dst: int, nbytes: float = 0.0, tag="unicast") -> List[List[Flow]]:
    return [[Flow.make([src], [dst], nbytes, tag)]]


def multicast(src: int, dsts: Sequence[int], nbytes: float = 0.0,
              tag="multicast") -> List[List[Flow]]:
    return [[Flow.make([src], dsts, nbytes, tag)]]


def reduce(srcs: Sequence[int], dst: int, nbytes: float = 0.0,
           tag="reduce") -> List[List[Flow]]:
    return [[Flow.make(srcs, [dst], nbytes, tag)]]


def all_reduce(peers: Sequence[int], nbytes: float = 0.0,
               tag="all_reduce") -> List[List[Flow]]:
    """Input ports and output ports are the same — one flow."""
    return [[Flow.make(peers, peers, nbytes, tag)]]


# --------------------------------------------------------------------------
# Table I — compound collectives: serial steps of flows
# --------------------------------------------------------------------------

def reduce_scatter(peers: Sequence[int], nbytes: float = 0.0,
                   tag="reduce_scatter") -> List[List[Flow]]:
    """i serial Reduce steps, step j reducing shard j onto peer j."""
    n = len(peers)
    shard = nbytes / max(n, 1)
    return [[Flow.make(peers, [p], shard, f"{tag}[{j}]")]
            for j, p in enumerate(peers)]


def all_gather(peers: Sequence[int], nbytes: float = 0.0,
               tag="all_gather") -> List[List[Flow]]:
    """i serial Multicast steps, step j broadcasting peer j's shard."""
    n = len(peers)
    shard = nbytes / max(n, 1)
    return [[Flow.make([p], peers, shard, f"{tag}[{j}]")]
            for j, p in enumerate(peers)]


def scatter(src: int, dsts: Sequence[int], nbytes: float = 0.0,
            tag="scatter") -> List[List[Flow]]:
    shard = nbytes / max(len(dsts), 1)
    return [[Flow.make([src], [d], shard, f"{tag}[{j}]")]
            for j, d in enumerate(dsts)]


def gather(srcs: Sequence[int], dst: int, nbytes: float = 0.0,
           tag="gather") -> List[List[Flow]]:
    shard = nbytes / max(len(srcs), 1)
    return [[Flow.make([s], [dst], shard, f"{tag}[{j}]")]
            for j, s in enumerate(srcs)]


def all_to_all(peers: Sequence[int], nbytes: float = 0.0,
               tag="all_to_all") -> List[List[Flow]]:
    """i serial steps; in step j every input unicasts to the output at
    distance j (Table I) — each step is a parallel set of disjoint
    unicasts, which FRED routes concurrently."""
    n = len(peers)
    shard = nbytes / max(n, 1)
    steps = []
    for j in range(n):
        step = [Flow.make([peers[i]], [peers[(i + j) % n]], shard,
                          f"{tag}[{j}]") for i in range(n)]
        steps.append(step)
    return steps


COLLECTIVES = {
    "unicast": unicast, "multicast": multicast, "reduce": reduce,
    "all_reduce": all_reduce, "reduce_scatter": reduce_scatter,
    "all_gather": all_gather, "scatter": scatter, "gather": gather,
    "all_to_all": all_to_all,
}


def endpoint_traffic_bytes(kind: str, n: int, nbytes: float) -> float:
    """Per-NPU send traffic for the *endpoint* (ring) algorithm — the
    baseline FRED compares against (Sec. II-B): All-Reduce costs each NPU
    2(N−1)/N·D; RS/AG cost (N−1)/N·D; A2A (N−1)/N·D."""
    if n <= 1:
        return 0.0
    if kind == "all_reduce":
        return 2.0 * (n - 1) / n * nbytes
    if kind in ("reduce_scatter", "all_gather", "all_to_all"):
        return (n - 1) / n * nbytes
    if kind in ("reduce", "multicast", "unicast"):
        return nbytes
    raise ValueError(f"unknown collective kind {kind!r}; "
                     f"expected one of {sorted(COLLECTIVES)}")


def innetwork_traffic_bytes(kind: str, n: int, nbytes: float) -> float:
    """Per-NPU send traffic with in-switch execution: All-Reduce of D costs
    each NPU exactly D (send once, receive once) — the ≈2× reduction."""
    if n <= 1:
        return 0.0
    if kind == "all_reduce":
        return nbytes
    if kind in ("reduce_scatter", "all_gather", "all_to_all"):
        return (n - 1) / n * nbytes
    if kind in ("reduce", "multicast", "unicast"):
        return nbytes
    raise ValueError(f"unknown collective kind {kind!r}; "
                     f"expected one of {sorted(COLLECTIVES)}")
