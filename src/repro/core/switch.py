"""FRED switch: recursive Clos-style interconnect with reduction/distribution
micro-switches (paper Sec. IV, Fig. 7).

A ``FRED_m(P)`` switch has P input and P output ports.  It is built
recursively like an (m, n=2, r) Clos network:

  * P = 2r   → r input µswitches (2×2), m middle ``FRED_m(r)`` subnetworks,
               r output µswitches.
  * P = 2r+1 → same, but with ``FRED_m(r+1)`` middles and mux/demux wiring
               for the odd port (Chang & Melhem arbitrary-size Beneš).
  * Base cases: FRED_m(2) (single RD-µswitch) and FRED_m(3) (Fig. 7(d)).

µswitch types (Fig. 7(e-g)):
  * ``R``  — can reduce its two inputs into one output.
  * ``D``  — can broadcast one input to both outputs.
  * ``RD`` — both.

Input-stage µswitches are R (reduce on the way in), output-stage are D
(broadcast on the way out), base-case 2×2 are RD.  This module builds the
*structure* (for HW accounting, Table III) and provides per-switch routing
state used by ``core.routing``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class MicroSwitch:
    """One 2×2 µswitch."""
    kind: str          # "R" | "D" | "RD"
    stage: str         # "input" | "output" | "base"

    @property
    def can_reduce(self) -> bool:
        return self.kind in ("R", "RD")

    @property
    def can_distribute(self) -> bool:
        return self.kind in ("D", "RD")


@dataclasses.dataclass
class FredSwitch:
    """Recursive FRED_m(P) switch."""
    ports: int
    m: int
    input_switches: List[MicroSwitch]
    output_switches: List[MicroSwitch]
    middles: List["FredSwitch"]
    is_base: bool = False
    odd: bool = False

    # ---- construction -------------------------------------------------------
    @classmethod
    def build(cls, ports: int, m: int = 3) -> "FredSwitch":
        if ports < 2:
            raise ValueError("FRED switch needs ≥ 2 ports")
        if m < 2:
            raise ValueError("Clos middle count m must be ≥ 2 "
                             "(m=2 rearrangeable, m≥3 strict-sense for unicast)")
        if ports == 2:
            return cls(ports=2, m=m,
                       input_switches=[MicroSwitch("RD", "base")],
                       output_switches=[], middles=[], is_base=True)
        if ports == 3:
            # Fig. 7(d): 3-port base built from R/D/RD µswitches
            return cls(ports=3, m=m,
                       input_switches=[MicroSwitch("R", "base"),
                                       MicroSwitch("RD", "base")],
                       output_switches=[MicroSwitch("D", "base")],
                       middles=[], is_base=True)
        r = ports // 2
        odd = ports % 2 == 1
        sub = r + 1 if odd else r
        return cls(
            ports=ports, m=m,
            input_switches=[MicroSwitch("R", "input") for _ in range(r)],
            output_switches=[MicroSwitch("D", "output") for _ in range(r)],
            middles=[cls.build(sub, m) if sub > 1 else cls.build(2, m)
                     for _ in range(m)],
            odd=odd,
        )

    # ---- port → µswitch mapping ----------------------------------------------
    def input_switch_of(self, port: int) -> Optional[int]:
        """Index of the input µswitch handling ``port`` (None for the odd
        port, which connects through mux/demux directly to the middles)."""
        if self.odd and port == self.ports - 1:
            return None
        return port // 2

    def output_switch_of(self, port: int) -> Optional[int]:
        if self.odd and port == self.ports - 1:
            return None
        return port // 2

    def middle_port_of(self, port: int) -> int:
        """Port index on each middle subnetwork this port maps to."""
        if self.odd and port == self.ports - 1:
            return self.ports // 2          # the extra middle port
        return port // 2

    # ---- accounting (Table III) ----------------------------------------------
    def count_microswitches(self) -> Dict[str, int]:
        counts = {"R": 0, "D": 0, "RD": 0}
        for sw in self.input_switches + self.output_switches:
            counts[sw.kind] += 1
        for mid in self.middles:
            for k, v in mid.count_microswitches().items():
                counts[k] += v
        return counts

    def depth(self) -> int:
        if self.is_base:
            return 1
        return 2 + max(mid.depth() for mid in self.middles)


# --------------------------------------------------------------------------
# HW overhead model (Table III calibration)
# --------------------------------------------------------------------------

# Post-layout numbers from the paper (15 nm NanGate, 24 KB/port buffers):
#   FRED3(12) L1: 685 mm², 2.73 W;  FRED3(11): 678 mm², 2.50 W;
#   FRED3(10) L2: 814 mm², 2.28 W.
# The paper notes area is dominated by wafer-scale I/O (perimeter), not
# switch logic — we model area = a·P + b·µswitches and fit to Table III.

def hw_overhead(switch: FredSwitch, port_bw_gbps: float = 750.0
                ) -> Dict[str, float]:
    counts = switch.count_microswitches()
    n_micro = sum(counts.values())
    # fit: dominated by per-port I/O pads; logic term small
    area_mm2 = 52.0 * switch.ports + 1.2 * n_micro
    power_w = 0.18 * switch.ports + 0.012 * n_micro
    return {"ports": switch.ports, "microswitches": n_micro,
            "area_mm2": area_mm2, "power_w": power_w, **counts}
