"""chatglm3-6b — RoPE-2d (half-rotary), extreme GQA (kv=2)
[arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope="2d",
    qkv_bias=True,
    subquadratic=False,
)
