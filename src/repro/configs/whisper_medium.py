"""whisper-medium — encoder/decoder with conv frontend stubbed
[arXiv:2212.04356; unverified].

24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865 (padded to 52096 for
16-way vocab TP).  24 encoder layers over precomputed frame embeddings
(enc_seq=1500), 24 decoder layers with cross-attention."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    n_enc_layers=24,
    enc_seq=1500,
    subquadratic=False,
)
