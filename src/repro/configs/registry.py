"""Architecture registry — the 10 assigned configs + the paper's workloads.

Each ``src/repro/configs/<id>.py`` defines ``CONFIG`` with the exact figures
from the assignment; this registry imports them and offers lookup by id for
``--arch <id>`` everywhere (launcher, dry-run, benchmarks, tests).

The (arch × shape) applicability matrix lives here too: ``cells()`` yields
every runnable cell and the reason string for every skipped one (recorded in
EXPERIMENTS.md §Dry-run per the task spec).
"""

from __future__ import annotations

import importlib
from typing import Dict, Iterator, List, Optional, Tuple

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

ARCH_IDS = (
    "zamba2-2.7b",
    "llava-next-34b",
    "whisper-medium",
    "llama3.2-1b",
    "chatglm3-6b",
    "qwen3-32b",
    "qwen1.5-4b",
    "arctic-480b",
    "mixtral-8x7b",
    "mamba2-1.3b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_applicability(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full quadratic attention; 512k-token decode requires "
                       "sub-quadratic attention (SSM/hybrid/SWA only) — skip "
                       "per task spec, noted in DESIGN.md")
    return True, ""


def cells(archs=ARCH_IDS, shapes=SHAPES) -> Iterator[Tuple[str, ModelConfig, ShapeConfig, bool, str]]:
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            ok, why = shape_applicability(cfg, s)
            yield a, cfg, s, ok, why
