"""arctic-480b — 128-expert top-2 MoE with parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per expert) vocab=32000,
MoE 128e top-2 + dense residual (d_ff=4864)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_ff=4864,
    subquadratic=False,
)
