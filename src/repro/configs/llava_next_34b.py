"""llava-next-34b — VLM decoder backbone, anyres tiling frontend stubbed
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  ``input_specs``
supplies precomputed patch embeddings (B, n_patches, d_model)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    n_patches=1024,
    subquadratic=False,
)
