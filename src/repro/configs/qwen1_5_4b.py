"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    subquadratic=False,
)
