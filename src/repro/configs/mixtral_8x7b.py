"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
SWA window 4096 (rolling-buffer KV cache → sub-quadratic long decode)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    subquadratic=True,
)
