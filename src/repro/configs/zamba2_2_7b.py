"""zamba2-2.7b — Mamba2 blocks + shared attention block [arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Hybrid: the attention(+MLP) block is a single shared-weight block applied
every 6 Mamba2 layers (9 applications)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=6,
    subquadratic=True,
)
