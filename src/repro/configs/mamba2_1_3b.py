"""mamba2-1.3b — attention-free SSD stack [arXiv:2405.21060; unverified].

48L d_model=2048 d_ff=0 vocab=50280 (padded), ssm_state=128."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    subquadratic=True,
)
