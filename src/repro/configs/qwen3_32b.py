"""qwen3-32b — qk-norm + GQA [hf:Qwen/Qwen3-8B; hf].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    subquadratic=False,
)
