"""Batched serving engine: continuous prefill + decode over a request queue.

A production-shaped loop on top of ``transformer.prefill``/``decode_step``:
requests are admitted up to the configured batch, prompts padded to a
common length and prefetched into the shared KV state, then decode steps
run for the whole batch with per-sequence stop handling and temperature /
top-k sampling.  Used by ``examples/serve_batch.py`` and the serving tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ParallelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    stop_token: Optional[int] = None
    # filled by the engine
    output: Optional[List[int]] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 512
    # Serving SLO / traffic parameters (ISSUE 10): consumed by the
    # analytical serving cost model (core/serving via a serving
    # Objective) and recorded by `launch.dryrun --serving` next to the
    # measured per-token decode latency.  None = no SLO attached.
    target_p99_ms: Optional[float] = None
    arrival_rate_rps: Optional[float] = None


class Engine:
    def __init__(self, params, cfg: ModelConfig,
                 pcfg: Optional[ParallelConfig] = None,
                 ecfg: Optional[EngineConfig] = None):
        self.params = params
        self.cfg = cfg
        self.pcfg = (pcfg or ParallelConfig()).replace(remat="none")
        self.ecfg = ecfg or EngineConfig()
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, self.pcfg,
                                     self.ecfg.cache_len))
        self._decode = jax.jit(
            lambda p, t, s: tfm.decode_step(p, t, s, cfg, self.pcfg))
        # per-decode-step wall times of the most recent run_batch (first
        # entry includes the decode jit compile; dryrun --serving drops it)
        self.decode_step_s: List[float] = []

    def _sample(self, logits: jnp.ndarray, reqs: List[Request],
                key) -> np.ndarray:
        logits = np.asarray(logits, np.float32)
        out = np.zeros(len(reqs), np.int32)
        for i, r in enumerate(reqs):
            row = logits[i][:self.cfg.vocab_size]
            if r.temperature <= 0:
                out[i] = int(row.argmax())
                continue
            row = row / r.temperature
            if r.top_k:
                kth = np.partition(row, -r.top_k)[-r.top_k]
                row = np.where(row < kth, -np.inf, row)
            p = np.exp(row - row.max())
            p /= p.sum()
            out[i] = int(np.random.default_rng(
                (int(jax.random.key_data(key)[0]), r.uid)).choice(len(p), p=p))
        return out

    def run_batch(self, requests: List[Request], seed: int = 0
                  ) -> List[Request]:
        """Serve one admission batch to completion."""
        if len(requests) > self.ecfg.max_batch:
            raise ValueError("admit at most max_batch requests")
        t0 = time.perf_counter()
        self.decode_step_s = []
        key = jax.random.PRNGKey(seed)
        B = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(toks)})

        outs: List[List[int]] = [[] for _ in requests]
        done = np.zeros(B, bool)
        max_new = max(r.max_new_tokens for r in requests)
        next_tok = self._sample(logits, requests, key)
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not done[i]:
                    outs[i].append(int(next_tok[i]))
                    if (r.stop_token is not None and
                            next_tok[i] == r.stop_token) or \
                            len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            ts = time.perf_counter()
            logits, state = self._decode(
                self.params, jnp.asarray(next_tok)[:, None], state)
            logits.block_until_ready()
            self.decode_step_s.append(time.perf_counter() - ts)
            key = jax.random.fold_in(key, step)
            next_tok = self._sample(logits, requests, key)

        dt = time.perf_counter() - t0
        for r, o in zip(requests, outs):
            r.output = o
            r.latency_s = dt
        return requests
