"""jit-compiled train / prefill / decode steps with full sharding metadata.

``make_setup`` assembles everything the launcher and the dry-run need for an
(arch × shape × mesh) cell *without allocating anything*: parameter /
optimizer / decode-state shapes via ``jax.eval_shape`` and their
``NamedSharding``s via the Ruleset, plus the jitted step function with
``in_shardings`` / ``out_shardings`` / donation wired up.

This is the module the multi-pod dry-run lowers (deliverable (e)).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.modules import AxisNames, split
from repro.train.optim import AdamState, OptimConfig, QTensor, adam_update, init_adam
from .sharding import Ruleset


class TrainState(NamedTuple):
    params: Any
    opt: AdamState


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — the dry-run contract)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, pcfg: ParallelConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell, as ShapeDtypeStructs (no allocation).

    Modality frontends are stubs per the task spec: ``patch_embeds`` /
    ``frames`` are precomputed embeddings."""
    B, S = shape.global_batch, shape.seq_len
    cdt = _dtype(pcfg.compute_dtype)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        batch = {"tokens": sd((B, 1), i32)}
        return batch

    batch = {}
    s_text = S
    if cfg.family == "vlm":
        batch["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), cdt)
        s_text = S - cfg.n_patches
    if cfg.family == "audio":
        batch["frames"] = sd((B, cfg.enc_seq, cfg.d_model), cdt)
    batch["tokens"] = sd((B, s_text), i32)
    if shape.kind == "train":
        batch["labels"] = sd((B, s_text), i32)
    return batch


def batch_shardings(cfg, shape, ruleset: Ruleset):
    b = ruleset.batch_axes(shape.global_batch)
    mesh = ruleset.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    out = {}
    for k, v in input_specs(cfg, shape, ruleset.pcfg).items():
        out[k] = ns(P(b, None, None)) if k in ("patch_embeds", "frames") \
            else ns(P(b, None))
    return out


# --------------------------------------------------------------------------
# setup
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CellSetup:
    """Everything needed to lower/compile/run one (arch × shape × mesh)."""
    cfg: ModelConfig
    pcfg: ParallelConfig
    shape: ShapeConfig
    mesh: Mesh
    ruleset: Ruleset
    param_shapes: Any
    param_shardings: Any
    step_fn: Any                 # jitted
    example_args: Tuple          # ShapeDtypeStructs to pass to .lower()
    state_shapes: Any = None     # TrainState / DecodeState shapes
    state_shardings: Any = None


def _enc_fn(cfg, pcfg, constrain, enc_layer_constrain=lambda bp: bp):
    if cfg.family != "audio":
        return None
    from repro.models.whisper import encode
    return lambda p, b: encode(p, b, cfg, pcfg, constrain,
                               layer_constrain=enc_layer_constrain)


def make_layer_constrain(ruleset: Ruleset, axes_blocks):
    """Constrain a per-layer parameter slice to its stored sharding (with
    the leading 'layers' axis dropped) — keeps FSDP gathers inside the layer
    loop instead of materializing the gathered full stack."""
    mesh = ruleset.mesh
    is_ax = lambda x: isinstance(x, AxisNames)
    specs = jax.tree.map(
        lambda a: NamedSharding(mesh, ruleset.spec(AxisNames(*tuple(a)[1:]))),
        axes_blocks, is_leaf=is_ax)

    def f(bp):
        return jax.tree.map(jax.lax.with_sharding_constraint, bp, specs)
    return f


def _param_setup(cfg, pcfg, mesh):
    ruleset = Ruleset(mesh, cfg, pcfg)
    pdt = _dtype(pcfg.param_dtype)
    holder = {}

    def f(k):
        vals, axes = split(tfm.init(k, cfg, dtype=pdt))
        holder["axes"] = axes          # static metadata, captured at trace time
        return vals

    param_shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    axes = holder["axes"]
    param_shardings = ruleset.param_shardings(axes)
    return ruleset, param_shapes, axes, param_shardings


def opt_state_shardings(ruleset: Ruleset, axes, ocfg: OptimConfig):
    mesh = ruleset.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    is_ax = lambda x: isinstance(x, AxisNames)

    def pspec(a):
        return ns(ruleset.opt_spec(a))

    def moment(a):
        if ocfg.moments_dtype == "int8":
            row = ruleset.opt_spec(a)
            scale_spec = P(*tuple(row)[:-1]) if len(a) >= 1 else P()
            return QTensor(q=pspec(a), scale=ns(scale_spec))
        return pspec(a)

    return AdamState(
        step=ns(P()),
        master=jax.tree.map(pspec, axes, is_leaf=is_ax) if ocfg.master else None,
        m=jax.tree.map(moment, axes, is_leaf=is_ax),
        v=jax.tree.map(moment, axes, is_leaf=is_ax),
    )


def make_train_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     pcfg: Optional[ParallelConfig] = None,
                     ocfg: Optional[OptimConfig] = None) -> CellSetup:
    pcfg = pcfg or ParallelConfig()
    ocfg = ocfg or OptimConfig()
    ruleset, param_shapes, axes, param_shardings = _param_setup(cfg, pcfg, mesh)
    constrain = ruleset.constrain_fn(shape.global_batch)
    lc = make_layer_constrain(ruleset, axes["blocks"])
    enc_lc = (make_layer_constrain(ruleset, axes["encoder"]["blocks"])
              if cfg.family == "audio" else (lambda bp: bp))
    enc_fn = _enc_fn(cfg, pcfg, constrain, enc_lc)

    opt_shapes = jax.eval_shape(lambda p: init_adam(p, ocfg), param_shapes)
    state_shapes = TrainState(params=param_shapes, opt=opt_shapes)
    state_shardings = TrainState(params=param_shardings,
                                 opt=opt_state_shardings(ruleset, axes, ocfg))

    def train_step(state: TrainState, batch):
        def loss_f(params):
            return tfm.loss_fn(params, batch, cfg, pcfg,
                               constrain=constrain, enc_fn=enc_fn,
                               layer_constrain=lc)
        (_, metrics), grads = jax.value_and_grad(loss_f, has_aux=True)(
            state.params)
        new_params, new_opt, om = adam_update(state.params, grads,
                                              state.opt, ocfg)
        metrics = {**metrics, **om}
        return TrainState(new_params, new_opt), metrics

    bshard = batch_shardings(cfg, shape, ruleset)
    metrics_sh = None  # replicated by default
    step = jax.jit(train_step,
                   in_shardings=(state_shardings, bshard),
                   out_shardings=(state_shardings, metrics_sh),
                   donate_argnums=(0,))
    return CellSetup(cfg=cfg, pcfg=pcfg, shape=shape, mesh=mesh,
                     ruleset=ruleset, param_shapes=param_shapes,
                     param_shardings=param_shardings, step_fn=step,
                     example_args=(state_shapes, input_specs(cfg, shape, pcfg)),
                     state_shapes=state_shapes, state_shardings=state_shardings)


def make_prefill_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       pcfg: Optional[ParallelConfig] = None) -> CellSetup:
    pcfg = (pcfg or ParallelConfig()).replace(remat="none")
    ruleset, param_shapes, axes, param_shardings = _param_setup(cfg, pcfg, mesh)
    constrain = ruleset.constrain_fn(shape.global_batch)
    lc = make_layer_constrain(ruleset, axes["blocks"])
    enc_lc = (make_layer_constrain(ruleset, axes["encoder"]["blocks"])
              if cfg.family == "audio" else (lambda bp: bp))
    enc_fn = _enc_fn(cfg, pcfg, constrain, enc_lc)
    cache_len = shape.seq_len

    def prefill_step(params, batch):
        return tfm.prefill(params, batch, cfg, pcfg, cache_len,
                           constrain=constrain, enc_fn=enc_fn,
                           layer_constrain=lc)

    state_shardings = ruleset.decode_state_shardings(cfg, shape.global_batch)
    bshard = batch_shardings(cfg, shape, ruleset)
    b = ruleset.batch_axes(shape.global_batch)
    logits_sh = NamedSharding(mesh, P(b, ruleset.tp))
    step = jax.jit(prefill_step,
                   in_shardings=(param_shardings, bshard),
                   out_shardings=(logits_sh, state_shardings))
    return CellSetup(cfg=cfg, pcfg=pcfg, shape=shape, mesh=mesh,
                     ruleset=ruleset, param_shapes=param_shapes,
                     param_shardings=param_shardings, step_fn=step,
                     example_args=(param_shapes, input_specs(cfg, shape, pcfg)),
                     state_shardings=state_shardings)


def make_decode_setup(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      pcfg: Optional[ParallelConfig] = None) -> CellSetup:
    """serve_step: one new token against a cache of ``shape.seq_len``."""
    pcfg = (pcfg or ParallelConfig()).replace(remat="none")
    ruleset, param_shapes, axes, param_shardings = _param_setup(cfg, pcfg, mesh)
    constrain = ruleset.constrain_fn(shape.global_batch)
    lc = make_layer_constrain(ruleset, axes["blocks"])
    cdt = _dtype(pcfg.compute_dtype)

    state_shapes = jax.eval_shape(
        lambda: tfm.init_decode_state(cfg, shape.global_batch,
                                      shape.seq_len, cdt))
    state_shardings = ruleset.decode_state_shardings(cfg, shape.global_batch)

    def decode(params, state, tokens):
        return tfm.decode_step(params, tokens, state, cfg, pcfg,
                               constrain=constrain, layer_constrain=lc)

    b = ruleset.batch_axes(shape.global_batch)
    logits_sh = NamedSharding(mesh, P(b, ruleset.tp))
    tok_sh = NamedSharding(mesh, P(b, None))
    step = jax.jit(decode,
                   in_shardings=(param_shardings, state_shardings, tok_sh),
                   out_shardings=(logits_sh, state_shardings),
                   donate_argnums=(1,))
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return CellSetup(cfg=cfg, pcfg=pcfg, shape=shape, mesh=mesh,
                     ruleset=ruleset, param_shapes=param_shapes,
                     param_shardings=param_shardings, step_fn=step,
                     example_args=(param_shapes, state_shapes, toks),
                     state_shapes=state_shapes, state_shardings=state_shardings)


def moe_ep_ffn_fn(ruleset: Ruleset, cfg: ModelConfig,
                  capacity_factor: Optional[float] = None):
    """Bind the explicit shard_map All-to-All expert dispatch to a cell.

    Returns ``f(params_ffn, x) -> (out, aux)`` running
    :func:`repro.models.moe.moe_ffn_ep` on the cell's mesh over the
    Ruleset's active EP axis (``pcfg.moe_ep_axis``).  Raises if the cell
    has no valid EP axis — EP is a decision
    (``StrategyDecision.ep > 1``), not a silent fallback."""
    if not getattr(ruleset, "ep_axis", None):
        raise ValueError(
            "moe_ep_ffn_fn: the cell's ParallelConfig.moe_ep_axis is unset "
            "or invalid for this mesh/model — expert parallelism needs a "
            "data axis whose size divides n_experts")
    from repro.models.moe import moe_ffn_ep

    def f(params_ffn, x):
        return moe_ffn_ep(params_ffn, x, cfg, mesh=ruleset.mesh,
                          ep_axis=ruleset.ep_axis,
                          capacity_factor=capacity_factor)
    return f


def make_setup(cfg, shape, mesh, pcfg=None, ocfg=None) -> CellSetup:
    if shape.kind == "train":
        return make_train_setup(cfg, shape, mesh, pcfg, ocfg)
    if shape.kind == "prefill":
        return make_prefill_setup(cfg, shape, mesh, pcfg)
    return make_decode_setup(cfg, shape, mesh, pcfg)
