"""Pipeline parallelism: GPipe schedule over a ``pipe`` mesh axis.

FRED's Sec. II-C PP pattern — boundary activations forwarded stage-to-stage
— maps to ``collective_permute`` on the TPU torus (neighbouring stages on
neighbouring chips under the FRED-style placement in ``launch.mesh``).

Implementation: ``shard_map`` over ``pipe``; each shard holds its stage's
layer stack; a ``lax.scan`` over M + S − 1 ticks shifts microbatch
activations through stages with ``ppermute``.  The bubble, schedule, and
transfer pattern are exactly GPipe [16]; backward differentiates through
the scan (ppermute transposes to the reverse permutation), so one
``jax.grad`` gives pipeline-parallel training.

This module powers examples/tests (2–8 host devices); the 40-cell dry-run
uses DP×TP meshes per the task spec.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import pcast_varying, shard_map


def pipeline_fn(stage_fn: Callable, n_stages: int, n_microbatches: int,
                mesh: Mesh, pipe_axis: str = "pipe"):
    """Build a pipelined apply: (stage_params_stacked, x_mb) → y_mb.

    stage_fn(params_slice, x) → y, applied by each stage to each
    microbatch.  ``stage_params_stacked`` leaves have leading dim
    n_stages (sharded over ``pipe``); ``x_mb`` has leading dim
    n_microbatches (replicated).
    """
    S, M = n_stages, n_microbatches
    idx = jax.lax.axis_index

    def sharded(params, x_mb):
        # params: leaves (1, ...) local stage slice; x_mb: (M, B, ...)
        local = jax.tree.map(lambda a: a[0], params)
        stage = idx(pipe_axis)
        T = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        # initial carries are logically per-stage (varying over pipe)
        buf = pcast_varying(jnp.zeros_like(x_mb[0]), pipe_axis)
        outs0 = pcast_varying(jnp.zeros((M,) + x_mb.shape[1:], x_mb.dtype),
                              pipe_axis)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            mb_in = x_mb[jnp.clip(t, 0, M - 1)]
            inp = jnp.where(stage == 0, mb_in, buf)
            active = (t - stage >= 0) & (t - stage < M)
            y = stage_fn(local, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage emits microbatch (t - S + 1)
            out_idx = jnp.clip(t - S + 1, 0, M - 1)
            emit = (stage == S - 1) & active
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, outs[out_idx]), out_idx, 0)
            # shift activations to the next stage
            buf = jax.lax.ppermute(y, pipe_axis, perm)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs0), jnp.arange(T))
        return outs[None]                     # (1, M, ...) per stage

    mapped = shard_map(sharded, mesh=mesh,
                           in_specs=(P(pipe_axis), P()),
                           out_specs=P(pipe_axis))

    def apply(params_stacked, x_mb):
        stacked = mapped(params_stacked, x_mb)   # (S, M, ...)
        return stacked[-1]                       # only the last stage is real
    return apply


def sequential_reference(stage_fn, params_stacked, x_mb, n_stages: int):
    """Oracle: run stages sequentially on every microbatch."""
    def run_one(x):
        h = x
        for s in range(n_stages):
            ps = jax.tree.map(lambda a: a[s], params_stacked)
            h = stage_fn(ps, h)
        return h
    return jax.vmap(run_one)(x_mb)
