"""Gradient compression: blockwise int8 quantization with error feedback.

The software analogue of FRED's in-network traffic halving: where FRED's
R-µswitches halve All-Reduce injection bytes, EF-int8 quarters the
cross-pod payload (vs bf16) at equal convergence (error feedback keeps the
quantization bias out of the gradient estimate — Seide et al. 2014,
Karimireddy et al. 2019).

The Pallas kernel in ``repro.kernels.quant8`` implements the same math
with VMEM tiling for the TPU path; this module is its jnp reference and
the production fallback.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def quantize(x: jnp.ndarray, block: int = BLOCK
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) → (q int8 (n,), scale fp32 (ceil(n/block),))."""
    n = x.shape[0]
    pad = (-n) % block
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    amax = jnp.max(jnp.abs(xf), axis=1)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               block: int = BLOCK) -> jnp.ndarray:
    n = q.shape[0]
    pad = (-n) % block
    qf = jnp.pad(q, (0, pad)).reshape(-1, block).astype(jnp.float32)
    x = qf * scale[:, None]
    return x.reshape(-1)[:n]


def ef_quantize(x: jnp.ndarray, block: int = BLOCK):
    """Error-feedback quantization: returns (q, scale, error) where
    error = x − dequantize(q, scale) is carried to the next step."""
    q, scale = quantize(x, block)
    err = x.astype(jnp.float32) - dequantize(q, scale, block)
    return q, scale, err


def compression_ratio(n: int, block: int = BLOCK,
                      wire_dtype_bytes: int = 2) -> float:
    """Wire-byte ratio vs an uncompressed transfer of the same payload."""
    comp = n * 1 + (-(-n // block)) * 4
    return comp / (n * wire_dtype_bytes)
