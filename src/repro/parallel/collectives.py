"""FRED-style collective schedules as explicit shard_map programs.

The pjit/GSPMD path lets XLA choose collectives from shardings; this module
is the *explicit* layer used where schedule control matters (the gradient
path of the streaming trainer, the comm microbenchmarks, and the
compressed-gradient mode) and where the paper's ideas map directly:

  * ``flat``          — one ring All-Reduce over every data-parallel rank:
                        the endpoint algorithm FRED's baseline runs.
  * ``hierarchical``  — FRED's L1/L2 reduction-distribution tree mapped to
                        mesh axes: reduce-scatter *inside* the pod (L1
                        reduce), all-reduce across pods on the scattered
                        shard (L2 reduce — the only traffic that crosses
                        the narrow inter-pod link), all-gather inside the
                        pod (distribution tree).  Cross-pod bytes drop from
                        full-D to D/|data| exactly like FRED-B's L1-first
                        reduction (Sec. VIII).
  * ``compressed``    — hierarchical + int8 error-feedback quantization on
                        the cross-pod phase (software analogue of in-switch
                        traffic halving; beyond-paper optimization).

All functions run *inside* ``shard_map`` bodies, or use ``build_sync`` to
wrap a whole gradient pytree.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map
from .compress import ef_quantize, dequantize


def _pad_to(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, pad


def flat_all_reduce(x, axes: Sequence[str]):
    """Single-phase psum over every replica (endpoint/ring semantics)."""
    return jax.lax.psum(x, tuple(axes))


def hierarchical_all_reduce(x, inner_axis: str, outer_axis: Optional[str],
                            axis_size: int):
    """reduce_scatter(inner) → all_reduce(outer) → all_gather(inner).

    x: flat (n, ...) array replicated-shape per shard (same shape on every
    rank, holding that rank's local values)."""
    xp, pad = _pad_to(x, axis_size)
    shard = jax.lax.psum_scatter(xp, inner_axis, scatter_dimension=0,
                                 tiled=True)
    if outer_axis is not None:
        shard = jax.lax.psum(shard, outer_axis)
    full = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)
    return full[:x.shape[0]] if pad else full


def compressed_all_reduce(x, error, inner_axis: str,
                          outer_axis: Optional[str], axis_size: int):
    """Hierarchical all-reduce with int8 EF-compressed cross-pod phase.

    Returns (result, new_error).  The inner reduce-scatter stays full
    precision (ICI is fast inside a pod); only the scattered shard that
    crosses pods is quantized — with error feedback so the bias is
    corrected on the next step (convergence-safe).
    """
    xp, pad = _pad_to(x, axis_size)
    shard = jax.lax.psum_scatter(xp, inner_axis, scatter_dimension=0,
                                 tiled=True)
    new_error = jnp.zeros_like(shard[:0])  # placeholder when no outer axis
    if outer_axis is not None:
        carry = shard + error
        q, scale, new_error = ef_quantize(carry)
        # int8 values cannot psum without overflow: dequantize-and-sum via
        # all_gather of the compressed payload (bytes: |pod|·D/|data|/4
        # vs bf16 full-D — a ≥8× cross-pod reduction for |data|=16)
        qs = jax.lax.all_gather(q, outer_axis)
        ss = jax.lax.all_gather(scale, outer_axis)
        shard = jnp.sum(jax.vmap(dequantize)(qs, ss), axis=0).astype(x.dtype)
    full = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)
    out = full[:x.shape[0]] if pad else full
    return out, new_error


def build_sync(mesh: Mesh, mode: str = "hierarchical",
               inner_axis: str = "data", outer_axis: Optional[str] = None):
    """Gradient synchronizer over *replica-stacked* local grads.

    Input leaves carry a leading replica dim of size
    |outer_axis|·|inner_axis| (sharded over those axes — each rank holds
    its own local gradient slice); the output drops that dim and is the
    replicated global mean.  ``mode='compressed'`` additionally threads an
    error-feedback pytree (leaves shaped like the cross-pod shard).
    """
    axes = tuple(a for a in (outer_axis, inner_axis) if a)
    n_inner = mesh.shape[inner_axis]
    n_total = 1
    for a in axes:
        n_total *= mesh.shape[a]

    def sync_leaf(g):
        flat = g.reshape(-1)
        if mode == "flat":
            out = flat_all_reduce(flat, axes)
        else:
            out = hierarchical_all_reduce(flat, inner_axis, outer_axis,
                                          n_inner)
        return (out / n_total).reshape(g.shape).astype(g.dtype)

    def sync_leaf_compressed(g, err):
        flat = g.reshape(-1)
        out, new_err = compressed_all_reduce(flat, err, inner_axis,
                                             outer_axis, n_inner)
        return (out / n_total).reshape(g.shape).astype(g.dtype), new_err

    in_spec = P(axes)     # leading replica dim split over the DP axes
    out_spec = P()        # synced result is replicated

    if mode == "compressed":
        def sync(grads, errors):
            def body(gs, es):
                gs = jax.tree.map(lambda a: a[0], gs)   # drop replica dim
                es = jax.tree.map(lambda a: a[0], es)
                g_flat, tdef = jax.tree.flatten(gs)
                e_flat = tdef.flatten_up_to(es)
                pairs = [sync_leaf_compressed(g, e)
                         for g, e in zip(g_flat, e_flat)]
                return (jax.tree.unflatten(tdef, [p[0] for p in pairs]),
                        jax.tree.unflatten(tdef, [p[1][None] for p in pairs]))
            # all_gather(tiled) makes values equal across the inner axis
            # but the vma type system still marks them varying — the
            # replication is semantic, so disable the static check here
            return shard_map(body, mesh=mesh,
                                 in_specs=(in_spec, P(axes)),
                                 out_specs=(out_spec, P(axes)),
                                 check_vma=False)(grads, errors)
        return sync

    def sync(grads):
        def body(gs):
            gs = jax.tree.map(lambda a: a[0], gs)
            return jax.tree.map(sync_leaf, gs)
        return shard_map(body, mesh=mesh, in_specs=in_spec,
                             out_specs=out_spec, check_vma=False)(grads)
    return sync


def init_error_feedback(grads_shapes, mesh, inner_axis="data",
                        outer_axis="pod"):
    """Zero EF buffers matching the compressed cross-pod shards — one per
    replica (leading replica dim, sharded like the stacked grads)."""
    n = mesh.shape[inner_axis]
    reps = n * (mesh.shape[outer_axis] if outer_axis else 1)

    def leaf(s):
        size = 1
        for d in s.shape:
            size *= d
        shard = -(-size // n)
        return jnp.zeros((reps, shard), jnp.float32)
    return jax.tree.map(leaf, grads_shapes)
