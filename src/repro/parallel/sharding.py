"""Logical-axis → mesh-axis sharding rules.

This is the single place where parallelization policy becomes concrete
PartitionSpecs.  The model code only names *logical* axes (see
``models.modules``); the mesh only has *physical* axes (pod/data/model).
``Ruleset.spec(axes)`` translates.

Divisibility-aware policy (documented in DESIGN.md §6):

* TP axes (vocab/heads/kv/mlp/ssm_in/qkv) map to ``model``.  Query heads
  that don't divide the TP degree (llava 56H, qwen1.5 20H, arctic 56H over
  16) still shard — GSPMD pads the ragged tail — unless the arch opts into
  ``attn_sharding='context'``.
* KV heads shard over ``model`` only when divisible; otherwise the KV cache
  shards its *sequence* dim over ``model`` instead (flash-decoding layout)
  and kv projections stay replicated (they are tiny for strong-GQA archs).
* ``embed`` (d_model) shards over ``data`` when ``param_sharding='fsdp'``
  (ZeRO-3 style; GSPMD inserts the per-layer all-gathers); under ``zero1``
  only optimizer state takes the data sharding; under ``replicated``
  neither does.
* MoE ``expert`` shards over ``model`` when divisible (arctic 128/16),
  otherwise experts stay replicated and their ``mlp`` hidden dim takes the
  TP sharding (mixtral 8e over 16-way TP).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ParallelConfig
from repro.models.modules import AxisNames


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


@dataclasses.dataclass
class Ruleset:
    mesh: Mesh
    cfg: ModelConfig
    pcfg: ParallelConfig

    def __post_init__(self):
        mesh, cfg, pcfg = self.mesh, self.cfg, self.pcfg
        tp = pcfg.tp_axis if pcfg.tp_axis in mesh.shape else None
        dp: Tuple[str, ...] = tuple(a for a in pcfg.dp_axes if a in mesh.shape)
        if "pod" in mesh.shape and "pod" not in dp:
            dp = ("pod",) + dp
        if tp is None and "model" in mesh.shape and \
                "model" not in dp and pcfg.tp_axis == "":
            # no-TP mapping: the model axis becomes extra data parallelism
            # (a *parallelization strategy* choice, not a mesh change — the
            # flexibility the paper argues the fabric must support)
            dp = dp + ("model",)
        tp_size = _axis_size(mesh, tp)
        self.dp = dp
        self.tp = tp
        self.tp_size = tp_size
        fsdp = pcfg.param_sharding == "fsdp"
        # without TP, FSDP shards over every data axis (divisibility of
        # d_model by the full 256 holds for all assigned archs)
        fsdp_axis = (dp if tp is None else dp[-1]) if (fsdp and dp) else None

        kv_div = cfg.n_kv_heads > 0 and cfg.n_kv_heads % max(tp_size, 1) == 0
        heads_ok = cfg.n_heads > 0 and pcfg.attn_sharding != "context"
        exp_div = cfg.n_experts > 0 and cfg.n_experts % max(tp_size, 1) == 0
        # EP mode: experts shard over a *data* axis (all-to-all dispatch),
        # their hidden dim takes the TP sharding
        ep_axis = (pcfg.moe_ep_axis if pcfg.moe_ep_axis in mesh.shape and
                   cfg.n_experts and
                   cfg.n_experts % mesh.shape.get(pcfg.moe_ep_axis, 1) == 0
                   else None)
        self.ep_axis = ep_axis
        if ep_axis:
            exp_div = False

        self.kv_head_sharded = kv_div
        self.expert_sharded = exp_div

        rules = {
            "layers": None,
            "null": None,
            "embed": fsdp_axis,
            "embed_out": None,
            "vocab": tp if tp is not None else
            (tuple(dp) if fsdp else None),
            "qkv": tp,
            "heads": tp if heads_ok else None,
            "kv": tp,   # flattened Hkv·hd dim — always divisible
            "mlp": None if exp_div else tp,
            "expert": ep_axis if ep_axis else (tp if exp_div else None),
            "expert_router": None,
            "ssm_in": tp,
            "embed_unsharded": None,
            "mlp_dense": tp if tp is not None else
            (dp[-1] if (fsdp and dp) else None),
            "ssm_head": tp if (cfg.ssm_heads and cfg.ssm_heads % max(tp_size, 1) == 0) else None,
        }
        # Expert weights: never FSDP the d_model *contraction* dim (a
        # data-sharded contraction forces partial-sum all-reduces of the
        # (G,E,C,f) bucket tensor).  Put FSDP on the f dim instead —
        # combined with TP when experts aren't TP-sharded.
        if cfg.n_experts:
            if ep_axis:
                self.expert_mlp_axis = tp                 # (data, None, model)
            elif exp_div:
                self.expert_mlp_axis = fsdp_axis          # (model, None, data)
            else:
                self.expert_mlp_axis = ((tp, fsdp_axis) if (tp and fsdp_axis)
                                        else (tp or fsdp_axis))
        self.rules = rules

    # ---- parameters --------------------------------------------------------
    def spec(self, axes: AxisNames) -> P:
        names = tuple(axes)
        if "vocab" in names:
            # embedding/lm_head: never FSDP the d_model dim — a data-sharded
            # contraction dim would force logits partial-sums over the data
            # axis (measured: tens of GB of all-reduce per step).  The vocab
            # dim carries the TP sharding; ZeRO still shards the optimizer.
            return P(*(self.rules.get(a) if a == "vocab" else None
                       for a in names))
        if "expert" in names:
            # (expert, embed, mlp): FSDP lives on the mlp dim (see __post_init__)
            table = dict(self.rules)
            table["embed"] = None
            table["mlp"] = self.expert_mlp_axis
            return P(*(table.get(a, None) for a in names))
        return P(*(self.rules.get(a, None) for a in names))

    def param_shardings(self, axes_tree):
        return jax.tree.map(
            lambda a: NamedSharding(self.mesh, self.spec(a)), axes_tree,
            is_leaf=lambda x: isinstance(x, AxisNames))

    def opt_spec(self, axes: AxisNames) -> P:
        """Optimizer-state sharding: like params, but ZeRO-1 additionally
        shards over data on the 'embed' dim even when params are replicated."""
        if self.pcfg.param_sharding != "zero1":
            return self.spec(axes)
        dp_last = self.dp[-1] if self.dp else None
        names = []
        for a in axes:
            r = self.rules.get(a, None)
            if a == "embed" and r is None:
                r = dp_last
            names.append(r)
        return P(*names)

    # ---- activations ---------------------------------------------------------
    def batch_axes(self, global_batch: int) -> Optional[Tuple[str, ...]]:
        """Shard batch over as many dp axes as divide it (outermost first)."""
        axes = []
        rem = global_batch
        for a in self.dp:
            s = self.mesh.shape[a]
            if rem % s == 0 and rem >= s:
                axes.append(a)
                rem //= s
        return tuple(axes) or None

    def act_spec(self, kind: str, global_batch: int, *, ndim: int = 3) -> P:
        b = self.batch_axes(global_batch)
        seq = self.tp if (self.pcfg.seq_shard and kind == "residual") else None
        if kind == "residual":
            return P(b, seq, None)
        if kind == "logits":
            return P(b, None, self.tp)
        if kind == "tokens":
            return P(b, None)
        if kind == "q_heads":
            # uneven head counts (56, 20) shard with GSPMD padding
            hs = self.tp if self.rules.get("heads") else None
            return P(b, None, hs, None)
        if kind == "kv_heads":
            # replicate KV heads when they don't divide TP — they are tiny
            # for strong-GQA archs and replication avoids resharding storms
            return P(b, None, self.tp if self.kv_head_sharded else None, None)
        if kind == "moe_buckets":
            # (G, E, C, d/f): groups over data; experts over model when
            # expert-sharded; the expert hidden dim otherwise.
            # EP: experts carry the data axis (all-to-all dispatch), so the
            # group dim stays unsharded
            if getattr(self, "ep_axis", None):
                return P(None, self.ep_axis, None, None)
            e_ax = self.tp if self.expert_sharded else None
            f_ax = None if self.expert_sharded else self.tp
            return P(b, e_ax, None, f_ax)
        raise KeyError(kind)

    def constrain_fn(self, global_batch: int):
        mesh = self.mesh
        tp_size = max(self.tp_size, 1)

        def constrain(x, kind: str = "residual"):
            spec = list(self.act_spec(kind, global_batch))
            if x.ndim != len(spec):
                return x
            if kind == "moe_buckets" and spec[3] is not None and \
                    x.shape[3] % tp_size != 0:
                spec[3] = None   # bucket d dim: only the f-projection splits
            # drop the SP seq sharding when the seq dim doesn't divide TP
            if kind == "residual" and spec[1] is not None and \
                    x.shape[1] % tp_size != 0:
                spec[1] = None
            if kind == "q_heads" and x.shape[1] == 1:
                spec[1] = None  # decode: no seq to shard
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        return constrain

    # ---- decode state --------------------------------------------------------
    def kv_cache_spec(self, global_batch: int) -> P:
        """(L, B, S, Hkv, hd)."""
        b = self.batch_axes(global_batch)
        if b is None:
            # long-context single-sequence: spread the cache seq dim over
            # every mesh axis (flash-decode combines partial softmax stats)
            axes = tuple(a for a in (*self.dp, self.tp) if a)
            return P(None, None, axes or None, None, None)
        if self.kv_head_sharded:
            return P(None, b, None, self.tp, None)
        return P(None, b, self.tp, None, None)

    def ssm_state_spec(self, global_batch: int):
        """SSMState: h (L,B,H,hd,N), conv (L,B,K-1,C)."""
        b = self.batch_axes(global_batch)
        h_heads = self.rules["ssm_head"]
        return (P(None, b, h_heads, None, None), P(None, b, None, self.tp))

    def decode_state_shardings(self, cfg: ModelConfig, global_batch: int):
        """Shardings pytree matching transformer.DecodeState."""
        from repro.models.layers import KVCache
        from repro.models.transformer import DecodeState
        mesh = self.mesh
        ns = lambda spec: NamedSharding(mesh, spec)
        kv = ssm = shared = cross = None
        if cfg.family in ("ssm", "hybrid"):
            from repro.models.ssm import SSMState
            hspec, cspec = self.ssm_state_spec(global_batch)
            ssm = SSMState(h=ns(hspec), conv=ns(cspec))
            if cfg.family == "hybrid":
                shared = KVCache(ns(self.kv_cache_spec(global_batch)),
                                 ns(self.kv_cache_spec(global_batch)))
        else:
            kv = KVCache(ns(self.kv_cache_spec(global_batch)),
                         ns(self.kv_cache_spec(global_batch)))
            if cfg.family == "audio":
                # cross cache seq = enc_seq (1500, not TP-divisible): rely on
                # head sharding (whisper kv=16 divides) and keep seq whole
                xspec = P(None, self.batch_axes(global_batch),
                          None, self.tp if self.kv_head_sharded else None, None)
                cross = KVCache(ns(xspec), ns(xspec))
        return DecodeState(kv=kv, ssm=ssm, shared_kv=shared, cross_kv=cross,
                           index=ns(P()))
