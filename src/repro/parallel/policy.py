"""Per-cell parallelization policy (the "compiler" of this framework).

The paper's point is that the fabric should let the compiler pick whatever
parallelization strategy compute/memory prefers (Sec. I, Fig. 2).  This
module is that policy layer for the JAX runtime: given (arch × shape × mesh)
it returns the ParallelConfig/OptimConfig the step builders use.

Defaults are the *paper-faithful hierarchical* schedule; the dry-run records
these, and §Perf hillclimbs override via ``pcfg_overrides``.
"""

from __future__ import annotations

from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.train.optim import OptimConfig


def cell_policy(cfg: ModelConfig, shape: ShapeConfig, mesh):
    pcfg = ParallelConfig()
    ocfg = OptimConfig()

    # --- optimizer memory modes ---------------------------------------------
    # arctic-480b: 469B expert params; fp32 master+moments (12B/param) cannot
    # fit 256×16GB.  8-bit moments + no master (6B/param incl. grads) fits.
    if cfg.name == "arctic-480b":
        ocfg = OptimConfig(master=False, moments_dtype="int8")
    elif cfg.name in ("qwen3-32b", "llava-next-34b", "mixtral-8x7b"):
        # 30-50B: master fp32 is fine, keep moments bf16 to halve opt state
        ocfg = OptimConfig(master=True, moments_dtype="bfloat16")

    # --- remat ---------------------------------------------------------------
    # full remat for all train cells: at 1M tokens/step the saved-dot memory
    # of 'block' exceeds HBM for most archs; the recompute shows up honestly
    # in the HLO-vs-model FLOP ratio of §Roofline.
    if shape.kind == "train":
        pcfg = pcfg.replace(remat="full")

    # --- attention chunking ---------------------------------------------------
    if shape.seq_len >= 32_768:
        pcfg = pcfg.replace(attn_q_chunk=512, attn_k_chunk=1024)

    return pcfg, ocfg
