"""Per-cell parallelization policy (the "compiler" of this framework).

The paper's point is that the fabric should let the compiler pick whatever
parallelization strategy compute/memory prefers (Sec. I, Fig. 2).  This
module is that policy layer for the JAX runtime: given (arch × shape × mesh)
it returns the ParallelConfig/OptimConfig the step builders use.

Two modes:

* ``autostrategy=False`` (default) — the frozen *paper-faithful* schedule:
  hand-set optimizer memory modes, remat, and attention chunking, exactly
  as recorded by the dry-runs (pinned in tests/test_autostrategy.py).
* ``autostrategy=True`` — sweep-driven: the analytical FRED simulator
  (``core.sweep`` via ``core.autostrategy.choose``) picks the
  memory-feasible Pareto-optimal (mp, dp, pp, wafers) — and, for
  cross-wafer DP, the inter-wafer topology (ring / fully_connected /
  switch, ``core.cluster``) — for the cell under the frozen defaults'
  OptimConfig/remat settings, and the decision lands in
  ``ParallelConfig.auto_strategy`` (plus ``grad_sync="hierarchical"``
  for cross-wafer DP).  The JAX mesh itself is built by the launcher —
  the recorded strategy is what the dry-run logs and what wafer-side
  placement (``core.placement``) executes.

§Perf hillclimbs still override via ``pcfg_overrides`` after either mode.
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.models.config import (ModelConfig, ParallelConfig, ShapeConfig,
                                 StrategyDecision)
from repro.train.optim import OptimConfig


def paper_defaults(cfg: ModelConfig, shape: ShapeConfig
                   ) -> Tuple[ParallelConfig, OptimConfig]:
    """The frozen paper-faithful hierarchical schedule (pre-autostrategy
    behavior, bit-identical; pinned in tests/test_autostrategy.py)."""
    pcfg = ParallelConfig()
    ocfg = OptimConfig()

    # --- optimizer memory modes ---------------------------------------------
    # arctic-480b: 469B expert params; fp32 master+moments (12B/param) cannot
    # fit 256×16GB.  8-bit moments + no master (6B/param incl. grads) fits.
    if cfg.name == "arctic-480b":
        ocfg = OptimConfig(master=False, moments_dtype="int8")
    elif cfg.name in ("qwen3-32b", "llava-next-34b", "mixtral-8x7b"):
        # 30-50B: master fp32 is fine, keep moments bf16 to halve opt state
        ocfg = OptimConfig(master=True, moments_dtype="bfloat16")

    # --- remat ---------------------------------------------------------------
    # full remat for all train cells: at 1M tokens/step the saved-dot memory
    # of 'block' exceeds HBM for most archs; the recompute shows up honestly
    # in the HLO-vs-model FLOP ratio of §Roofline.
    if shape.kind == "train":
        pcfg = pcfg.replace(remat="full")

    # --- attention chunking ---------------------------------------------------
    if shape.seq_len >= 32_768:
        pcfg = pcfg.replace(attn_q_chunk=512, attn_k_chunk=1024)

    return pcfg, ocfg


def cell_policy(cfg: ModelConfig, shape: ShapeConfig, mesh,
                autostrategy: bool = False,
                sweep_kw: Optional[dict] = None,
                decision=None) -> Tuple[ParallelConfig, OptimConfig]:
    """Policy for one (arch × shape × mesh) cell.

    ``autostrategy=True`` runs the simulator sweep (``sweep_kw`` holds
    the :class:`~repro.core.specs.DeploymentRequest` axes: n_npus,
    fabrics, max_wafers, npu_hbm_bytes, ...) and stamps the chosen
    strategy on the
    returned ``ParallelConfig``; the frozen defaults are returned
    unchanged when ``False``.  A precomputed
    :class:`~repro.core.autostrategy.AutoStrategyDecision` can be passed
    as ``decision`` to skip the sweep (the dry-run records it anyway)."""
    pcfg, ocfg = paper_defaults(cfg, shape)
    if not autostrategy:
        return pcfg, ocfg

    if decision is None:
        from repro.core.autostrategy import _build_request, choose
        decision = choose(_build_request(
            cfg, shape, master=ocfg.master, moments_dtype=ocfg.moments_dtype,
            remat=pcfg.remat, **(sweep_kw or {})))
    st = decision.strategy
    pcfg = pcfg.replace(auto_strategy=StrategyDecision(
        mp=st.mp, dp=st.dp, pp=st.pp, wafers=st.wafers,
        ep=st.ep, sp=st.sp,
        inter_topology=decision.inter_topology,
        defect_seed=getattr(decision, "defect_seed", None)))
    if st.wafers > 1:
        # cross-wafer DP must use the hierarchical reduction: RS within
        # the wafer, the chosen inter-wafer collective (ring ring-AR /
        # fully-connected direct exchange / in-switch reduction) on the
        # shard, AG within
        pcfg = pcfg.replace(grad_sync="hierarchical")
    return pcfg, ocfg
