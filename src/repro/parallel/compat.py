"""jax version compatibility shims for the parallel substrate.

The repo targets current jax (``jax.shard_map``, ``check_vma``) but must
degrade gracefully on the 0.4.x runtimes still common in CI images, where
shard_map lives in ``jax.experimental.shard_map`` and the replication check
is spelled ``check_rep``.
"""

from __future__ import annotations


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Dispatch to ``jax.shard_map`` or the 0.4.x experimental fallback."""
    import jax
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def pcast_varying(x, axis_name: str):
    """Mark ``x`` varying over ``axis_name`` in the vma type system.

    Old runtimes have no vma typing, so the cast is the identity there."""
    import jax
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, (axis_name,), to="varying")
    return x
