"""Model / parallelism configuration dataclasses.

Every assigned architecture (and the paper's own workloads) is described by a
:class:`ModelConfig`.  The config is a *complete* architectural description:
the model code in ``repro.models`` consumes nothing else.

``ParallelConfig`` holds the distribution policy knobs that the runtime
(``repro.parallel``) uses to derive parameter/activation shardings for a
given mesh.  The FRED-inspired collective schedule is selected here as well
(``grad_sync``), so that the paper-faithful baseline ("flat" endpoint-style
ring all-reduce) and the FRED-style hierarchical schedule can be compared
like-for-like on the same model.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (family-polymorphic).

    Families:
      * ``dense``  — standard decoder-only transformer (llama/qwen/chatglm).
      * ``moe``    — mixture-of-experts FFN (mixtral/arctic).
      * ``ssm``    — attention-free Mamba2 / SSD stack.
      * ``hybrid`` — Mamba2 blocks + a *shared* attention block (zamba2).
      * ``vlm``    — decoder LM consuming precomputed patch embeddings
                     (llava; frontend is a stub per the task spec).
      * ``audio``  — encoder/decoder transformer consuming precomputed
                     audio frame embeddings (whisper; conv frontend stub).
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int
    d_model: int
    n_heads: int          # query heads (0 for attention-free)
    n_kv_heads: int       # KV heads (GQA); == n_heads for MHA
    d_ff: int             # FFN hidden size (0 for attention-free SSM stack)
    vocab_size: int

    head_dim: int = 128

    # --- attention variants -------------------------------------------------
    rope: str = "default"            # default | 2d (chatglm) | none
    rope_theta: float = 10000.0
    qk_norm: bool = False            # qwen3-style RMS norm on q/k heads
    qkv_bias: bool = False           # qwen1.5-style bias on QKV projections
    sliding_window: int = 0          # >0: SWA window (mixtral)
    causal: bool = True

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0            # arctic: parallel dense-residual FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0               # d_state (N)
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1              # B/C projection groups
    attn_every: int = 0              # hybrid: shared attn block period

    # --- encoder/decoder (audio) ----------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0                 # precomputed frame count (whisper: 1500)

    # --- VLM -----------------------------------------------------------------
    n_patches: int = 0               # precomputed patch embeddings (llava)

    # --- embeddings / misc ----------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    vocab_pad_to: int = 256          # pad vocab for TP divisibility

    # --- attention applicability metadata -------------------------------------
    subquadratic: bool = False       # may run long_500k decode

    # -------------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab_size, self.vocab_pad_to
        return ((v + m - 1) // m) * m

    @property
    def d_qkv(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2 if not self.attn_every else 4),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            moe_dense_ff=64 if self.moe_dense_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.enc_seq else 0,
            n_patches=8 if self.n_patches else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            vocab_pad_to=32,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (architecture × input-shape) grid."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class StrategyDecision:
    """The sweep-chosen parallelization for a cell (core/autostrategy.py).

    Replaces the legacy positional 5-tuple ``(mp, dp, pp, wafers,
    inter_topology)`` with named fields, while staying *tuple-compatible*:
    iteration, ``len``, indexing, unpacking, and equality against a plain
    tuple all see exactly those five legacy fields.  New axes ride along
    without widening the tuple protocol: ``ep``/``sp`` reserve the
    expert- and sequence-parallel degrees, and ``defect_seed`` records
    the :func:`repro.core.defects.sample_mask` seed when the decision was
    made under a defect mask (None = pristine wafer).
    """

    mp: int = 0
    dp: int = 0
    pp: int = 0
    wafers: int = 0
    inter_topology: str = ""      # ring | fully_connected | switch; ""
                                  # for single-wafer decisions
    ep: int = 1                   # expert-parallel degree (reserved)
    sp: int = 1                   # sequence-parallel degree (reserved)
    defect_seed: Optional[int] = None

    @property
    def is_set(self) -> bool:
        """False for the all-zero sentinel (sweep not run)."""
        return self._legacy() != (0, 0, 0, 0, "")

    def _legacy(self) -> tuple:
        return (self.mp, self.dp, self.pp, self.wafers,
                self.inter_topology)

    # -- legacy tuple protocol ----------------------------------------------
    def __iter__(self):
        return iter(self._legacy())

    def __len__(self) -> int:
        return 5

    def __getitem__(self, i):
        return self._legacy()[i]

    def __eq__(self, other):
        if isinstance(other, StrategyDecision):
            return dataclasses.astuple(self) == dataclasses.astuple(other)
        if isinstance(other, tuple):
            return self._legacy() == other
        return NotImplemented

    def __hash__(self):
        return hash(self._legacy())

    @classmethod
    def coerce(cls, value) -> "StrategyDecision":
        """Adapt a legacy positional tuple (or pass a decision through)."""
        if isinstance(value, cls):
            return value
        return cls(*value)


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution policy for a given mesh.

    ``grad_sync`` selects the data-parallel gradient synchronization
    schedule — this is where the FRED technique surfaces in the runtime:

      * ``flat``       — single ring all-reduce across all data-parallel
                         replicas (the endpoint-based collective the paper's
                         2D-mesh baseline is limited to).
      * ``hierarchical`` — FRED-style reduction tree: reduce-scatter inside
                         the pod (the L1 switch reduction), all-reduce across
                         pods on the scattered shard (the L2 reduction), then
                         all-gather inside the pod (the distribution tree).
      * ``compressed`` — hierarchical + int8 quantization with error feedback
                         on the cross-pod phase (software analogue of FRED's
                         in-network traffic halving; beyond-paper).
    """

    mesh_axes: Tuple[str, ...] = ("data", "model")
    dp_axes: Tuple[str, ...] = ("data",)          # batch-sharded axes
    tp_axis: str = "model"
    param_sharding: str = "fsdp"                  # replicated | zero1 | fsdp
    attn_sharding: str = "heads"                  # heads | context
    scan_layers: bool = True
    remat: str = "block"                          # none | block | full
    grad_sync: str = "hierarchical"               # flat | hierarchical | compressed
    seq_shard: bool = True                        # SP: shard seq dim of activations
    moe_ep_axis: str = ""                         # "" = TP-only MoE
    compute_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"
    attn_q_chunk: int = 1024
    attn_k_chunk: int = 1024
    use_pallas: bool = False                      # TPU-only fused kernels
    # sweep-driven auto-strategy (core/autostrategy.py): the simulator-
    # chosen StrategyDecision for this cell.  The default (all-zero)
    # decision means hand-set defaults / sweep not run.  Tuple-compatible
    # with the legacy (mp, dp, pp, wafers, inter_topology) 5-tuple —
    # a plain tuple assigned here still unpacks and compares the same.
    # Informational for the runtime mesh (the launcher builds the mesh),
    # executable for the wafer-side placement.
    auto_strategy: StrategyDecision = StrategyDecision()

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)
