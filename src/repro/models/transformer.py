"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

One model class (functions + pytrees, no framework) serves all ten assigned
architectures.  Layers are *stacked* on a leading ``layers`` axis and
executed with ``lax.scan`` so the compiled HLO is O(1) in depth — essential
for the 512-device dry-run compile times — with ``jax.checkpoint`` (remat)
around the block body.

Hybrid (zamba2) structure: ``num_layers`` Mamba2 blocks; after every
``attn_every`` of them, a single *shared* attention block (one set of
weights, applied num_layers/attn_every times, each application with its own
KV cache slice — weights shared, activations not).

Entry points:
  * ``init``          — Box-tree of parameters.
  * ``loss_fn``       — (params, batch) → (loss, metrics); full causal LM.
  * ``prefill``       — builds the decode state (KV caches / SSM states).
  * ``decode_step``   — one token for every sequence in the batch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, ParallelConfig
from .layers import (KVCache, apply_attn_block, init_attn_block)
from .modules import (Box, AxisNames, dense_init, embed_init, ones_init,
                      rms_norm, softmax_cross_entropy, split)
from .ssm import SSMState, init_mamba2, init_ssm_state, mamba2_forward


class DecodeState(NamedTuple):
    """Everything carried between decode steps (pytree)."""
    kv: Any            # stacked KVCache or None
    ssm: Any           # stacked SSMState or None
    shared_kv: Any     # hybrid: (groups,) stacked KVCache for the shared block
    cross_kv: Any      # enc-dec: stacked static cross-attention cache
    index: jnp.ndarray  # scalar int32 — next write position / #tokens seen


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stack_init(block_init, keys):
    """vmap an init over layer keys; re-attach 'layers' axis metadata."""
    one = block_init(keys[0])
    _, axes_one = split(one)

    def vinit(k):
        v, _ = split(block_init(k))
        return v

    vals = jax.vmap(vinit)(keys)
    axes = jax.tree.map(lambda a: a.stacked(), axes_one,
                        is_leaf=lambda x: isinstance(x, AxisNames))
    return jax.tree.map(Box, vals, axes,
                        is_leaf=lambda x: isinstance(x, AxisNames))


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    V = cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], V, cfg.d_model, dtype),
        "final_norm": ones_init((cfg.d_model,), ("embed",), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, V),
                                       ("embed", "vocab"), scale=0.02, dtype=dtype)

    lkeys = jax.random.split(keys[2], max(cfg.num_layers, 1))
    ffn = "moe" if cfg.n_experts else "mlp"
    if cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: {"ln": ones_init((cfg.d_model,), ("embed",), dtype),
                       "ssm": init_mamba2(k, cfg, dtype)}, lkeys)
    elif cfg.family == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: {"ln": ones_init((cfg.d_model,), ("embed",), dtype),
                       "ssm": init_mamba2(k, cfg, dtype)}, lkeys)
        params["shared_attn"] = init_attn_block(keys[3], cfg, dtype)
    else:
        with_cross = cfg.family == "audio"
        params["blocks"] = _stack_init(
            lambda k: init_attn_block(k, cfg, dtype, ffn=ffn,
                                      with_cross=with_cross), lkeys)

    if cfg.family == "vlm":
        params["mm_proj"] = dense_init(keys[4], (cfg.d_model, cfg.d_model),
                                       ("embed", "embed_out"), dtype=dtype)
    if cfg.family == "audio":
        from .whisper import init_encoder
        params["encoder"] = init_encoder(keys[5], cfg, dtype)
    return params


# --------------------------------------------------------------------------
# shared forward machinery
# --------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch, constrain):
    """Token (+ patch) embedding.  Returns (x, positions)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype) @ params["mm_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return constrain(x), positions


def _maybe_remat(fn, pcfg: ParallelConfig):
    if pcfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if pcfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _scan_blocks(params, cfg, pcfg, x, positions, constrain, *,
                 mode="train", kv=None, ssm=None, shared_kv=None,
                 cross_kv=None, enc_out=None, cache_index=None,
                 cache_len=None, layer_constrain=lambda bp: bp):
    """Run the full stacked block stack.  Returns
    (x, new_kv, new_ssm, new_shared_kv, new_cross_kv, aux).

    ``None`` flows through ``lax.scan`` xs/ys as an empty pytree, so modes
    that carry no cache/state (train) pay zero memory for them.
    """
    L = cfg.num_layers
    is_ssm_family = cfg.family in ("ssm", "hybrid")

    def maybe_scan(body, carry, xs, length):
        """lax.scan, or an unrolled python loop when ``scan_layers=False``
        (used by the dry-run's single/double-layer cost probes so that
        ``cost_analysis`` sees every layer)."""
        if pcfg.scan_layers:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(length):
            xsl = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xsl)
            ys.append(y)
        # None subtrees pass through tree.map untouched
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
        return carry, stacked

    if is_ssm_family:
        def body(carry, xs):
            h, = carry
            bp, st = xs
            # re-pin the per-layer slice to its stored sharding so FSDP
            # all-gathers happen inside the loop body, not on the whole stack
            bp = layer_constrain(bp)

            def run(h, bp, st):
                hin = rms_norm(h, bp["ln"], cfg.norm_eps)
                if mode == "train":
                    out = mamba2_forward(bp["ssm"], hin, cfg)
                    return constrain(h + out), None
                out, new_st = mamba2_forward(bp["ssm"], hin, cfg,
                                             state=st, return_state=True)
                return constrain(h + out), new_st
            run = _maybe_remat(run, pcfg)
            h, new_st = run(h, bp, st)
            return (h,), new_st

        scan_ssm = ssm if mode == "decode" else None
        if cfg.family == "hybrid":
            groups = L // cfg.attn_every
            gp = jax.tree.map(lambda a: a.reshape(groups, cfg.attn_every, *a.shape[1:]),
                              params["blocks"])
            gs = (jax.tree.map(lambda a: a.reshape(groups, cfg.attn_every, *a.shape[1:]),
                               scan_ssm) if scan_ssm is not None else None)
            new_ssm_groups, new_shared = [], []
            aux = jnp.zeros((), jnp.float32)
            for g in range(groups):
                bg = jax.tree.map(lambda a: a[g], gp)
                sg = jax.tree.map(lambda a: a[g], gs) if gs is not None else None
                (x,), sg_new = maybe_scan(body, (x,), (bg, sg), cfg.attn_every)
                new_ssm_groups.append(sg_new)
                skv = (jax.tree.map(lambda a: a[g], shared_kv)
                       if shared_kv is not None else None)
                x, nkv, _, a = apply_attn_block(
                    params["shared_attn"], cfg, pcfg, x, positions=positions,
                    mode=mode, cache=skv, cache_index=cache_index,
                    cache_len=cache_len, constrain=constrain)
                aux = aux + a
                new_shared.append(nkv)
            new_ssm = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_ssm_groups)
                       if mode != "train" else None)
            new_shared_kv = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_shared)
                            if mode != "train" else None)
            return x, None, new_ssm, new_shared_kv, None, aux

        (x,), new_ssm = maybe_scan(body, (x,), (params["blocks"], scan_ssm), L)
        return (x, None, new_ssm if mode != "train" else None, None, None,
                jnp.zeros((), jnp.float32))

    # --- attention families ------------------------------------------------
    has_cross = cfg.family == "audio"

    def body(carry, xs):
        h, = carry
        bp, kvl, xkvl = xs
        bp = layer_constrain(bp)

        def run(h, bp, kvl, xkvl):
            hh, nkv, nxkv, a = apply_attn_block(
                bp, cfg, pcfg, h, positions=positions, mode=mode,
                cache=kvl, cache_index=cache_index, cache_len=cache_len,
                cross_cache=xkvl, enc_out=enc_out, constrain=constrain)
            if mode == "train":
                nkv, nxkv = None, None
            elif mode == "decode":
                nxkv = None   # cross cache is static; avoid re-stacking it
            return hh, nkv, nxkv, a
        run = _maybe_remat(run, pcfg)
        h, nkv, nxkv, a = run(h, bp, kvl, xkvl)
        return (h,), (nkv, nxkv, a)

    scan_kv = kv if mode == "decode" else None
    scan_cross = cross_kv if (has_cross and mode == "decode") else None
    (x,), (new_kv, new_cross, auxs) = maybe_scan(
        body, (x,), (params["blocks"], scan_kv, scan_cross), L)
    aux = jnp.sum(jnp.asarray(auxs))
    want_cache = mode in ("prefill", "decode")
    return (x, new_kv if want_cache else None, None, None,
            new_cross if (has_cross and mode == "prefill") else None, aux)


# --------------------------------------------------------------------------
# training loss
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig, pcfg: ParallelConfig,
            constrain=lambda t, kind="residual": t, enc_fn=None,
            layer_constrain=lambda bp: bp):
    """Causal LM loss.  batch: tokens (B,S) int32, labels (B,S) int32
    (−1 = masked), plus family-specific extras (patch_embeds / frames)."""
    x, positions = _embed_inputs(params, cfg, batch, constrain)
    enc_out = enc_fn(params, batch) if enc_fn is not None else None
    x, _, _, _, _, aux = _scan_blocks(params, cfg, pcfg, x, positions,
                                      constrain, mode="train", enc_out=enc_out,
                                      layer_constrain=layer_constrain)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head, "logits")
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # image positions don't predict tokens
        P = batch["patch_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], P), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss, count = softmax_cross_entropy(logits, labels, cfg.vocab_size)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": count}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    """Allocate the decode state for a given cache length."""
    L = cfg.num_layers
    kv = ssm = shared = cross = None
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    if cfg.family in ("ssm", "hybrid"):
        ssm = jax.vmap(lambda _: init_ssm_state(cfg, batch, dtype))(jnp.arange(L))
        if cfg.family == "hybrid":
            groups = L // cfg.attn_every
            z = jnp.zeros((groups, batch, eff_len, cfg.n_kv_heads, cfg.head_dim), dtype)
            shared = KVCache(z, z)
    else:
        z = jnp.zeros((L, batch, eff_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        kv = KVCache(z, z)
        if cfg.family == "audio":
            zc = jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
            cross = KVCache(zc, zc)
    return DecodeState(kv=kv, ssm=ssm, shared_kv=shared, cross_kv=cross,
                       index=jnp.zeros((), jnp.int32))


def prefill(params, batch, cfg, pcfg, cache_len: int,
            constrain=lambda t, kind="residual": t, enc_fn=None,
            layer_constrain=lambda bp: bp) -> Tuple[jnp.ndarray, DecodeState]:
    """Run the prompt; return (last-token logits, DecodeState)."""
    x, positions = _embed_inputs(params, cfg, batch, constrain)
    enc_out = enc_fn(params, batch) if enc_fn is not None else None
    x, kv, ssm, shared, cross, _ = _scan_blocks(
        params, cfg, pcfg, x, positions, constrain, mode="prefill",
        enc_out=enc_out, cache_len=cache_len, layer_constrain=layer_constrain)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head, "logits")
    state = DecodeState(kv=kv, ssm=ssm, shared_kv=shared, cross_kv=cross,
                        index=jnp.array(batch["tokens"].shape[1] +
                                        (batch.get("patch_embeds").shape[1]
                                         if cfg.family == "vlm" and
                                         "patch_embeds" in batch else 0),
                                        jnp.int32))
    return logits[:, 0], state


def decode_step(params, tokens, state: DecodeState, cfg, pcfg,
                constrain=lambda t, kind="residual": t,
                layer_constrain=lambda bp: bp
                ) -> Tuple[jnp.ndarray, DecodeState]:
    """One decode step.  tokens: (B, 1) int32 → logits (B, V)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B = x.shape[0]
    positions = jnp.broadcast_to(state.index[None, None], (B, 1)).astype(jnp.int32)
    x, kv, ssm, shared, cross, _ = _scan_blocks(
        params, cfg, pcfg, x, positions, constrain, mode="decode",
        kv=state.kv, ssm=state.ssm, shared_kv=state.shared_kv,
        cross_kv=state.cross_kv, cache_index=state.index,
        layer_constrain=layer_constrain)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head, "logits")
    new_state = DecodeState(kv=kv if kv is not None else state.kv,
                            ssm=ssm if ssm is not None else state.ssm,
                            shared_kv=shared if shared is not None else state.shared_kv,
                            cross_kv=state.cross_kv,
                            index=state.index + 1)
    return logits[:, 0], new_state
