"""Attention: GQA with RoPE variants, qk-norm, sliding windows, and a
memory-bounded chunked ("flash-style") implementation in pure jnp.

The chunked implementation is the *reference semantics* for the Pallas
flash kernel in ``repro.kernels.flash_attention`` and is what the dry-run
lowers (Pallas runs only on real TPUs; see ``ParallelConfig.use_pallas``).

Design notes
------------
* All softmax statistics are fp32; matmuls run in the compute dtype (bf16).
* Chunking is a double ``lax.scan``: outer over query blocks, inner over KV
  blocks with running (max, denom) online-softmax state — O(S·chunk) memory
  instead of O(S²), which is what lets ``prefill_32k`` fit HBM.
* Causal + sliding-window masks are computed from block offsets, and KV
  blocks that are fully masked are *skipped for memory purposes only* (the
  scan still visits them — XLA hoists the constant mask; on TPU the Pallas
  kernel skips them for compute too).
* Decode (q_len == 1) takes a separate path: no materialized S×S scores,
  works on a KV cache whose *sequence* dim may be sharded over the ``model``
  mesh axis — GSPMD turns the masked softmax reductions into tiny
  all-reduces of per-head statistics (flash-decoding style).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mode: str = "default") -> jnp.ndarray:
    """Rotary embedding, rotate-half convention.

    x: (B, S, H, hd); positions: (B, S) absolute positions.
    ``mode``:
      * ``default`` — rotate the full head_dim (llama-style rotate-half:
        pairs are (x[i], x[i+hd/2])).
      * ``2d``      — chatglm/GLM RoPE: only the first half of head_dim is
        rotated; the second half passes through.
      * ``none``    — identity.

    The rotate-half (rather than interleaved-pair) layout is deliberate:
    it lowers to two slices + one concatenate on the *minor* dim, which the
    SPMD partitioner handles without resharding copies.  Interleaved
    stack+reshape forced an involuntary full rematerialization under
    (SP seq × TP heads) sharding.
    """
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot_dim = hd if mode == "default" else hd // 2
    half = rot_dim // 2
    freqs = rope_frequencies(rot_dim, theta)                      # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs     # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)          # (B,S,1,half)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1 = x[..., :half]
    x2 = x[..., half:rot_dim]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    if rot_dim == hd:
        return jnp.concatenate([r1, r2], axis=-1)
    return jnp.concatenate([r1, r2, x[..., rot_dim:]], axis=-1)


# --------------------------------------------------------------------------
# chunked flash attention (pure jnp oracle + production fallback)
# --------------------------------------------------------------------------

def _block_mask(q_off, k_off, q_blk, k_blk, causal, window, kv_len):
    """(q_blk, k_blk) additive mask for a q/k block pair at given offsets."""
    qi = q_off + jnp.arange(q_blk)[:, None]
    kj = k_off + jnp.arange(k_blk)[None, :]
    ok = kj < kv_len
    if causal:
        ok &= kj <= qi
    if window:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """GQA → MHA: (B,S,Hkv,hd) → (B,S,Hkv·n_rep,hd).

    Making the repeat explicit (instead of a grouped 6-D einsum) keeps every
    attention tensor at a single clean head dim, which the SPMD partitioner
    shards over ``model`` without the pathological Hkv×group axis splits we
    measured (all-to-alls inside every chunk-scan iteration)."""
    if n_rep == 1:
        return k
    B, S, H, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, H, n_rep, hd)) \
        .reshape(B, S, H * n_rep, hd)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool = True, window: int = 0,
                      q_chunk: int = 1024, k_chunk: int = 1024,
                      q_offset: int = 0, kv_len: Optional[int] = None,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Online-softmax blocked attention.

    q: (B, Sq, H, hd);  k/v: (B, Sk, H, hd) — GQA repeat happens *before*
    this call (see ``repeat_kv``).  Returns (B, Sq, H, hd) in q.dtype.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if k.shape[2] != H:
        k = repeat_kv(k, H // k.shape[2])
        v = repeat_kv(v, H // v.shape[2])
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kv_len = Sk if kv_len is None else kv_len

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = -(-Sq // q_chunk), -(-Sk // k_chunk)
    # pad to whole blocks
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * k_chunk - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * k_chunk - Sk), (0, 0), (0, 0)))

    qb = qp.reshape(B, nq, q_chunk, H, hd)
    kb = kp.reshape(B, nk, k_chunk, H, hd)
    vb = vp.reshape(B, nk, k_chunk, H, hd)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk                                    # qblk: (B,qc,H,hd)
        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        acc0 = jnp.zeros((B, q_chunk, H, hd), jnp.float32)

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            s = jnp.einsum("bqhd,bkhd->bqhk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(qi * q_chunk + q_offset, kj * k_chunk,
                               q_chunk, k_chunk, causal, window, kv_len)
            s = s + mask[None, :, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        ks = (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0), ks)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    qs = (jnp.arange(nq), jnp.moveaxis(qb, 1, 0))
    _, outs = jax.lax.scan(q_step, None, qs)                  # (nq,B,qc,H,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq]


def dense_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None, scale=None):
    """Plain (materialized-scores) attention — used for short sequences and
    as the numerically trivial oracle for the chunked/Pallas versions."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if k.shape[2] != H:
        k = repeat_kv(k, H // k.shape[2])
        v = repeat_kv(v, H // v.shape[2])
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kv_len = Sk if kv_len is None else kv_len
    s = jnp.einsum("bqhd,bkhd->bqhk", q, k,
                   preferred_element_type=jnp.float32) * scale
    qi = q_offset + jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    ok = kj < kv_len
    if causal:
        ok &= kj <= qi
    if window:
        ok &= kj > qi - window
    s = s + jnp.where(ok, 0.0, NEG_INF)[None, :, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhk,bkhd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: Optional[float] = None):
    """Single-token decode attention over a (possibly seq-sharded) KV cache.

    q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd); cache_len: scalar or (B,)
    count of valid cache positions (the new token's K/V must already be
    written at position cache_len-1... i.e. included).

    The masked max/sum reductions over S are partitioner-friendly: when S is
    sharded over the ``model`` axis, XLA emits partial reductions plus an
    all-reduce over (B, H) statistics — the flash-decoding pattern — instead
    of gathering the cache.
    """
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, group, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))        # (B,S)
    if window:
        valid &= pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bkhd->bhgd", (p / denom).astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)
