"""Minimal pure-JAX module substrate.

No flax/haiku here — parameters are plain pytrees of ``jnp.ndarray``.  Each
``init_*`` function returns a pytree whose leaves are :class:`Box` — an array
together with its *logical axis names*.  ``split`` separates the value tree
from the axis tree; the axis tree is consumed by ``repro.parallel.sharding``
to produce ``NamedSharding``s for any mesh, which keeps parameter structure
and sharding metadata impossible to de-synchronize.

Logical axis vocabulary (mapped to physical mesh axes by sharding rules):

  ``layers``   stacked-layer leading dim (never sharded; scanned over)
  ``embed``    d_model                                   (FSDP candidate)
  ``qkv``      fused attention projection output         (TP)
  ``heads``    attention heads                           (TP)
  ``kv``       kv heads / kv projection output           (TP when divisible)
  ``mlp``      FFN hidden                                (TP)
  ``vocab``    (padded) vocabulary                       (TP)
  ``expert``   MoE expert dim                            (EP/TP)
  ``ssm_in``   SSM inner channels                        (TP)
  ``null``     never sharded
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AxisNames:
    """Logical axis names for one parameter — deliberately NOT a pytree,
    so an axes-tree has exactly the structure of its value-tree."""

    __slots__ = ("names",)

    def __init__(self, *names: str):
        self.names = tuple(names)

    def stacked(self, name: str = "layers") -> "AxisNames":
        return AxisNames(name, *self.names)

    def __iter__(self):
        return iter(self.names)

    def __len__(self):
        return len(self.names)

    def __eq__(self, other):
        return isinstance(other, AxisNames) and self.names == other.names

    def __hash__(self):
        return hash(self.names)

    def __repr__(self):
        return f"AxisNames{self.names}"


class Box(NamedTuple):
    """A parameter leaf: array value + logical axis names (one per dim)."""

    value: Any
    axes: AxisNames


def is_box(x) -> bool:
    return isinstance(x, Box)


def boxed_tree_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_box)


def split(tree):
    """Split a Box-tree into (value_tree, axes_tree)."""
    values = boxed_tree_map(lambda b: b.value, tree)
    axes = boxed_tree_map(lambda b: b.axes, tree)
    return values, axes


def unsplit(values, axes):
    return jax.tree.map(Box, values, axes,
                        is_leaf=lambda x: isinstance(x, AxisNames))


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, axes, scale: float | None = None, dtype=jnp.float32) -> Box:
    """Truncated-normal fan-in init (the usual transformer default)."""
    fan_in = shape[0] if len(shape) <= 2 else int(math.prod(shape[:-1]))
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    v = std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return Box(v, AxisNames(*axes))


def zeros_init(shape, axes, dtype=jnp.float32) -> Box:
    return Box(jnp.zeros(shape, dtype), AxisNames(*axes))


def ones_init(shape, axes, dtype=jnp.float32) -> Box:
    return Box(jnp.ones(shape, dtype), AxisNames(*axes))


def embed_init(key, vocab, d, dtype=jnp.float32) -> Box:
    v = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return Box(v, AxisNames("vocab", "embed"))


# --------------------------------------------------------------------------
# core ops
# --------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-5):
    """RMSNorm in fp32 accumulation (returns x.dtype)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


def linear(x, w, b=None):
    """x @ w with optional bias; w may be (d_in, d_out) or (d_in, h, hd)."""
    y = jnp.einsum("...d,dk->...k", x, w.reshape(w.shape[0], -1))
    y = y.reshape(*x.shape[:-1], *w.shape[1:])
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


NEG_BIG = -3e38  # near-min float32; representable in bf16 too


def softmax_cross_entropy(logits, labels, vocab_size: int, z_weight: float = 0.0):
    """Token-level CE over a (possibly padded) vocab; labels < 0 are masked.

    Memory-lean by construction: logits stay in their compute dtype (bf16);
    all fp32 appears only inside reductions (max / exp-sum / einsum with
    ``preferred_element_type``) which XLA fuses — no fp32 (B,S,V) tensor is
    ever materialized.  Padded vocab entries are suppressed with a
    multiplicative mask *inside* the exp-sum so no masked copy of the
    logits is created either.  Vocab may be sharded over TP; the reductions
    become partial + tiny (B,S) all-reduces.
    Returns (mean_loss, token_count).
    """
    v = logits.shape[-1]
    valid_v = None
    if vocab_size < v:
        valid_v = (jnp.arange(v) < vocab_size)
    # stable logsumexp with fused fp32 accumulation
    neg = jnp.asarray(NEG_BIG, logits.dtype)
    masked = logits if valid_v is None else jnp.where(valid_v, logits, neg)
    m = jnp.max(masked.astype(jnp.float32), axis=-1)
    e = jnp.exp(masked.astype(jnp.float32) - m[..., None])
    lse = m + jnp.log(jnp.sum(e, axis=-1))
    label_onehot = jax.nn.one_hot(jnp.maximum(labels, 0), v, dtype=logits.dtype)
    picked = jnp.einsum("...v,...v->...", logits, label_onehot,
                        preferred_element_type=jnp.float32)
    nll = lse - picked
    if z_weight:
        nll = nll + z_weight * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    total = jnp.sum(nll * mask)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    return total / count, count
