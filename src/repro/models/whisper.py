"""Whisper-style encoder/decoder backbone (audio family).

Per the task spec the conv/mel frontend is a *stub*: ``input_specs()``
supplies precomputed frame embeddings ``frames: (B, enc_seq, d_model)``.
The encoder is a stack of non-causal attention blocks (scanned); the decoder
is the shared ``transformer`` stack with cross-attention enabled.

The backbone dims follow the assignment (24L, d=1024, 16H/16KV, ff=4096,
vocab 51865→padded); norm/MLP/positional details follow this repo's unified
stack (RMSNorm/SwiGLU/RoPE) — noted in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_attn_block, init_attn_block
from .modules import ones_init, rms_norm, split
from .transformer import _maybe_remat, _stack_init


def init_encoder(key, cfg, dtype=jnp.float32):
    lkeys = jax.random.split(key, max(cfg.n_enc_layers, 1))
    return {
        "blocks": _stack_init(lambda k: init_attn_block(k, cfg, dtype), lkeys),
        "final_norm": ones_init((cfg.d_model,), ("embed",), dtype),
    }


def encode(params, batch, cfg, pcfg, constrain=lambda t, kind="residual": t,
           layer_constrain=lambda bp: bp):
    """frames (B, enc_seq, d_model) → encoder hidden states."""
    enc = params["encoder"]
    x = constrain(batch["frames"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, bp):
        h, = carry
        bp = layer_constrain(bp)

        def run(h, bp):
            hh, _, _, _ = apply_attn_block(bp, cfg, pcfg, h,
                                           positions=positions, mode="train",
                                           causal=False, constrain=constrain)
            return hh
        run = _maybe_remat(run, pcfg)
        return (run(h, bp),), None

    if pcfg.scan_layers:
        (x,), _ = jax.lax.scan(body, (x,), enc["blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            (x,), _ = body((x,), jax.tree.map(lambda a: a[i], enc["blocks"]))
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)
