"""Transformer block layers shared by all architectures.

Each ``init_*`` returns a Box-tree (see ``modules``); each ``apply_*``
consumes the *value-only* tree (after ``modules.split``).  Blocks are
polymorphic over execution mode:

  * ``train``   — full-sequence causal forward, no cache.
  * ``prefill`` — full-sequence forward that also emits the KV cache laid
                  out into a fixed ``cache_len`` buffer.
  * ``decode``  — single-token forward reading/updating the cache.

The KV cache for a layer is ``(k, v)`` of shape (B, cache_len, Hkv, hd); a
sliding-window layer uses a rolling buffer of size ``window``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (apply_rope, chunked_attention, decode_attention,
                        dense_attention)
from .modules import dense_init, ones_init, rms_norm, swiglu, zeros_init
from .moe import init_moe, moe_ffn


class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, S_cache, Hkv, hd)
    v: jnp.ndarray


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(key, cfg, dtype=jnp.float32, cross: bool = False):
    """QKV/O projections in *flattened* (d, H·hd) layout.

    H·hd is divisible by the 16-way TP degree for every assigned arch even
    when H itself is not (llava 56H, qwen1.5 20H, arctic 56H) — jit input
    shardings require exact divisibility; the per-head structure only
    appears on activations, where uneven GSPMD sharding is permitted.
    """
    d, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, Hq * hd), ("embed", "qkv"), dtype=dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), ("embed", "kv"), dtype=dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), ("embed", "kv"), dtype=dtype),
        "wo": dense_init(ks[3], (Hq * hd, d), ("qkv", "embed"),
                         scale=1.0 / (d ** 0.5 * (2 * max(cfg.num_layers, 1)) ** 0.5),
                         dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((Hq * hd,), ("qkv",), dtype)
        p["bk"] = zeros_init((Hkv * hd,), ("kv",), dtype)
        p["bv"] = zeros_init((Hkv * hd,), ("kv",), dtype)
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), ("null",), dtype)
        p["k_norm"] = ones_init((hd,), ("null",), dtype)
    return p


def _project_qkv(p, cfg, x, kv_x, positions, *, use_rope: bool):
    B, S = x.shape[:2]
    Sk = kv_x.shape[1]
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, Sk, Hkv, hd)
    v = v.reshape(B, Sk, Hkv, hd)
    if "q_norm" in p:  # qwen3 qk-norm (per-head RMS)
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and cfg.rope != "none":
        kv_positions = positions if kv_x is x else \
            jnp.broadcast_to(jnp.arange(kv_x.shape[1])[None], kv_x.shape[:2])
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope)
        k = apply_rope(k, kv_positions, cfg.rope_theta, cfg.rope)
    return q, k, v


def apply_attention(p, cfg, pcfg, x, *, positions, mode: str = "train",
                    cache: Optional[KVCache] = None, cache_index=None,
                    cache_len: Optional[int] = None, kv_x=None,
                    causal: bool = True, window: int = 0,
                    constrain=lambda t, kind="residual": t,
                    ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Unified attention. Returns (out, new_cache)."""
    B, S, d = x.shape
    cross = kv_x is not None
    src = kv_x if cross else x
    new_cache = cache

    if mode == "decode" and cross:
        # cross-attention at decode reads the static (precomputed) cache
        q = x @ p["wq"]
        if "bq" in p:
            q = q + p["bq"]
        q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
        if "q_norm" in p:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        out = decode_attention(q, cache.k, cache.v,
                               jnp.full((B,), cache.k.shape[1], jnp.int32))
        return out.reshape(B, S, -1) @ p["wo"], cache

    q, k, v = _project_qkv(p, cfg, x, src, positions, use_rope=not cross)
    q = constrain(q, "q_heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")

    if mode == "decode":
        # write new K/V at cache_index (rolling slot for SWA buffers)
        S_cache = cache.k.shape[1]
        write_pos = cache_index % S_cache if window else cache_index
        kc = _write_cache(cache.k, k, write_pos)
        vc = _write_cache(cache.v, v, write_pos)
        valid = jnp.minimum(cache_index + 1, S_cache)
        out = decode_attention(q, kc, vc, jnp.broadcast_to(valid, (B,)))
        new_cache = KVCache(kc, vc)
    else:
        if cross:
            out = chunked_attention(q, k, v, causal=False,
                                    q_chunk=pcfg.attn_q_chunk,
                                    k_chunk=pcfg.attn_k_chunk)
        elif S <= 512:
            out = dense_attention(q, k, v, causal=causal, window=window)
        else:
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    q_chunk=pcfg.attn_q_chunk,
                                    k_chunk=pcfg.attn_k_chunk)
        if mode == "prefill":
            new_cache = _build_cache(k, v,
                                     cache_len=cache_len or k.shape[1],
                                     window=window)
    B2, S2 = out.shape[:2]
    return out.reshape(B2, S2, -1) @ p["wo"], new_cache


def _write_cache(buf, kv, pos):
    """dynamic_update_slice along seq dim (pos may be traced)."""
    return jax.lax.dynamic_update_slice(
        buf, kv.astype(buf.dtype),
        (0, pos) + (0,) * (buf.ndim - 2))


def _build_cache(k, v, cache_len: int, window: int = 0) -> KVCache:
    """Lay prefill K/V into a fixed-size cache buffer.

    For sliding-window layers the buffer holds only the last ``window``
    positions (rolling semantics start aligned so that position p maps to
    slot p % window)."""
    B, S, H, hd = k.shape
    if window and window < cache_len:
        cache_len = window
    if S >= cache_len:
        # keep the last cache_len positions, aligned to their rolling slots
        start = S - cache_len
        ks, vs = k[:, start:], v[:, start:]
        if window:
            shift = start % cache_len
            ks = jnp.roll(ks, shift, axis=1)
            vs = jnp.roll(vs, shift, axis=1)
        return KVCache(ks, vs)
    pad = cache_len - S
    return KVCache(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                   jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, cfg, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), ("embed", "mlp"), dtype=dtype),
        "w_up": dense_init(ks[1], (d, f), ("embed", "mlp"), dtype=dtype),
        "w_down": dense_init(ks[2], (f, d), ("mlp", "embed"),
                             scale=1.0 / (f ** 0.5 * (2 * max(cfg.num_layers, 1)) ** 0.5),
                             dtype=dtype),
    }


def apply_mlp(p, x):
    return swiglu(x @ p["w_gate"], x @ p["w_up"]) @ p["w_down"]


# --------------------------------------------------------------------------
# full block (pre-norm residual)
# --------------------------------------------------------------------------

def init_attn_block(key, cfg, dtype=jnp.float32, with_cross: bool = False,
                    ffn: str = "mlp"):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": ones_init((cfg.d_model,), ("embed",), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": ones_init((cfg.d_model,), ("embed",), dtype),
    }
    if with_cross:
        p["ln_x"] = ones_init((cfg.d_model,), ("embed",), dtype)
        p["cross"] = init_attention(ks[1], cfg, dtype, cross=True)
    if ffn == "moe":
        p["ffn"] = init_moe(ks[2], cfg, dtype)
    else:
        p["ffn"] = init_mlp(ks[2], cfg, dtype)
    return p


def apply_attn_block(p, cfg, pcfg, x, *, positions, mode="train",
                     cache: Optional[KVCache] = None, cache_index=None,
                     cache_len: Optional[int] = None,
                     cross_cache: Optional[KVCache] = None, enc_out=None,
                     causal=True, constrain=lambda t, kind="residual": t):
    """Returns (x, new_cache, new_cross_cache, aux_loss)."""
    window = cfg.sliding_window
    h, new_cache = apply_attention(
        p["attn"], cfg, pcfg, rms_norm(x, p["ln1"], cfg.norm_eps),
        positions=positions, mode=mode, cache=cache, cache_index=cache_index,
        cache_len=cache_len, causal=causal, window=window,
        constrain=constrain)
    x = constrain(x + h)
    new_cross = cross_cache
    if "cross" in p:
        xq = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            # static cross cache, built at prefill
            hx, _ = apply_attention(p["cross"], cfg, pcfg, xq,
                                    positions=positions, mode="decode",
                                    cache=cross_cache, kv_x=x,
                                    constrain=constrain)
        else:
            hx, new_cross = apply_attention(
                p["cross"], cfg, pcfg, xq, positions=positions, mode=mode,
                cache_len=enc_out.shape[1], kv_x=enc_out, causal=False,
                constrain=constrain)
        x = constrain(x + hx)
    aux = jnp.zeros((), jnp.float32)
    y = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts and "router" in p["ffn"]:
        ff, aux = moe_ffn(p["ffn"], y, cfg, constrain=constrain)
    else:
        ff = apply_mlp(p["ffn"], y)
    x = constrain(x + ff)
    return x, new_cache, new_cross, aux
