"""Mixture-of-Experts FFN (mixtral / arctic style).

Dispatch is *sort-based* (dropless-up-to-capacity, MegaBlocks-lite): tokens
are argsorted by expert id, each token's position inside its expert bucket
falls out of the sorted order, and tokens are gathered/scattered through
dense (E, C, d) buffers.  Everything is static-shaped and jit/pjit friendly.

**Grouped for the partitioner** (GShard-style): tokens are reshaped to
(G, T_g, d) groups with G sharded over the data axes, and the whole
route→dispatch→combine pipeline is ``vmap``-ed over G.  Batched scatters /
gathers whose batch dim is sharded stay local to the shard — without the
grouping, GSPMD replicated the (tokens·k·cf, d) bucket tensor on every
device (measured: +170 GiB/device on arctic-480b train).

Parallelism modes
-----------------
* **TP (default):** expert weights shard over ``model`` on the expert dim
  when divisible (arctic 128/16) else on the FFN hidden dim (mixtral 8e).
* **EP (optional, ``moe_ep_axis``):** shard_map all-to-all dispatch across
  the data axis — the paper's All-to-All collective pattern (Sec. II-C);
  exercised by tests/benchmarks.

The router aux loss follows Switch Transformer (fraction·probability).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .modules import Box, dense_init, swiglu


def init_moe(key, cfg, dtype=jnp.float32):
    """Expert-parallel SwiGLU FFN params (+ optional arctic dense residual)."""
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, E), ("embed", "expert_router"),
                             scale=0.02, dtype=dtype),
        "w_gate": dense_init(ks[1], (E, d, f), ("expert", "embed", "mlp"), dtype=dtype),
        "w_up": dense_init(ks[2], (E, d, f), ("expert", "embed", "mlp"), dtype=dtype),
        "w_down": dense_init(ks[3], (E, f, d), ("expert", "mlp", "embed"), dtype=dtype),
    }
    if cfg.moe_dense_ff:
        # dedicated logical axis: the dense-residual FFN must be Megatron
        # column/row TP-sharded with an UNSHARDED contraction dim — FSDP on
        # d here produced ~800 GiB/dev of partial-sum all-reduce (measured,
        # arctic-480b; see EXPERIMENTS.md §Perf)
        kd = jax.random.split(ks[4], 3)
        params["dense"] = {
            "w_gate": dense_init(kd[0], (d, cfg.moe_dense_ff),
                                 ("embed_unsharded", "mlp_dense"), dtype=dtype),
            "w_up": dense_init(kd[1], (d, cfg.moe_dense_ff),
                               ("embed_unsharded", "mlp_dense"), dtype=dtype),
            "w_down": dense_init(kd[2], (cfg.moe_dense_ff, d),
                                 ("mlp_dense", "embed_unsharded"), dtype=dtype),
        }
    return params


def _route(x2d, router_w, n_experts: int, top_k: int):
    """(T,d) tokens → (expert_idx (T,k), combine_w (T,k), aux scalar)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    combine_w, expert_idx = jax.lax.top_k(probs, top_k)
    combine_w = combine_w / jnp.sum(combine_w, axis=-1, keepdims=True)
    T = x2d.shape[0]
    frac_tokens = jnp.zeros(n_experts).at[expert_idx.reshape(-1)].add(1.0) / (T * top_k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return expert_idx, combine_w, aux


def _dispatch_indices(expert_idx, n_experts: int, capacity: int):
    """Sort-based bucket slots.  expert_idx: (T, k) → slot (T, k) in the
    flat (E·C) buffer, or -1 when the bucket overflowed (token dropped)."""
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(T * k) - first[sorted_e]
    pos = jnp.zeros(T * k, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity + pos, -1)
    return slot.reshape(T, k)


def _group_dispatch(x_g, router_w, E: int, k: int, capacity: int):
    """Per-group: (T_g, d) → dispatched buckets (E, C, d) + combine info."""
    expert_idx, combine_w, aux = _route(x_g, router_w, E, k)
    slot = _dispatch_indices(expert_idx, E, capacity)            # (T,k)
    flat_slot = slot.reshape(-1)
    src = jnp.repeat(x_g, k, axis=0)
    scatter_idx = jnp.where(flat_slot >= 0, flat_slot, E * capacity)
    buckets = jnp.zeros((E * capacity, x_g.shape[-1]), x_g.dtype)
    buckets = buckets.at[scatter_idx].set(src, mode="drop")
    return buckets.reshape(E, capacity, x_g.shape[-1]), flat_slot, combine_w, aux


def _group_combine(y_e, flat_slot, combine_w, T: int, k: int):
    """Per-group inverse: (E·C, d) expert outputs → (T, d) tokens."""
    safe = jnp.maximum(flat_slot, 0)
    w = jnp.where(flat_slot >= 0, combine_w.reshape(-1), 0.0)
    gathered = y_e[safe] * w[:, None].astype(y_e.dtype)
    return jnp.sum(gathered.reshape(T, k, -1), axis=1)


def moe_ffn(params, x, cfg, *, capacity_factor: float | None = None,
            n_groups: int | None = None,
            constrain=lambda t, kind="residual": t):
    """Apply the MoE FFN.  x: (B, S, d) → ((B, S, d), aux scalar).

    ``constrain`` pins the (G, E, C, d) bucket tensor's sharding (G over
    data, E over model when experts are TP-sharded) so the dispatch→expert
    boundary reshards with one all-to-all-class transfer instead of
    gathering every token onto every expert shard."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    G = n_groups or B                      # per-sequence groups by default
    T_g = B * S // G
    xg = x.reshape(G, T_g, d)
    capacity = max(int(math.ceil(T_g * k * cf / E)), 4)
    capacity = -(-capacity // 4) * 4

    buckets, flat_slot, combine_w, aux = jax.vmap(
        lambda t: _group_dispatch(t, params["router"], E, k, capacity))(xg)
    # buckets: (G, E, C, d) — G carries the data sharding end to end
    buckets = constrain(buckets, "moe_buckets")

    g = jnp.einsum("gecd,edf->gecf", buckets, _v(params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", buckets, _v(params["w_up"]))
    h = swiglu(g, u)
    y = jnp.einsum("gecf,efd->gecd", h, _v(params["w_down"]))
    y = constrain(y, "moe_buckets")

    out = jax.vmap(lambda ye, fs, cw: _group_combine(
        ye.reshape(E * capacity, d), fs, cw, T_g, k))(y, flat_slot, combine_w)
    out = out.reshape(B, S, d)

    if cfg.moe_dense_ff:
        dn = params["dense"]
        x2d = x.reshape(-1, d)
        dense = swiglu(x2d @ _v(dn["w_gate"]), x2d @ _v(dn["w_up"])) @ _v(dn["w_down"])
        out = out + dense.reshape(B, S, d)
    return out, jnp.mean(aux)


def moe_ffn_ep(params, x, cfg, *, mesh, ep_axis: str,
               capacity_factor: float | None = None):
    """Expert-parallel MoE FFN: explicit shard_map All-to-All dispatch.

    The paper's All-to-All collective pattern (Sec. II-C), written out
    rather than left to GSPMD: experts shard over ``ep_axis`` (a data
    axis of ``mesh``), each rank routes its local tokens and builds full
    (E, C, d) dispatch buckets, a tiled ``jax.lax.all_to_all`` exchanges
    them so every rank holds only its E/n experts' buckets from all n
    ranks — shape (E/n, n·C, d), the Table-I shard-D/n unicast pattern —
    the expert FFN runs on the local weight shard, and the inverse
    all-to-all returns outputs for the local combine.

    Routing, capacity and combine math are shared with :func:`moe_ffn`,
    so the result matches ``moe_ffn(..., n_groups=n)`` (one dispatch
    group per EP rank) up to float reduction order — pinned by
    tests/test_multidevice.py against the dense-gather reference.

    ``x`` must shard its batch dim over ``ep_axis`` (n | B) and expert
    weights their leading E dim (E % n == 0).
    """
    from repro.parallel.compat import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    n = mesh.shape[ep_axis]
    if B % n or E % n:
        raise ValueError(f"moe_ffn_ep: batch {B} and n_experts {E} must "
                         f"both divide over ep_axis {ep_axis!r} (size {n})")
    T_l = B * S // n                       # tokens per EP rank
    capacity = max(int(math.ceil(T_l * k * cf / E)), 4)
    capacity = -(-capacity // 4) * 4

    def shard_fn(router_w, wg, wu, wd, x_l):
        T = x_l.shape[0] * x_l.shape[1]
        x2d = x_l.reshape(T, d)
        buckets, flat_slot, combine_w, aux = _group_dispatch(
            x2d, router_w, E, k, capacity)
        # dispatch A2A: keep E/n experts, gather every rank's C slots
        b = jax.lax.all_to_all(buckets, ep_axis, split_axis=0,
                               concat_axis=1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", b, wg)
        u = jnp.einsum("ecd,edf->ecf", b, wu)
        h = swiglu(g, u)
        y = jnp.einsum("ecf,efd->ecd", h, wd)
        # combine A2A: the exact inverse exchange
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1,
                               concat_axis=0, tiled=True)
        out = _group_combine(y.reshape(E * capacity, d), flat_slot,
                             combine_w, T, k)
        return out.reshape(x_l.shape), jax.lax.pmean(aux, ep_axis)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P(ep_axis), P(ep_axis), P(ep_axis),
                             P(ep_axis)),
                   out_specs=(P(ep_axis), P()),
                   check_vma=False)
    out, aux = fn(_v(params["router"]), _v(params["w_gate"]),
                  _v(params["w_up"]), _v(params["w_down"]), x)

    if cfg.moe_dense_ff:
        dn = params["dense"]
        x2d = x.reshape(-1, d)
        dense = swiglu(x2d @ _v(dn["w_gate"]),
                       x2d @ _v(dn["w_up"])) @ _v(dn["w_down"])
        out = out + dense.reshape(B, S, d)
    return out, aux


def _v(p):
    return p.value if isinstance(p, Box) else p
