"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

The SSD layer computes, per head h and state size N:

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T        (N x hd state)
    y_t = C_t^T h_t  (+ D_h * x_t)

Training/prefill uses the *chunked* SSD algorithm: the sequence is split
into chunks of Q tokens; within a chunk the output is a masked quadratic
form (the "attention-like" dual), across chunks the state is carried by a
scan with scalar per-head decays.  This is O(S·Q) compute/memory instead of
O(S²) and maps directly onto the MXU — the Pallas kernel in
``repro.kernels.ssd_scan`` implements the intra-chunk part with VMEM tiling;
this file is its jnp oracle and the production fallback.

Decode maintains (state, conv buffer) and performs the O(1) recurrence.

TP note: heads are independent except through the channel-mixing in/out
projections, so the layer shards over the ``model`` axis on heads/d_inner
('ssm_in' logical axis), exactly like attention head-TP.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .modules import AxisNames, Box, dense_init, zeros_init, ones_init, rms_norm


class SSMState(NamedTuple):
    """Per-layer decode state."""
    h: jnp.ndarray        # (B, H, hd, N) SSM state
    conv: jnp.ndarray     # (B, d_conv-1, conv_dim) conv lag buffer


def init_mamba2(key, cfg, dtype=jnp.float32):
    d, di = cfg.d_model, cfg.d_inner
    H, hd, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 6)
    # in_proj emits [z (gate), x, B, C, dt]
    d_in_proj = 2 * di + 2 * G * N + H
    params = {
        "in_proj": dense_init(ks[0], (d, d_in_proj), ("embed", "ssm_in"), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), ("null", "ssm_in"),
                             scale=1.0 / math.sqrt(cfg.ssm_conv), dtype=dtype),
        "conv_b": zeros_init((conv_dim,), ("ssm_in",), dtype),
        "a_log": Box(jnp.log(jnp.linspace(1.0, 16.0, H, dtype=dtype)), AxisNames("ssm_head")),
        "dt_bias": Box(jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), dtype) *
                    (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)))), AxisNames("ssm_head")),
        "d_skip": ones_init((H,), ("ssm_head",), dtype),
        "norm_g": ones_init((di,), ("ssm_in",), dtype),
        "out_proj": dense_init(ks[3], (di, d), ("ssm_in", "embed"), dtype=dtype),
    }
    return params


def _split_in_proj(zxbcdt, cfg):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, lag=None):
    """Depthwise causal conv1d.  xBC: (B,S,C); conv_w: (K,C).

    ``lag``: optional (B, K-1, C) left-context (decode buffer). Returns
    (out, new_lag)."""
    K = conv_w.shape[0]
    B, S, C = xBC.shape
    if lag is None:
        lag = jnp.zeros((B, K - 1, C), xBC.dtype)
    xfull = jnp.concatenate([lag, xBC], axis=1)               # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + xfull[:, i:i + S].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    out = jax.nn.silu(out + conv_b.astype(jnp.float32)).astype(xBC.dtype)
    new_lag = xfull[:, S:]
    return out, new_lag


def _segsum(log_a):
    """(..., Q) → (..., Q, Q) lower-triangular cumulative log-decay:
    segsum[i, j] = sum_{k=j+1..i} log_a[k] for i >= j, -inf otherwise."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                # i,j → cs_i - cs_j
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bmat, Cmat, *, chunk: int = 128,
                initial_state=None, return_state: bool = False):
    """Chunked SSD scan.

    x:    (B, S, H, hd)   — per-head inputs
    dt:   (B, S, H)       — positive step sizes (softplus already applied)
    A:    (H,)            — negative per-head decay rates
    Bmat: (B, S, G, N);  Cmat: (B, S, G, N) with H % G == 0
    Returns y: (B, S, H, hd) (+ final state (B,H,hd,N) if requested).
    """
    Bsz, S, H, hd = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(Bsz, nc, Q, H, hd)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bmat.reshape(Bsz, nc, Q, G, N)
    Cc = Cmat.reshape(Bsz, nc, Q, G, N)

    dA = dtc * A.astype(jnp.float32)[None, None, None, :]     # (B,nc,Q,H) ≤ 0
    seg = _segsum(jnp.moveaxis(dA, -1, -2))                   # (B,nc,H,Q,Q)

    # ---- intra-chunk (quadratic dual) -------------------------------------
    Bh = jnp.repeat(Bc, rep, axis=3)                          # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh,
                        preferred_element_type=jnp.float32)
    L = jnp.exp(seg)                                          # (B,nc,H,Q,Q)
    M = scores * L * jnp.moveaxis(dtc, -1, -2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhd->bcqhd", M.astype(x.dtype), xc)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(jnp.cumsum(dA, axis=2)[:, :, -1:, :] -
                           jnp.cumsum(dA, axis=2))            # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhd->bchdn", Bh.astype(jnp.float32),
                        dtc * decay_to_end, xc.astype(jnp.float32))
    # (B,nc,H,hd,N) fp32
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))                # (B,nc,H)

    # ---- inter-chunk scan (associative, log-depth) --------------------------
    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, hd, N), states.dtype)

    def combine(a, b):
        (da, sa), (db, sb) = a, b
        return (da * db, sa * db[..., None, None] + sb)

    decays = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,B,H)
    sts = jnp.moveaxis(states, 1, 0)                          # (nc,B,H,hd,N)
    # prepend initial state as a chunk with decay 1
    decays = jnp.concatenate([jnp.ones_like(decays[:1]), decays], axis=0)
    sts = jnp.concatenate([initial_state[None].astype(sts.dtype), sts], axis=0)
    acc_decay, acc_state = jax.lax.associative_scan(combine, (decays, sts), axis=0)
    prev_states = acc_state[:-1]                              # state entering chunk c
    final_state = acc_state[-1]

    # ---- inter-chunk contribution ------------------------------------------
    in_decay = jnp.exp(jnp.cumsum(dA, axis=2))                # decay from chunk start
    y_inter = jnp.einsum("bcqhn,bchdn,bcqh->bcqhd", Ch,
                         jnp.moveaxis(prev_states, 0, 1).astype(jnp.float32),
                         in_decay).astype(x.dtype)

    y = (y_intra.astype(jnp.float32) + y_inter.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(Bsz, nc * Q, H, hd)[:, :S]
    if return_state:
        return y, final_state
    return y


def ssd_reference(x, dt, A, Bmat, Cmat, initial_state=None, return_state=False):
    """Sequential per-token recurrence — the bit-exact oracle for tests."""
    Bsz, S, H, hd = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    h0 = (jnp.zeros((Bsz, H, hd, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                                 # (B,H,hd),(B,H),(B,G,N),(B,G,N)
        Bh = jnp.repeat(Bt, rep, axis=1)
        Ch = jnp.repeat(Ct, rep, axis=1)
        decay = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32))
        upd = jnp.einsum("bh,bhd,bhn->bhdn", dtt.astype(jnp.float32),
                         xt.astype(jnp.float32), Bh.astype(jnp.float32))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhdn->bhd", Ch.astype(jnp.float32), h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if return_state:
        return y, hT
    return y


def mamba2_forward(params, u, cfg, *, chunk: int = 128,
                   state: SSMState | None = None, return_state: bool = False):
    """Full Mamba2 mixer.  u: (B, S, d_model) → (B, S, d_model)."""
    B, S, d = u.shape
    H, hd, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_inner

    zxbcdt = u @ params["in_proj"]
    z, xBC, dt = _split_in_proj(zxbcdt, cfg)
    lag = state.conv if state is not None else None
    xBC, new_lag = _causal_conv(xBC, params["conv_w"], params["conv_b"], lag)
    x = xBC[..., :di].reshape(B, S, H, hd)
    Bmat = xBC[..., di:di + G * N].reshape(B, S, G, N)
    Cmat = xBC[..., di + G * N:].reshape(B, S, G, N)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,S,H)

    h0 = state.h if state is not None else None
    if S == 1 and state is not None:
        # O(1) decode recurrence
        decay = jnp.exp(dt[:, 0] * A)                          # (B,H)
        Bh = jnp.repeat(Bmat[:, 0], H // G, axis=1)
        Ch = jnp.repeat(Cmat[:, 0], H // G, axis=1)
        upd = jnp.einsum("bh,bhd,bhn->bhdn", dt[:, 0],
                         x[:, 0].astype(jnp.float32), Bh.astype(jnp.float32))
        h = state.h.astype(jnp.float32) * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhdn->bhd", Ch.astype(jnp.float32), h)[:, None]
        y = y.astype(u.dtype)
        hT = h
    else:
        y, hT = ssd_chunked(x, dt, A, Bmat, Cmat, chunk=chunk,
                            initial_state=h0, return_state=True)

    y = y + x * params["d_skip"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2's norm-before-out)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_g"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, SSMState(h=hT, conv=new_lag)
    return out


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    H, hd, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * N
    return SSMState(
        h=jnp.zeros((batch, H, hd, N), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )
