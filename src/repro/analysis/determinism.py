"""DETERMINISM — goldens and CSVs must be byte-stable across processes.

The CI gates diff sweep CSVs, Pareto fronts and autostrategy decisions
bit-for-bit against committed goldens (PRs 3–6); three classes of
nondeterminism can break that without any cost-model change:

D1  Unseeded RNG: module-level ``random.*`` draws, no-arg
    ``random.Random()`` / ``np.random.default_rng()``, any legacy
    ``np.random.<fn>`` (global-state API), and ``np.random.seed`` (mutates
    shared state out from under other callers).  Checked across
    ``src/repro`` + ``examples`` + ``benchmarks``.

D2  Wall-clock reads inside ``src/repro/core``: ``time.time()`` /
    ``perf_counter()`` / ``monotonic()`` / ``datetime.now()``.  The core
    cost model is a pure function of its inputs; timing instrumentation
    that genuinely never feeds a golden (e.g. ``sweep_seconds``) carries
    an explicit ``# repro: ignore[DETERMINISM]``.

D3  Iterating a ``set`` (literal, ``set(...)``/``frozenset(...)`` call or
    set comprehension) in a ``for`` statement or comprehension: with
    ``PYTHONHASHSEED`` randomization, string-set iteration order differs
    *per process*, so any derived row order is golden-hostile.  Dict
    iteration is insertion-ordered and therefore fine — ``dict.fromkeys``
    is the sanctioned order-preserving dedup.  Order-insensitive
    reductions (``sorted(set(...))``, ``max(set(...))``) are fine too:
    the rule only fires when the set is the loop iterable itself.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .engine import Finding, Repo

RULE = "DETERMINISM"

CORE_PREFIX = "src/repro/core"

# global-state draws on the stdlib `random` module
_RANDOM_FNS = {
    "random", "randint", "randrange", "uniform", "gauss", "normalvariate",
    "choice", "choices", "sample", "shuffle", "betavariate", "expovariate",
    "seed", "getrandbits", "triangular",
}
_CLOCK_FNS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "process_time"), ("time", "time_ns"),
    ("time", "perf_counter_ns"), ("time", "monotonic_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}


def _dotted(node: ast.AST) -> Optional[List[str]]:
    """['np', 'random', 'rand'] for np.random.rand — None if not a plain
    dotted name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _check_calls(sf, in_core: bool, findings: List[Finding]) -> None:
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        parts = _dotted(node.func)
        if not parts:
            continue
        # ---- D1: RNG ------------------------------------------------
        if parts[0] == "random" and len(parts) == 2:
            if parts[1] in _RANDOM_FNS:
                findings.append(Finding(
                    RULE, sf.path, node.lineno,
                    f"random.{parts[1]}() draws from the unseeded global "
                    f"RNG — use random.Random(seed)"))
            elif parts[1] in ("Random", "SystemRandom") and not (
                    node.args or node.keywords):
                findings.append(Finding(
                    RULE, sf.path, node.lineno,
                    f"random.{parts[1]}() without a seed is "
                    f"OS-entropy-seeded — pass an explicit seed"))
        if len(parts) >= 2 and parts[0] in ("np", "numpy") \
                and parts[1] == "random" and len(parts) == 3:
            fn = parts[2]
            if fn == "default_rng":
                if not (node.args or node.keywords):
                    findings.append(Finding(
                        RULE, sf.path, node.lineno,
                        "np.random.default_rng() without a seed is "
                        "OS-entropy-seeded — pass an explicit seed"))
            else:
                findings.append(Finding(
                    RULE, sf.path, node.lineno,
                    f"np.random.{fn} uses numpy's global RNG state — use "
                    f"np.random.default_rng(seed)"))
        # ---- D2: wall clock in core ---------------------------------
        if in_core and len(parts) >= 2 and (
                parts[-2], parts[-1]) in _CLOCK_FNS:
            findings.append(Finding(
                RULE, sf.path, node.lineno,
                f"wall-clock read {'.'.join(parts)}() inside core/ — the "
                f"cost model must be a pure function of its inputs"))


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


def _check_set_iteration(sf, findings: List[Finding]) -> None:
    def flag(it: ast.AST) -> None:
        findings.append(Finding(
            RULE, sf.path, it.lineno,
            "iterating a set: hash order differs per process "
            "(PYTHONHASHSEED), so any derived row/golden order is "
            "unstable — sort it, or dedup with dict.fromkeys"))
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.For, ast.AsyncFor)) \
                and _is_set_expr(node.iter):
            flag(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                               ast.DictComp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    flag(gen.iter)


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for sf in repo.files():
        if sf.tree is None:
            continue
        in_core = sf.path.startswith(CORE_PREFIX)
        _check_calls(sf, in_core, findings)
        _check_set_iteration(sf, findings)
    return findings
