"""UNITS — seconds/bytes/bandwidth flow through dozens of fields and CSV
columns with nothing but naming discipline keeping them straight.

U1  Every float-typed dataclass field in ``src/repro/core`` must be
    *unit-resolvable*: its name carries a unit token (``_s``, ``_bytes``,
    ``_bw``, ``_gib``, ``_rate``, ``_flops``, ...), a dimensionless token
    (``_fraction``, ``_efficiency``, ``_ratio``, ...), or the line carries
    an explicit ``# repro: unit[...]`` declaration.  The declaration form
    exists for names that are API-frozen — ``Breakdown.compute`` is a
    golden/as_dict key and ``ClusterSpec.inter_wafer_latency`` is a
    public kwarg, so they cannot grow a suffix without breaking parity
    goldens; the comment makes the unit machine-readable instead.

U2  CSV header tokens (module-level ``*CSV_HEADER*`` string constants in
    core) that contain a physical stem (``time``, ``latency``, ``bytes``,
    ``memory``...) must also carry a unit token — a ``decode_time``
    column would be flagged until it becomes ``decode_time_s``.

U3  ``+``/``-`` over two operands whose *names* resolve to different
    known units (``x_s + y_bytes``) is flagged — unit mixing must go
    through an explicit conversion expression (which breaks the naive
    name inference, by design).  ``*``/``/`` legitimately change units,
    so their results are treated as unknown.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from .engine import (Finding, Repo, annotation_text, dataclass_fields,
                     is_dataclass_def)

RULE = "UNITS"

CORE_PREFIX = "src/repro/core"

# name tokens that resolve a unit (suffix-or-component match)
UNIT_TOKENS = {
    "s": "s", "sec": "s", "secs": "s", "seconds": "s", "ms": "s",
    "us": "s", "ns": "s",
    "hours": "hours", "hrs": "hours",
    "bytes": "bytes", "byte": "bytes", "gib": "bytes", "gb": "bytes",
    "mb": "bytes", "kib": "bytes",
    "bw": "bw", "bps": "bw", "gbps": "bw",
    "rate": "rate", "hz": "rate", "rps": "rate",
    "flops": "flops", "tflops": "flops",
    "params": "count", "w": "power", "watts": "power", "mm2": "area",
}
# tokens that mark a field as deliberately dimensionless
DIMENSIONLESS_TOKENS = {
    "fraction", "frac", "ratio", "factor", "efficiency", "utilization",
    "util", "share", "slowdown", "speedup", "scale", "prob", "probability",
}
# stems that indicate a physical quantity in CSV column names (U2)
PHYSICAL_STEMS = {
    "time", "latency", "bytes", "bw", "memory", "mem", "hbm", "load",
    "bandwidth", "overhead", "duration", "elapsed",
}

FLOAT_ANNOTATIONS = {
    "float", "Optional[float]", "Tuple[float, ...]", "List[float]",
    "Sequence[float]",
}


def _tokens(name: str) -> List[str]:
    return [t for t in name.lower().split("_") if t]


def resolve_unit(name: str) -> Optional[str]:
    """Unit implied by a name, or None.  The *last* unit-bearing token
    wins (``act_bytes_per_sample`` → bytes; ``time_per_sample_s`` → s)."""
    toks = _tokens(name)
    for t in reversed(toks):
        if t in UNIT_TOKENS:
            return UNIT_TOKENS[t]
    if any(t in DIMENSIONLESS_TOKENS for t in toks):
        return "dimensionless"
    return None


def _is_float_annotation(text: str) -> bool:
    return text.replace(" ", "") in {a.replace(" ", "")
                                     for a in FLOAT_ANNOTATIONS}


def _check_fields(sf, findings: List[Finding]) -> None:
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.ClassDef) and is_dataclass_def(node)):
            continue
        for field in dataclass_fields(node):
            if not _is_float_annotation(annotation_text(field)):
                continue
            name = field.target.id  # type: ignore[union-attr]
            if resolve_unit(name) is not None:
                continue
            if sf.declared_unit(field.lineno) is not None:
                continue
            findings.append(Finding(
                RULE, sf.path, field.lineno,
                f"float field {node.name}.{name} has no unit suffix "
                f"(_s/_bytes/_bw/_gib/_rate/...), no dimensionless token "
                f"and no `# repro: unit[...]` declaration"))


def _check_csv_headers(sf, findings: List[Finding]) -> None:
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Assign):
            continue
        targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if not any("CSV_HEADER" in t for t in targets):
            continue
        try:
            value = ast.literal_eval(node.value)
        except (ValueError, SyntaxError):
            continue
        if not isinstance(value, str):
            continue
        for col in value.split(","):
            col = col.strip()
            toks = set(_tokens(col))
            if not toks & PHYSICAL_STEMS:
                continue
            if resolve_unit(col) is None and not sf.is_suppressed(
                    RULE, node.lineno):
                findings.append(Finding(
                    RULE, sf.path, node.lineno,
                    f"CSV column '{col}' ({targets[0]}) names a physical "
                    f"quantity but carries no unit token"))


class _MixVisitor(ast.NodeVisitor):
    """Flags Add/Sub whose operands resolve to different known units."""

    def __init__(self, sf, findings: List[Finding]):
        self.sf = sf
        self.findings = findings

    @staticmethod
    def _name_of(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Attribute):
            return node.attr
        return None

    def _unit_of(self, node: ast.AST) -> Optional[str]:
        name = self._name_of(node)
        if name is not None:
            # suffix semantics only: a bare `w` or `s` loop variable must
            # not be read as watts/seconds — require an actual `_unit`
            # suffix (≥ 2 name components) before trusting the inference
            if len(_tokens(name)) < 2:
                return None
            u = resolve_unit(name)
            return None if u == "dimensionless" else u
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            lu, ru = self._unit_of(node.left), self._unit_of(node.right)
            return lu or ru
        return None      # calls, subscripts, Mult/Div: unknown unit

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            lu, ru = self._unit_of(node.left), self._unit_of(node.right)
            if lu and ru and lu != ru:
                self.findings.append(Finding(
                    RULE, self.sf.path, node.lineno,
                    f"'{ast.unparse(node)}' adds/subtracts operands with "
                    f"different units ({lu} vs {ru}) — convert explicitly"))
        self.generic_visit(node)


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for sf in repo.files(CORE_PREFIX):
        if sf.tree is None:
            continue
        _check_fields(sf, findings)
        _check_csv_headers(sf, findings)
        _MixVisitor(sf, findings).visit(sf.tree)
    return findings
