"""DEPRECATION — the consolidated spec API (PR 6) is the only internal
construction surface.

``FabricSpec`` / ``ClusterSpec`` / ``StrategyDecision`` replaced ten
legacy ``Simulator`` kwargs and the bare positional strategy tuple; the
shims still work (with a ``DeprecationWarning``) so downstream users get
a deprecation window, but *internal* code — ``src/repro``, ``examples``,
``benchmarks`` — must not keep minting new call sites:

X1  ``Simulator(mesh_shape=..., n_wafers=..., ...)`` with any legacy
    kwarg.  The authoritative kwarg list is read from the
    ``_LEGACY_FABRIC_KW`` / ``_LEGACY_CLUSTER_KW`` tuples in
    ``core/simulator.py`` (falling back to the frozen PR-6 list when
    checking a tree that lacks the file), so retiring a shim there
    automatically retires the rule.

X2  Bare strategy tuples: a tuple literal passed as ``auto_strategy=``
    or assigned to an ``auto_strategy`` attribute — that slot takes a
    ``StrategyDecision`` (named fields, ``as_strategy()``), the 5-tuple
    is the legacy encoding.

X3  Legacy decision entry points (ISSUE 10): calls to the kwarg-sprawl
    ``choose_strategy(...)`` form — the typed front door is
    ``choose(DeploymentRequest(...))`` with an ``Objective`` carrying
    the mtbf/SLO parameters.  Like X1, the authoritative name list is
    read from ``_LEGACY_CHOOSE_FNS`` in ``core/autostrategy.py`` (with
    a frozen fallback), so retiring the shim retires the rule.

``core/simulator.py``, ``core/specs.py`` and ``core/autostrategy.py``
(the shim implementations and their spec twin) are exempt; tests are
outside the walk roots entirely — test shims exercising the deprecated
surface on purpose is exactly why the engine skips ``tests/``.
"""

from __future__ import annotations

import ast
from typing import List, Tuple

from .engine import Finding, Repo, string_tuple_assign

RULE = "DEPRECATION"

SIMULATOR = "src/repro/core/simulator.py"
AUTOSTRATEGY = "src/repro/core/autostrategy.py"
EXEMPT = (SIMULATOR, "src/repro/core/specs.py", AUTOSTRATEGY)

# frozen PR-6 shim list — used only when the checked tree has no
# core/simulator.py to read the live tuples from (fixture trees in tests)
FALLBACK_LEGACY_KW: Tuple[str, ...] = (
    "mesh_shape", "fred_shape", "n_io", "n_wafers", "inter_wafer_links",
    "inter_wafer_bw", "inter_wafer_latency", "inter_topology", "hierarchy")

# frozen ISSUE-10 shim list — same fallback contract for X3
FALLBACK_LEGACY_CHOOSE: Tuple[str, ...] = ("choose_strategy",)


def legacy_kwargs(repo: Repo) -> Tuple[str, ...]:
    sf = repo.file(SIMULATOR)
    if sf is not None and sf.tree is not None:
        fab = string_tuple_assign(sf.tree, "_LEGACY_FABRIC_KW") or ()
        clu = string_tuple_assign(sf.tree, "_LEGACY_CLUSTER_KW") or ()
        if fab or clu:
            return fab + clu
    return FALLBACK_LEGACY_KW


def legacy_choose_fns(repo: Repo) -> Tuple[str, ...]:
    sf = repo.file(AUTOSTRATEGY)
    if sf is not None and sf.tree is not None:
        fns = string_tuple_assign(sf.tree, "_LEGACY_CHOOSE_FNS") or ()
        if fns:
            return fns
    return FALLBACK_LEGACY_CHOOSE


def _is_simulator_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Name) and f.id == "Simulator") or \
        (isinstance(f, ast.Attribute) and f.attr == "Simulator")


def _called_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    legacy = set(legacy_kwargs(repo))
    legacy_choose = set(legacy_choose_fns(repo))
    for sf in repo.files():
        if sf.tree is None or sf.path in EXEMPT:
            continue
        for node in ast.walk(sf.tree):
            # ---- X3: legacy decision entry points --------------------
            if isinstance(node, ast.Call) and \
                    _called_name(node) in legacy_choose:
                findings.append(Finding(
                    RULE, sf.path, node.lineno,
                    f"{_called_name(node)}(...) is a deprecated shim — "
                    f"build a DeploymentRequest (+ Objective) in "
                    f"repro.core.specs and call choose(request)"))
            # ---- X1: legacy Simulator kwargs -------------------------
            if isinstance(node, ast.Call) and _is_simulator_call(node):
                for kw in node.keywords:
                    if kw.arg in legacy:
                        findings.append(Finding(
                            RULE, sf.path, node.lineno,
                            f"Simulator({kw.arg}=...) is a deprecated shim "
                            f"— pass spec=FabricSpec(...) / "
                            f"cluster_spec=ClusterSpec(...) instead"))
            # ---- X2: bare strategy tuples ----------------------------
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "auto_strategy" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        findings.append(Finding(
                            RULE, sf.path, node.lineno,
                            "bare tuple passed as auto_strategy — use "
                            "StrategyDecision(mp=..., dp=..., pp=..., "
                            "wafers=..., ...)"))
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, (ast.Tuple, ast.List)):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) \
                            and tgt.attr == "auto_strategy":
                        findings.append(Finding(
                            RULE, sf.path, node.lineno,
                            "bare tuple assigned to .auto_strategy — use "
                            "StrategyDecision"))
    return findings
