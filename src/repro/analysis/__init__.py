"""Static invariant checkers for the cost-model core (ISSUE 7).

The cost model's trustworthiness rests on contracts that used to be
enforced only by convention or by expensive dynamic gates:

* ``LAYERING``    — ``repro.core``/``repro.configs`` stay importable on the
                    JAX-free CI core lane (requirements-core.txt only),
                    directly or transitively; runtime packages never
                    import ``repro.analysis`` back.
* ``PARITY``      — every scalar ``Simulator`` axis (``Strategy`` /
                    ``Workload`` / ``Breakdown`` / ``FabricSpec`` /
                    ``ClusterSpec`` field) has a batched counterpart in
                    ``batch_engine.CandidateBatch`` / ``run_batch``, so a
                    new axis (e.g. the ROADMAP's ``ep``/``sp``) cannot
                    silently fall out of the bit-parity sweeps.
* ``UNITS``       — float dataclass fields and CSV header tokens carrying
                    physical quantities bear unit suffixes (``_s``,
                    ``_bytes``, ``_bw``, ...) or an explicit
                    ``# repro: unit[...]`` declaration; ``+``/``-`` over
                    operands with different known units is flagged.
* ``DETERMINISM`` — no unseeded RNG, no wall-clock reads inside ``core/``,
                    no iteration over hash-ordered ``set``s (goldens and
                    CSVs must be byte-stable across processes).
* ``DEPRECATION`` — no internal use of the ten legacy ``Simulator``
                    kwargs or bare strategy tuples now that
                    ``FabricSpec``/``ClusterSpec``/``StrategyDecision``
                    exist.

Pure stdlib (``ast`` + ``re``): this package must itself import cleanly
on the core lane, so it depends on nothing outside the standard library
— not even numpy.

Suppress a finding inline with ``# repro: ignore[RULE]`` (comma-list or
``*`` allowed) on the flagged line; declare a unit on a field whose name
is API-frozen with ``# repro: unit[s]``.  Grandfathered findings live in
``tests/goldens/analysis_baseline.json`` (regen with
``python -m repro.analysis --check --regen-baseline``); the committed
baseline is empty and should stay that way.
"""

from .engine import (ALL_RULES, Finding, Repo, load_baseline, run_checks,
                     write_baseline)

__all__ = ["ALL_RULES", "Finding", "Repo", "load_baseline", "run_checks",
           "write_baseline"]
