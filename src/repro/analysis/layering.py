"""LAYERING — the core cost model must stay importable on the JAX-free
CI core lane, and runtime packages must not depend back on the checkers.

Rule 1 (core lane): every module *transitively reachable* from
``repro.core`` / ``repro.configs`` (or ``repro.analysis`` itself) via
import edges may only import, at module level and unguarded, (a) the
stdlib, (b) packages named in ``requirements-core.txt``, or (c) other
``repro`` modules.  Two escape hatches are sanctioned because they are
exactly how the repo gates jax today: *function-level* imports (gated by
the call site — e.g. ``parallel/policy.py`` lazily importing
``core.autostrategy`` and vice versa) and module-level imports inside a
``try`` whose handler catches ``ImportError``/``ModuleNotFoundError``
(e.g. ``train/optim.py``'s jax import).  ``if TYPE_CHECKING:`` blocks
never execute and are skipped.

Rule 2 (no back-edges): ``repro.kernels`` / ``repro.parallel`` /
``repro.train`` / ``repro.serve`` must never import ``repro.analysis``
in any form — the checkers observe the runtime, not the other way round.

The allowed third-party set is **derived from requirements-core.txt**,
not hardcoded (ISSUE 7 satellite): if that file is missing or names no
packages, that is itself a finding.
"""

from __future__ import annotations

import ast
import dataclasses
import re
import sys
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import Finding, Repo, SourceFile

RULE = "LAYERING"

CORE_ROOT_PREFIXES = ("repro.core", "repro.configs", "repro.analysis")
NO_ANALYSIS_PREFIXES = ("repro.kernels", "repro.parallel", "repro.train",
                        "repro.serve")
REQUIREMENTS_CORE = "requirements-core.txt"

# requirement-name -> importable top package, for the names that differ
_DIST_TO_MODULE = {"pyyaml": "yaml", "pillow": "PIL", "msgpack": "msgpack"}

_REQ_NAME_RE = re.compile(r"^\s*([A-Za-z0-9_.\-]+)")


@dataclasses.dataclass(frozen=True)
class ImportEdge:
    line: int
    target: str            # dotted module the import resolves to
    lazy: bool             # inside a function body
    guarded: bool          # inside try/except ImportError
    typing_only: bool      # inside `if TYPE_CHECKING:`


def parse_requirements(text: str) -> Set[str]:
    """Top-level importable package names from a requirements file."""
    out: Set[str] = set()
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip()
        if not line or line.startswith("-"):
            continue
        m = _REQ_NAME_RE.match(line)
        if m:
            name = m.group(1).lower().replace("-", "_")
            out.add(_DIST_TO_MODULE.get(name, name))
    return out


def module_name(relpath: str) -> Optional[str]:
    """Dotted module name for a file under ``src/`` (None otherwise)."""
    if not relpath.startswith("src/"):
        return None
    parts = relpath[len("src/"):].removesuffix(".py").split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class _ImportCollector(ast.NodeVisitor):
    """Collects import edges with lazy/guarded/typing context."""

    def __init__(self, module: str, is_package: bool = False):
        self.module = module
        self.is_package = is_package
        self.edges: List[ImportEdge] = []
        self._depth = 0          # function nesting
        self._guard = 0          # try-with-ImportError-handler nesting
        self._typing = 0         # `if TYPE_CHECKING:` nesting

    # -- context tracking ---------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Try(self, node: ast.Try) -> None:
        catches_import_error = False
        for h in node.handlers:
            names: List[str] = []
            t = h.type
            for sub in ([t] if not isinstance(t, ast.Tuple)
                        else list(t.elts)) if t is not None else []:
                if isinstance(sub, ast.Name):
                    names.append(sub.id)
                elif isinstance(sub, ast.Attribute):
                    names.append(sub.attr)
            if t is None or any(n in ("ImportError", "ModuleNotFoundError",
                                      "Exception", "BaseException")
                                for n in names):
                catches_import_error = True
        if catches_import_error:
            self._guard += 1
            for stmt in node.body:
                self.visit(stmt)
            self._guard -= 1
        else:
            for stmt in node.body:
                self.visit(stmt)
        for part in (node.handlers, node.orelse, node.finalbody):
            for stmt in part:
                self.visit(stmt)

    def visit_If(self, node: ast.If) -> None:
        t = node.test
        is_typing = (isinstance(t, ast.Name) and t.id == "TYPE_CHECKING") \
            or (isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING")
        if is_typing:
            self._typing += 1
            for stmt in node.body:
                self.visit(stmt)
            self._typing -= 1
            for stmt in node.orelse:
                self.visit(stmt)
        else:
            self.generic_visit(node)

    # -- imports ------------------------------------------------------
    def _add(self, line: int, target: str) -> None:
        self.edges.append(ImportEdge(
            line=line, target=target, lazy=self._depth > 0,
            guarded=self._guard > 0, typing_only=self._typing > 0))

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._add(node.lineno, alias.name)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level:                     # relative: resolve against self
            base = self.module.split(".")
            # level 1 in module repro.core.sweep strips the leaf, giving
            # package repro.core; in a package __init__ it is the package
            # itself, so one fewer component is stripped
            drop = node.level - 1 if self.is_package else node.level
            base = base[:len(base) - drop] if drop else base
            prefix = ".".join(base)
            stem = f"{prefix}.{node.module}" if node.module else prefix
        else:
            stem = node.module or ""
        if not stem:
            return
        # `from pkg import name` may bind a submodule: record both the
        # package edge and candidate submodule edges (resolved later
        # against the module index — non-modules simply don't resolve).
        self._add(node.lineno, stem)
        for alias in node.names:
            if alias.name != "*":
                self._add(node.lineno, f"{stem}.{alias.name}")


def collect_imports(sf: SourceFile, module: str) -> List[ImportEdge]:
    if sf.tree is None:
        return []
    c = _ImportCollector(module, is_package=sf.path.endswith("__init__.py"))
    c.visit(sf.tree)
    return c.edges


def _stdlib_names() -> Set[str]:
    names = set(getattr(sys, "stdlib_module_names", ()))
    names.update(("typing_extensions",))   # vendored-or-absent; harmless
    return names


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []

    # -- allowed third-party set from requirements-core.txt -----------
    req = repo.file(REQUIREMENTS_CORE)
    if req is None:
        findings.append(Finding(
            RULE, REQUIREMENTS_CORE, 1,
            "requirements-core.txt is missing — the layering checker "
            "derives the allowed core-lane import set from it"))
        allowed_external: Set[str] = set()
    else:
        allowed_external = parse_requirements(req.text)
        if not allowed_external:
            findings.append(Finding(
                RULE, REQUIREMENTS_CORE, 1,
                "requirements-core.txt names no packages — the core-lane "
                "allowed import set would be empty"))
    allowed = _stdlib_names() | allowed_external

    # -- module index + import edges over src/repro --------------------
    modules: Dict[str, SourceFile] = {}
    for sf in repo.files("src/repro"):
        name = module_name(sf.path)
        if name:
            modules[name] = sf
    edges: Dict[str, List[ImportEdge]] = {
        name: collect_imports(sf, name) for name, sf in modules.items()}

    def resolve(target: str) -> Optional[str]:
        """Map an import target onto a repo module (longest prefix wins:
        `from repro.core.sweep import sweep` hits repro.core.sweep, the
        trailing function name just fails to resolve)."""
        parts = target.split(".")
        for i in range(len(parts), 0, -1):
            cand = ".".join(parts[:i])
            if cand in modules:
                return cand
        return None

    # -- rule 1: BFS reachability from the core roots ------------------
    roots = sorted(m for m in modules
                   if any(m == p or m.startswith(p + ".")
                          for p in CORE_ROOT_PREFIXES))
    # provenance: module -> (parent, via-line) for readable chains
    parent: Dict[str, Optional[str]] = {m: None for m in roots}
    queue = list(roots)
    while queue:
        mod = queue.pop(0)
        for e in edges.get(mod, []):
            if e.typing_only or e.lazy or e.guarded:
                # lazy/guarded edges are the sanctioned gating pattern:
                # they may *reach* jax at runtime but cannot break the
                # core-lane import, which is what this rule protects.
                continue
            tgt = resolve(e.target)
            if tgt is not None and tgt not in parent:
                parent[tgt] = mod
                queue.append(tgt)

    def chain(mod: str) -> str:
        hops = [mod]
        while parent.get(hops[-1]) is not None:
            hops.append(parent[hops[-1]])  # type: ignore[arg-type]
        return " <- ".join(hops)

    for mod in sorted(parent):
        sf = modules[mod]
        for e in edges.get(mod, []):
            if e.typing_only or e.lazy or e.guarded:
                continue
            top = e.target.split(".", 1)[0]
            if top == "repro" or top in allowed:
                continue
            # `from pkg import sub` records both pkg and pkg.sub edges;
            # only report the bare package once per line
            if "." in e.target and any(
                    o.line == e.line and o.target == top
                    for o in edges.get(mod, [])):
                continue
            findings.append(Finding(
                RULE, sf.path, e.line,
                f"module-level import of '{top}' outside the core-lane "
                f"allowed set (requirements-core.txt + stdlib) in a module "
                f"reachable from the core roots via {chain(mod)}"))

    # -- rule 2: runtime packages must not import repro.analysis -------
    for mod in sorted(modules):
        if not any(mod == p or mod.startswith(p + ".")
                   for p in NO_ANALYSIS_PREFIXES):
            continue
        seen_lines: Set[int] = set()
        for e in edges.get(mod, []):
            tgt = e.target
            if tgt == "repro.analysis" or tgt.startswith("repro.analysis."):
                # `from repro.analysis import X` records both the package
                # and candidate-submodule edges — one finding per line
                if e.line in seen_lines:
                    continue
                seen_lines.add(e.line)
                findings.append(Finding(
                    RULE, modules[mod].path, e.line,
                    f"'{mod}' imports '{tgt}' — runtime packages must not "
                    f"depend on the static checkers"))
    return findings
