"""CLI: ``python -m repro.analysis --check``.

Exits non-zero on any finding not grandfathered by the baseline.  Runs
on the CI core lane (pure stdlib — no numpy/jax needed).

Examples::

    python -m repro.analysis --check
    python -m repro.analysis --check --rules LAYERING,PARITY
    python -m repro.analysis --check --regen-baseline
    python -m repro.analysis --check --json artifacts/analysis_report.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import (ALL_RULES, json_report, load_baseline, run_checks,
                     split_baselined, write_baseline)

DEFAULT_BASELINE = "tests/goldens/analysis_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checkers for the cost-model core")
    ap.add_argument("--check", action="store_true",
                    help="run the checkers (the only mode; kept explicit "
                         "so CI invocations read as intent)")
    ap.add_argument("--root", default=".",
                    help="repository root to analyse (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help=f"grandfathered-findings file "
                         f"(default: {DEFAULT_BASELINE} under --root, "
                         f"if present)")
    ap.add_argument("--regen-baseline", action="store_true",
                    help="rewrite the baseline from current findings and "
                         "exit 0")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full JSON report here")
    ap.add_argument("--rules", default=None,
                    help=f"comma list of rules to run "
                         f"(default: all of {', '.join(ALL_RULES)})")
    args = ap.parse_args(argv)
    if not args.check and not args.regen_baseline:
        ap.error("nothing to do: pass --check")

    root = Path(args.root).resolve()
    rules = tuple(r.strip().upper() for r in args.rules.split(",")
                  if r.strip()) if args.rules else ALL_RULES
    baseline_path = Path(args.baseline) if args.baseline \
        else root / DEFAULT_BASELINE

    findings, suppressed = run_checks(root, rules)

    if args.regen_baseline:
        write_baseline(baseline_path, findings)
        print(f"baseline written: {baseline_path} "
              f"({len(findings)} grandfathered finding(s))")
        return 0

    baseline = load_baseline(baseline_path)
    new, grandfathered, stale = split_baselined(findings, baseline)

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(
            json_report(new, grandfathered, suppressed, stale, rules),
            indent=2, sort_keys=True) + "\n", encoding="utf-8")

    for f in new:
        print(f.render())
    if stale:
        print(f"note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} no longer fire(s) — "
              f"rerun with --regen-baseline to shrink the baseline",
              file=sys.stderr)
    counts = ", ".join(
        f"{r}={sum(1 for f in new if f.rule == r)}" for r in rules)
    print(f"repro.analysis: {len(new)} new finding(s) "
          f"[{counts}], {len(grandfathered)} grandfathered, "
          f"{len(suppressed)} suppressed", file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
