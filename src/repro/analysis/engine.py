"""Shared static-analysis engine: file walker, findings, suppressions,
baseline, reporters.

Checkers are functions ``check(repo: Repo) -> List[Finding]`` registered
in :data:`CHECKERS`.  The engine owns everything rule-independent:

* walking the repo (``src/repro`` + ``examples`` + ``benchmarks``) with a
  per-file parse cache,
* inline ``# repro: ignore[RULE]`` suppressions (matched on the finding's
  line; ``RULE`` may be a comma list or ``*``),
* the committed baseline of grandfathered findings, keyed on
  ``(rule, path, message)`` — deliberately *not* on line numbers, so an
  unrelated edit shifting a grandfathered finding by a few lines does not
  break the build,
* text (``path:line: RULE message``) and JSON reports.

Pure stdlib — no numpy, no jax (this runs on the CI core lane *before*
anything heavier is installed).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# rule names double as the tokens accepted by `# repro: ignore[...]`
ALL_RULES = ("LAYERING", "PARITY", "UNITS", "DETERMINISM", "DEPRECATION")

# directories walked relative to the repo root; tests are deliberately
# excluded (test shims may exercise deprecated surfaces on purpose) —
# individual checkers may still read specific test files as data.
DEFAULT_ROOTS = ("src/repro", "examples", "benchmarks")

_IGNORE_RE = re.compile(r"#\s*repro:\s*ignore\[([A-Za-z_*,\s]+)\]")
_UNIT_RE = re.compile(r"#\s*repro:\s*unit\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at ``path:line``.

    Baseline identity is ``(rule, path, message)`` — see :func:`baseline_key`.
    """
    rule: str
    path: str           # repo-root-relative, posix separators
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.message)


class SourceFile:
    """A parsed source file: text, lines, AST (or None on syntax error),
    per-line suppressions and unit declarations."""

    def __init__(self, relpath: str, text: str):
        self.path = relpath
        self.text = text
        self.lines = text.splitlines()
        try:
            self.tree: Optional[ast.AST] = ast.parse(text)
            self.parse_error: Optional[str] = None
        except SyntaxError as e:          # surfaced as an engine finding
            self.tree = None
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        # line number -> set of rule names (or "*") suppressed there
        self.suppressions: Dict[int, Set[str]] = {}
        # line number -> declared unit string from `# repro: unit[...]`
        self.unit_decls: Dict[int, str] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _IGNORE_RE.search(line)
            if m:
                rules = {tok.strip().upper()
                         for tok in m.group(1).split(",") if tok.strip()}
                self.suppressions[i] = rules
            m = _UNIT_RE.search(line)
            if m:
                self.unit_decls[i] = m.group(1).strip()

    def is_suppressed(self, rule: str, line: int) -> bool:
        rules = self.suppressions.get(line)
        return bool(rules) and (rule in rules or "*" in rules)

    def declared_unit(self, line: int) -> Optional[str]:
        return self.unit_decls.get(line)


class Repo:
    """Walk context over one repository root with a parse cache."""

    def __init__(self, root: Path, roots: Sequence[str] = DEFAULT_ROOTS):
        self.root = Path(root).resolve()
        self.roots = tuple(roots)
        self._cache: Dict[str, Optional[SourceFile]] = {}

    def file(self, relpath: str) -> Optional[SourceFile]:
        """Parse one file by repo-relative path (cached); None if absent."""
        relpath = str(relpath).replace("\\", "/")
        if relpath not in self._cache:
            p = self.root / relpath
            if p.is_file():
                self._cache[relpath] = SourceFile(
                    relpath, p.read_text(encoding="utf-8"))
            else:
                self._cache[relpath] = None
        return self._cache[relpath]

    def files(self, *prefixes: str) -> List[SourceFile]:
        """All ``.py`` files under the walk roots (sorted by path).  With
        ``prefixes``, only files whose relative path starts with one."""
        out: List[SourceFile] = []
        for rel in self._walk():
            if prefixes and not any(rel.startswith(p) for p in prefixes):
                continue
            sf = self.file(rel)
            if sf is not None:
                out.append(sf)
        return out

    def _walk(self) -> List[str]:
        rels: List[str] = []
        for r in self.roots:
            base = self.root / r
            if not base.is_dir():
                continue
            for p in sorted(base.rglob("*.py")):
                if "__pycache__" in p.parts:
                    continue
                rels.append(p.relative_to(self.root).as_posix())
        return rels


# --------------------------------------------------------------------------
# AST helpers shared by checkers
# --------------------------------------------------------------------------

def is_dataclass_def(node: ast.ClassDef) -> bool:
    """True if the class carries a @dataclass / @dataclasses.dataclass(...)
    decorator (bare or called)."""
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(target, ast.Name) and target.id == "dataclass":
            return True
        if isinstance(target, ast.Attribute) and target.attr == "dataclass":
            return True
    return False


def dataclass_fields(node: ast.ClassDef) -> List[ast.AnnAssign]:
    """Class-level annotated assignments (the dataclass fields), in
    declaration order."""
    return [stmt for stmt in node.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)]


def annotation_text(node: ast.AnnAssign) -> str:
    return ast.unparse(node.annotation)


def find_class(tree: ast.AST, name: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def find_function(scope: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(scope):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def string_tuple_assign(tree: ast.AST, target_name: str
                        ) -> Optional[Tuple[str, ...]]:
    """Value of ``TARGET = ("a", "b", ...)`` (module- or class-level
    constant tuple of strings), or None."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == target_name:
                    if isinstance(node.value, ast.Tuple) and all(
                            isinstance(e, ast.Constant)
                            and isinstance(e.value, str)
                            for e in node.value.elts):
                        return tuple(e.value for e in node.value.elts)
    return None


# --------------------------------------------------------------------------
# running checks
# --------------------------------------------------------------------------

def run_checks(root: Path, rules: Optional[Iterable[str]] = None,
               ) -> Tuple[List[Finding], List[Finding]]:
    """Run the selected checkers over ``root``.

    Returns ``(findings, suppressed)``: findings that survive inline
    suppression, and the ones an ``# repro: ignore[...]`` comment ate
    (reported in the JSON output so suppressions stay auditable).
    """
    # local imports: each checker module imports the engine, so importing
    # them at module scope here would be circular.
    from . import deprecation, determinism, layering, parity, units
    checkers = {
        "LAYERING": layering.check,
        "PARITY": parity.check,
        "UNITS": units.check,
        "DETERMINISM": determinism.check,
        "DEPRECATION": deprecation.check,
    }
    selected = tuple(rules) if rules else ALL_RULES
    unknown = [r for r in selected if r not in checkers]
    if unknown:
        raise ValueError(f"unknown rule(s) {unknown}; valid: {ALL_RULES}")

    repo = Repo(Path(root))
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for rule in selected:
        for f in checkers[rule](repo):
            sf = repo.file(f.path)
            if sf is not None and sf.is_suppressed(f.rule, f.line):
                suppressed.append(f)
            else:
                kept.append(f)
    # parse failures anywhere in the walk are findings too — a checker
    # silently skipping an unparseable file would be a hole in every rule.
    for sf in repo.files():
        if sf.parse_error:
            kept.append(Finding("LAYERING", sf.path, 1, sf.parse_error))
    return sorted(kept), sorted(suppressed)


# --------------------------------------------------------------------------
# baseline + reports
# --------------------------------------------------------------------------

BASELINE_SCHEMA = 1


def load_baseline(path: Path) -> Set[Tuple[str, str, str]]:
    """Grandfathered finding keys from a baseline JSON (empty if the file
    does not exist — absence of a baseline means nothing is grandfathered)."""
    p = Path(path)
    if not p.is_file():
        return set()
    data = json.loads(p.read_text(encoding="utf-8"))
    return {(e["rule"], e["path"], e["message"])
            for e in data.get("findings", [])}


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "schema": BASELINE_SCHEMA,
        "comment": ("Grandfathered repro.analysis findings, keyed on "
                    "(rule, path, message) — line numbers intentionally "
                    "excluded.  Regen: python -m repro.analysis --check "
                    "--regen-baseline.  Keep this empty: fix or "
                    "`# repro: ignore[...]` new findings instead."),
        "findings": [{"rule": f.rule, "path": f.path, "message": f.message}
                     for f in sorted(findings)],
    }
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n",
                 encoding="utf-8")


def split_baselined(findings: Sequence[Finding],
                    baseline: Set[Tuple[str, str, str]]
                    ) -> Tuple[List[Finding], List[Finding], List[Tuple]]:
    """(new, grandfathered, stale_baseline_keys).  Stale keys — baseline
    entries that no longer fire — are reported so the baseline shrinks
    over time instead of accreting."""
    new = [f for f in findings if f.baseline_key() not in baseline]
    old = [f for f in findings if f.baseline_key() in baseline]
    live = {f.baseline_key() for f in findings}
    stale = sorted(k for k in baseline if k not in live)
    return new, old, stale


def json_report(new: Sequence[Finding], grandfathered: Sequence[Finding],
                suppressed: Sequence[Finding], stale: Sequence[Tuple],
                rules: Sequence[str]) -> Dict:
    def rows(fs: Sequence[Finding]) -> List[Dict]:
        return [{"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message} for f in fs]
    counts: Dict[str, int] = {r: 0 for r in rules}
    for f in new:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "schema": BASELINE_SCHEMA,
        "rules": list(rules),
        "counts_by_rule": counts,
        "new_findings": rows(new),
        "grandfathered": rows(grandfathered),
        "suppressed": rows(suppressed),
        "stale_baseline_entries": [list(k) for k in stale],
        "ok": not new,
    }
