"""PARITY — the batched engine must cover every scalar Simulator axis.

PRs 4–6 hold ``batch_engine.run_batch`` bit-identical to
``Simulator.run`` and CI gates re-prove it dynamically (sweepperf /
hiersweep / faultsweep goldens) — but only over the axes the sweeps
*exercise*.  A new ``Strategy`` or ``Workload`` axis that
``CandidateBatch`` does not pack would sail through
those gates and silently diverge at sweep time.  (The ``ep``/``sp`` axes
landed exactly this way: this check went red the moment CandidateBatch
packed them and green once the contract below named their scalar twins.)
This checker pins the coupling statically via :data:`PACK_CONTRACT`, the
explicit map from each
``CandidateBatch`` packed array to the scalar-side field it mirrors.

When an axis is added on either side, this map (and the parity tests the
ISSUE-4/5 gates run) must be extended in the same PR — that is the
point: the build breaks until the batched engine and the contract agree.

Checks (all AST/text, nothing imported):

* P1  ``CandidateBatch._ARRAYS`` == PACK_CONTRACT keys, both directions.
* P2  ``Strategy`` fields == the contract's Strategy-owned targets.
* P3  every contract target exists on its owner (field *or* property).
* P4  every ``w.<attr>`` the scalar paths read (``Simulator.run`` and
      ``workloads.memory_bytes_per_npu``) is a contract target.
* P5  every ``Breakdown`` field is packed by ``run_batch``'s
      ``br.__dict__`` literal.
* P6  every float ``Breakdown`` field appears in ``as_dict()`` — the
      dict the dynamic parity gates actually diff.
* P7  every ``FabricSpec``/``ClusterSpec``/non-legacy ``Simulator`` field
      is referenced somewhere in ``batch_engine.py`` or ``sweep.py``.
* P8  every ``MemoryModel`` field is referenced in ``batch_engine.py``
      (``memory_bytes_batch``/``feasible_batch`` mirror the scalar
      memory model).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import (Finding, Repo, SourceFile, annotation_text,
                     dataclass_fields, find_class, find_function,
                     string_tuple_assign)

RULE = "PARITY"

PLACEMENT = "src/repro/core/placement.py"
SIMULATOR = "src/repro/core/simulator.py"
BATCH_ENGINE = "src/repro/core/batch_engine.py"
WORKLOADS = "src/repro/core/workloads.py"
SPECS = "src/repro/core/specs.py"
SWEEP = "src/repro/core/sweep.py"

# CandidateBatch packed array -> (owner class, scalar-side field/property).
# EXTEND THIS (plus the batched implementation and the parity goldens)
# whenever Strategy or Workload grows an axis — P1/P2 fail until you do.
PACK_CONTRACT: Dict[str, Tuple[str, str]] = {
    "mp": ("Strategy", "mp"),
    "dp": ("Strategy", "dp"),
    "pp": ("Strategy", "pp"),
    "wafers": ("Strategy", "wafers"),
    "ep": ("Strategy", "ep"),
    "sp": ("Strategy", "sp"),
    "n_layers": ("Workload", "n_layers"),
    "mp_ar": ("Workload", "mp_allreduce_per_layer"),
    "samples": ("Workload", "samples_per_dp"),
    "minibatch": ("Workload", "minibatch"),
    "seq": ("Workload", "seq"),
    "params_layer": ("Workload", "params_per_layer"),
    "flops": ("Workload", "flops_fwd_per_sample_layer"),
    "abps": ("Workload", "act_bytes_per_sample"),
    "pbt": ("Workload", "param_bytes_total"),
    "kv_layer": ("Workload", "kv_bytes_per_sample_layer"),
    "a2a_layer": ("Workload", "a2a_bytes_per_sample_layer"),
    "expert_frac": ("Workload", "expert_param_fraction"),
    "streaming": ("Workload", "execution"),
}

# Workload attributes the scalar paths may read without a packed twin:
# identity/labelling only, never arithmetic.
NON_NUMERIC_READS = {"name", "strategy"}


def _class_field_names(sf: SourceFile, cls: str) -> Optional[List[str]]:
    node = find_class(sf.tree, cls) if sf.tree else None
    if node is None:
        return None
    return [f.target.id for f in dataclass_fields(node)]  # type: ignore


def _class_property_names(sf: SourceFile, cls: str) -> Set[str]:
    node = find_class(sf.tree, cls) if sf.tree else None
    if node is None:
        return set()
    out: Set[str] = set()
    for stmt in node.body:
        if isinstance(stmt, ast.FunctionDef) and any(
                (isinstance(d, ast.Name) and d.id == "property") or
                (isinstance(d, ast.Attribute) and d.attr == "property")
                for d in stmt.decorator_list):
            out.add(stmt.name)
    return out


def _attr_reads(fn: ast.FunctionDef, varname: str) -> Dict[str, int]:
    """attribute name -> first line read on ``varname.<attr>``."""
    reads: Dict[str, int] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == varname):
            reads.setdefault(node.attr, node.lineno)
    return reads


def _dunder_dict_keys(tree: ast.AST) -> Optional[Tuple[Set[str], int]]:
    """Keys of the ``br.__dict__ = {...}`` literal in run_batch."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Attribute)
                and node.targets[0].attr == "__dict__"
                and isinstance(node.value, ast.Dict)):
            keys = {k.value for k in node.value.keys
                    if isinstance(k, ast.Constant) and isinstance(k.value, str)}
            return keys, node.lineno
    return None


def _as_dict_keys(cls: ast.ClassDef) -> Set[str]:
    fn = find_function(cls, "as_dict")
    if fn is None:
        return set()
    keys: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            keys |= {k.value for k in node.keys
                     if isinstance(k, ast.Constant)
                     and isinstance(k.value, str)}
    return keys


def _referenced(name: str, *texts: str) -> bool:
    pat = re.compile(rf"\b{re.escape(name)}\b")
    return any(pat.search(t) for t in texts)


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    files: Dict[str, Optional[SourceFile]] = {
        p: repo.file(p)
        for p in (PLACEMENT, SIMULATOR, BATCH_ENGINE, WORKLOADS, SPECS, SWEEP)}
    missing = [p for p, sf in files.items() if sf is None or sf.tree is None]
    if missing:
        for p in missing:
            findings.append(Finding(
                RULE, p, 1, "expected core module missing or unparseable — "
                "the engine-parity contract cannot be checked"))
        return findings
    placement, simulator, batch, workloads, specs, sweep = (
        files[PLACEMENT], files[SIMULATOR], files[BATCH_ENGINE],
        files[WORKLOADS], files[SPECS], files[SWEEP])

    # ---- P1: packed arrays <-> contract ------------------------------
    arrays = string_tuple_assign(batch.tree, "_ARRAYS")
    if arrays is None:
        findings.append(Finding(
            RULE, BATCH_ENGINE, 1,
            "CandidateBatch._ARRAYS tuple not found — cannot verify the "
            "packed-axis contract"))
        arrays = ()
    for name in arrays:
        if name not in PACK_CONTRACT:
            findings.append(Finding(
                RULE, BATCH_ENGINE, 1,
                f"CandidateBatch packs '{name}' but PACK_CONTRACT has no "
                f"entry mapping it to a scalar-side field — extend "
                f"analysis/parity.py in the same change"))
    for name, (owner, field) in sorted(PACK_CONTRACT.items()):
        if arrays and name not in arrays:
            findings.append(Finding(
                RULE, BATCH_ENGINE, 1,
                f"contract axis '{name}' ({owner}.{field}) is no longer "
                f"packed by CandidateBatch._ARRAYS — the batched engine "
                f"lost a scalar axis"))

    # ---- P2: Strategy fields <-> contract ----------------------------
    strategy_fields = _class_field_names(placement, "Strategy")
    if strategy_fields is None:
        findings.append(Finding(RULE, PLACEMENT, 1,
                                "class Strategy not found"))
        strategy_fields = []
    contract_strategy = {f for (o, f) in PACK_CONTRACT.values()
                         if o == "Strategy"}
    for f in strategy_fields:
        if f not in contract_strategy:
            findings.append(Finding(
                RULE, PLACEMENT, 1,
                f"Strategy.{f} has no packed counterpart in CandidateBatch "
                f"— a sweep over it would silently fall back to scalar-only "
                f"(add it to _ARRAYS, run_batch and PACK_CONTRACT)"))
    for f in sorted(contract_strategy):
        if f not in strategy_fields:
            findings.append(Finding(
                RULE, PLACEMENT, 1,
                f"PACK_CONTRACT maps a packed array to Strategy.{f}, which "
                f"no longer exists"))

    # ---- P3: contract targets exist on their owners ------------------
    workload_fields = _class_field_names(workloads, "Workload") or []
    workload_props = _class_property_names(workloads, "Workload")
    workload_surface = set(workload_fields) | workload_props
    for name, (owner, field) in sorted(PACK_CONTRACT.items()):
        if owner == "Workload" and field not in workload_surface:
            findings.append(Finding(
                RULE, WORKLOADS, 1,
                f"PACK_CONTRACT maps packed '{name}' to Workload.{field}, "
                f"which is neither a field nor a property"))

    # ---- P4: scalar-side w.<attr> reads are all packed ---------------
    contract_workload = {f for (o, f) in PACK_CONTRACT.values()
                         if o == "Workload"}
    for sf, fn_name, var in ((simulator, "run", "w"),
                             (workloads, "memory_bytes_per_npu", "w")):
        fn = find_function(sf.tree, fn_name)
        if fn is None:
            findings.append(Finding(RULE, sf.path, 1,
                                    f"function {fn_name} not found"))
            continue
        for attr, line in sorted(_attr_reads(fn, var).items()):
            if attr in NON_NUMERIC_READS or attr in contract_workload:
                continue
            findings.append(Finding(
                RULE, sf.path, line,
                f"{fn_name} reads w.{attr}, which has no packed "
                f"counterpart in CandidateBatch (PACK_CONTRACT)"))

    # ---- P5/P6: Breakdown fields packed and diffable -----------------
    bd = find_class(simulator.tree, "Breakdown")
    if bd is None:
        findings.append(Finding(RULE, SIMULATOR, 1,
                                "class Breakdown not found"))
    else:
        fields = dataclass_fields(bd)
        packed = _dunder_dict_keys(batch.tree)
        if packed is None:
            findings.append(Finding(
                RULE, BATCH_ENGINE, 1,
                "run_batch's `br.__dict__ = {...}` literal not found — "
                "cannot verify Breakdown coverage"))
        else:
            keys, line = packed
            for f in fields:
                name = f.target.id  # type: ignore[union-attr]
                if name not in keys:
                    findings.append(Finding(
                        RULE, BATCH_ENGINE, line,
                        f"Breakdown.{name} is not packed by run_batch's "
                        f"br.__dict__ literal — batched results would lack "
                        f"the field"))
        as_dict = _as_dict_keys(bd)
        for f in fields:
            name = f.target.id  # type: ignore[union-attr]
            if annotation_text(f).strip() == "float" and name not in as_dict:
                findings.append(Finding(
                    RULE, SIMULATOR, f.lineno,
                    f"float field Breakdown.{name} missing from as_dict() — "
                    f"the dynamic parity gates diff as_dict, so drift in it "
                    f"would go unchecked"))

    # ---- P7: spec/Simulator surfaces referenced by the batched side --
    legacy = (string_tuple_assign(simulator.tree, "_LEGACY_FABRIC_KW") or ()) \
        + (string_tuple_assign(simulator.tree, "_LEGACY_CLUSTER_KW") or ())
    if not legacy:
        findings.append(Finding(
            RULE, SIMULATOR, 1,
            "_LEGACY_FABRIC_KW/_LEGACY_CLUSTER_KW tuples not found — "
            "cannot separate legacy shims from live Simulator fields"))
    surfaces: List[Tuple[str, str, Sequence[str]]] = []
    for cls in ("FabricSpec", "ClusterSpec"):
        names = _class_field_names(specs, cls)
        if names is None:
            findings.append(Finding(RULE, SPECS, 1, f"class {cls} not found"))
        else:
            surfaces.append((SPECS, cls, names))
    sim_fields = _class_field_names(simulator, "Simulator") or []
    surfaces.append((SIMULATOR, "Simulator",
                     [f for f in sim_fields if f not in legacy]))
    engine_texts = (batch.text, sweep.text)
    for path, cls, names in surfaces:
        for name in names:
            if not _referenced(name, *engine_texts):
                findings.append(Finding(
                    RULE, path, 1,
                    f"{cls}.{name} is never referenced in batch_engine.py "
                    f"or sweep.py — the batched/sweep side cannot be "
                    f"honouring it"))

    # ---- structural twins: the batched hierarchy/memory surfaces -----
    for twin in ("InterLane", "CandidateBatch"):
        if find_class(batch.tree, twin) is None:
            findings.append(Finding(
                RULE, BATCH_ENGINE, 1,
                f"class {twin} not found — the batched structure twin of "
                f"the scalar surface is gone"))
    for fn_name in ("run_batch", "memory_bytes_batch", "feasible_batch"):
        if find_function(batch.tree, fn_name) is None:
            findings.append(Finding(
                RULE, BATCH_ENGINE, 1,
                f"function {fn_name} not found in batch_engine.py"))

    # ---- P8: memory model parity -------------------------------------
    for name in _class_field_names(workloads, "MemoryModel") or []:
        if not _referenced(name, batch.text):
            findings.append(Finding(
                RULE, WORKLOADS, 1,
                f"MemoryModel.{name} is never referenced in batch_engine.py "
                f"— memory_bytes_batch/feasible_batch have drifted from the "
                f"scalar memory model"))
    return findings
