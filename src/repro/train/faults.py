"""Fault injection for the JAX train lane.

The cost model prices failures (``core/lifetime.py``); this module
*creates* them against the real runtime, so the recovery path the
pricing assumes — torn checkpoint swept, survivors re-meshed, state
re-sharded, trajectory continued — is exercised end to end by
``tests/test_multidevice.py`` instead of trusted on faith.

Three injectors:

  * :func:`torn_save` — a checkpoint writer killed mid-save: real leaf
    files land in the ``step_X.tmp`` staging dir but the MANIFEST /
    COMMIT never do.  The debris is byte-for-byte what
    ``checkpoint.cleanup_incomplete`` must sweep and ``latest_step``
    must ignore.
  * :class:`FlakyIO` — a transient-failure wrapper (NFS/FUSE under
    load): the first ``failures`` calls raise ``OSError``, then it
    delegates.  This is the fault ``checkpoint._retry_io`` exists to
    absorb.
  * :func:`seeded_device_failure` — a seeded draw of devices to kill,
    the runtime mirror of the degradation chain's
    ``random.Random(seed)`` kill order.

:func:`crash_and_recover` composes them into the full story: tear the
in-flight save, kill devices, and drive
``elastic.resume_after_failure`` — including the ``n_alive < tp`` case
where the survivors cannot host the model axis and ``plan_shrink``
re-plans ``tp`` onto a smaller head/FFN-divisible divisor.
"""

from __future__ import annotations

import dataclasses
import random
import shutil
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.train import checkpoint as ckpt
from repro.train.elastic import resume_after_failure
from repro.train.optim import OptimConfig


class TornWrite(RuntimeError):
    """Raised by :func:`torn_save` at the simulated point of death."""


def torn_save(path: str | Path, tree: Any, *, step: int,
              fail_after_leaves: int = 1) -> Path:
    """Start a real checkpoint save and die partway through.

    Writes ``fail_after_leaves`` genuine leaf ``.npy`` files into the
    ``step_X.tmp`` staging directory — never the manifest, never the
    COMMIT marker, never the rename — then raises :class:`TornWrite`,
    exactly as if the writer process was killed by the failure the
    checkpoint was racing.  Returns nothing usable: the point is the
    debris left behind (the raised exception carries the tmp path)."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = jax.tree.flatten(tree)
    n = min(fail_after_leaves, len(leaves))
    for i, leaf in enumerate(leaves[:n]):
        arr = np.asarray(jax.device_get(leaf))
        if str(arr.dtype) in ckpt._VIEW_DTYPES:
            arr = arr.view(ckpt._VIEW_DTYPES[str(arr.dtype)])
        np.save(tmp / f"leaf_{i:05d}.npy", arr, allow_pickle=False)
    raise TornWrite(
        f"simulated writer death after {n}/{len(leaves)} leaves in {tmp}")


class FlakyIO:
    """Wrap a callable so its first ``failures`` invocations raise
    ``OSError`` (the transient NFS/FUSE fault model), then delegate.

    ``calls`` counts every invocation — a retry loop that absorbed two
    injected faults shows ``calls == failures + 1``."""

    def __init__(self, fn: Callable[..., Any], failures: int):
        self.fn = fn
        self.failures_left = failures
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if self.failures_left > 0:
            self.failures_left -= 1
            raise OSError(f"injected transient IO failure "
                          f"({self.failures_left} left)")
        return self.fn(*args, **kwargs)


def seeded_device_failure(mesh, n_failed: int, seed: int = 0) -> List:
    """A seeded sample of ``mesh``'s devices to declare dead — the
    runtime mirror of ``core/lifetime.py``'s degradation-chain kill
    order (``random.Random(seed)``), so a cost-model scenario and its
    runtime re-enactment can share a seed."""
    devices = list(mesh.devices.flat)
    if not 0 < n_failed < len(devices):
        raise ValueError(f"n_failed must be in (0, {len(devices)}), "
                         f"got {n_failed}")
    return random.Random(seed).sample(devices, n_failed)


@dataclasses.dataclass(frozen=True)
class FaultRecovery:
    """What :func:`crash_and_recover` hands back to the train loop."""
    setup: Any                        # CellSetup for the survivor mesh
    state: Any                        # TrainState restored + re-sharded
    resumed_step: int                 # last *committed* step
    mesh: Any                         # the survivor mesh
    failed: Tuple                     # devices declared dead
    torn_step: int                    # the save the failure interrupted
    plan: Dict[str, int]              # new mesh axes, e.g. data/model


def crash_and_recover(checkpoint_dir: str | Path, cfg: ModelConfig,
                      shape: ShapeConfig, mesh, state: Any, *,
                      torn_step: int, n_failed: int, seed: int = 0,
                      pcfg: Optional[ParallelConfig] = None,
                      ocfg: Optional[OptimConfig] = None) -> FaultRecovery:
    """Inject the full failure story and recover from it.

    1. the in-flight save of ``torn_step`` is torn mid-write
       (:func:`torn_save` — committed checkpoints are untouched);
    2. ``n_failed`` seeded devices die (:func:`seeded_device_failure`);
    3. ``elastic.resume_after_failure`` sweeps the debris, shrinks the
       mesh onto the survivors (re-planning ``tp`` over its divisors
       when the failure ate into the model axis), and restores the last
       committed checkpoint onto the new sharding.
    """
    try:
        torn_save(checkpoint_dir, state, step=torn_step)
    except TornWrite:
        pass                          # the simulated kill, by design
    failed = seeded_device_failure(mesh, n_failed, seed)
    setup, new_state, at, new_mesh = resume_after_failure(
        str(checkpoint_dir), cfg, shape, mesh, failed, pcfg, ocfg)
    return FaultRecovery(setup=setup, state=new_state, resumed_step=at,
                         mesh=new_mesh, failed=tuple(failed),
                         torn_step=torn_step, plan=dict(new_mesh.shape))
