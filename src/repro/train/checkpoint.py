"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000420/
        MANIFEST.json        # treedef, shapes, dtypes, crc32s, extras
        leaf_00000.npy ...   # one file per pytree leaf (QTensor leaves
                             # stored as their q/scale arrays)
        COMMIT               # written last — a checkpoint without COMMIT
                             # is incomplete and ignored (atomicity)

Fault-tolerance contract:
  * writes go to ``step_X.tmp`` then ``rename`` (atomic on POSIX);
  * ``latest_step`` skips uncommitted/corrupt checkpoints;
  * ``AsyncCheckpointer`` snapshots device arrays to host, then writes on a
    background thread — the train loop never blocks on disk;
  * ``restore`` re-shards every leaf onto the *current* mesh via
    ``jax.device_put`` with target shardings — restoring onto a different
    device count (elastic restart) is the same code path.
"""

from __future__ import annotations

import dataclasses
import json
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't natively (de)serialize bfloat16 — store as a uint16 view and
# record the logical dtype in the manifest
_VIEW_DTYPES = {"bfloat16": np.uint16}

# transient-IO retry policy: networked filesystems (NFS/FUSE) throw
# spurious OSErrors under load; a failed *save* loses a checkpoint and a
# failed *restore* kills a recovery, so both deserve a few bounded
# attempts before giving up
IO_RETRIES = 3
IO_BACKOFF_S = 0.05     # repro: unit[s] (doubles per attempt)


def _retry_io(fn: Callable[[], Any], what: str, *,
              retries: int = IO_RETRIES,
              backoff_s: float = IO_BACKOFF_S) -> Any:
    """Run ``fn`` with bounded retry + exponential backoff on OSError.

    The last attempt re-raises, so persistent failures (disk full, dead
    mount, genuinely missing file) still surface to the caller."""
    for attempt in range(retries):
        try:
            return fn()
        except OSError:
            if attempt == retries - 1:
                raise
            time.sleep(backoff_s * (2 ** attempt))


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str | Path, tree: Any, *, step: int,
         extras: Optional[Dict[str, Any]] = None) -> Path:
    """Synchronous atomic save.  Returns the committed directory."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extras": extras or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical_dtype])
        fname = f"leaf_{i:05d}.npy"
        _retry_io(lambda: np.save(tmp / fname, arr, allow_pickle=False),
                  fname)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape),
            "dtype": logical_dtype,
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    _retry_io(lambda: (tmp / "MANIFEST.json").write_text(
        json.dumps(manifest, indent=1)), "MANIFEST.json")
    _retry_io(lambda: (tmp / "COMMIT").write_text("ok"), "COMMIT")
    if final.exists():
        shutil.rmtree(final)
    _retry_io(lambda: tmp.rename(final), "commit rename")
    return final


def cleanup_incomplete(path: str | Path) -> int:
    """Remove ``step_X.tmp`` debris left by a writer that died mid-save
    (the crash the elastic-restart path recovers from).  Committed
    checkpoints are never touched.  Returns the number of debris dirs
    gone after the call.

    Idempotent under races: two recoveries sweeping the same directory
    concurrently both succeed — a dir the other recovery already removed
    (or the root itself vanishing mid-scan) is a no-op, not an error."""
    root = Path(path)
    try:
        debris = [d for d in root.iterdir()
                  if d.is_dir() and d.name.startswith("step_")
                  and d.name.endswith(".tmp")]
    except FileNotFoundError:
        return 0
    n = 0
    for d in debris:
        shutil.rmtree(d, ignore_errors=True)
        if not d.exists():
            n += 1
    return n


def latest_step(path: str | Path) -> Optional[int]:
    root = Path(path)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.name.startswith("step_") and not d.name.endswith(".tmp") \
                and (d / "COMMIT").exists():
            try:
                steps.append(int(d.name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(path: str | Path, target_tree: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True
            ) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put directly to their (possibly different-mesh) destination,
    which is the whole elastic-restart story.
    """
    root = Path(path)
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads(_retry_io(
        lambda: (d / "MANIFEST.json").read_text(), "MANIFEST.json"))

    leaves, treedef = _flatten_with_paths(target_tree)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target expects "
            f"{len(leaves)} — architecture mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))

    out = []
    for i, meta in enumerate(manifest["leaves"]):
        arr = _retry_io(
            lambda: np.load(d / meta["file"], allow_pickle=False),
            meta["file"])
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"crc mismatch in {meta['file']}")
        if meta["dtype"] in _VIEW_DTYPES:
            arr = arr.view(ml_dtypes.bfloat16)
        if shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["extras"]


class AsyncCheckpointer:
    """Snapshot-to-host immediately, write on a worker thread."""

    def __init__(self, path: str | Path, keep: int = 3):
        self.path = Path(path)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree: Any, *, step: int,
             extras: Optional[Dict[str, Any]] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.path, host_tree, step=step, extras=extras)
            self.last_committed = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.name[5:]) for d in self.path.iterdir()
            if d.name.startswith("step_") and (d / "COMMIT").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.path / f"step_{s:08d}", ignore_errors=True)
