"""Production training loop: step timing, metrics, checkpoints, restart.

``Trainer`` wires together the cell setup (model + shardings + jitted
step), the data pipeline, the async checkpointer and the metrics log, and
implements the fault-tolerance contract:

  * auto-resume from the latest committed checkpoint (params, optimizer,
    data-pipeline state, step counter);
  * SIGTERM/SIGINT → synchronous final checkpoint before exit (preemption
    safety);
  * per-step wall-time and token-throughput accounting with an MFU
    estimate against the configured peak;
  * straggler hook: a callback observing per-step durations; the default
    policy logs p50/p95 and flags steps > ``straggler_factor``×p50 (on a
    real multi-host deployment this feeds the controller that re-shards
    around slow hosts — single-controller CPU runs only observe).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.parallel.steps import CellSetup, TrainState, make_train_setup
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, PrefetchIterator, SyntheticLM
from repro.train.optim import OptimConfig, init_adam
from repro.models.modules import split
from repro.models import transformer as tfm


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    seed: int = 0
    straggler_factor: float = 2.0
    peak_flops_per_device: float = 197e12


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 pcfg: Optional[ParallelConfig] = None,
                 ocfg: Optional[OptimConfig] = None,
                 tcfg: Optional[TrainerConfig] = None):
        self.tcfg = tcfg or TrainerConfig()
        self.setup: CellSetup = make_train_setup(cfg, shape, mesh, pcfg, ocfg)
        self.mesh = mesh
        self.cfg = cfg
        self.shape = shape
        self.ocfg = ocfg or OptimConfig()
        self.data = SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=self.tcfg.seed))
        self.ckpt = ckpt.AsyncCheckpointer(self.tcfg.checkpoint_dir,
                                           keep=self.tcfg.keep_checkpoints)
        self.step = 0
        self.history: list[Dict[str, float]] = []
        self._durations: list[float] = []
        self._stop = False

    # ---- state ------------------------------------------------------------
    def init_state(self) -> TrainState:
        pdt = {"bfloat16": jax.numpy.bfloat16,
               "float32": jax.numpy.float32}[self.setup.pcfg.param_dtype]

        def make(key):
            params, _ = split(tfm.init(key, self.cfg, dtype=pdt))
            return TrainState(params=params,
                              opt=init_adam(params, self.ocfg))

        with self.mesh:
            return jax.jit(make, out_shardings=self.setup.state_shardings)(
                jax.random.PRNGKey(self.tcfg.seed))

    def resume_or_init(self) -> TrainState:
        latest = ckpt.latest_step(self.tcfg.checkpoint_dir)
        state = self.init_state()
        if latest is not None:
            state, extras = ckpt.restore(
                self.tcfg.checkpoint_dir, state,
                shardings=self.setup.state_shardings)
            self.step = int(extras.get("step", latest))
            print(f"[trainer] resumed from step {self.step}")
        return state

    # ---- loop ---------------------------------------------------------------
    def run(self, state: Optional[TrainState] = None) -> TrainState:
        t = self.tcfg
        state = state if state is not None else self.resume_or_init()
        it = PrefetchIterator(self.data, start_step=self.step)

        orig_handlers = {}

        def on_signal(signum, frame):
            self._stop = True
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                orig_handlers[sig] = signal.signal(sig, on_signal)
            except ValueError:
                pass  # non-main thread (tests)

        tokens_per_step = self.shape.global_batch * self.shape.seq_len
        try:
            with self.mesh:
                while self.step < t.steps and not self._stop:
                    batch = next(it)
                    t0 = time.perf_counter()
                    state, metrics = self.setup.step_fn(state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.perf_counter() - t0
                    self.step += 1
                    self._durations.append(dt)
                    self._observe_stragglers()
                    if self.step % t.log_every == 0 or self.step == t.steps:
                        row = {k: float(v) for k, v in metrics.items()}
                        row.update(step=self.step, seconds=dt,
                                   tokens_per_s=tokens_per_step / dt)
                        self.history.append(row)
                        print(f"[trainer] step {self.step} "
                              f"loss={row['loss']:.4f} "
                              f"{row['tokens_per_s']:.0f} tok/s")
                    if self.step % t.checkpoint_every == 0:
                        self.ckpt.save(state, step=self.step,
                                       extras={"step": self.step,
                                               "data": it.state()})
            # final (synchronous) checkpoint — incl. preemption path
            self.ckpt.wait()
            ckpt.save(t.checkpoint_dir, state, step=self.step,
                      extras={"step": self.step, "data": it.state()})
        finally:
            it.close()
            for sig, h in orig_handlers.items():
                signal.signal(sig, h)
        return state

    def _observe_stragglers(self):
        if len(self._durations) < 10:
            return
        recent = np.array(self._durations[-50:])
        p50 = float(np.percentile(recent, 50))
        if self._durations[-1] > self.tcfg.straggler_factor * p50:
            print(f"[trainer] straggler step {self.step}: "
                  f"{self._durations[-1]:.3f}s vs p50 {p50:.3f}s")
