"""Deterministic, checkpointable synthetic-text data pipeline.

Production properties kept even though the corpus is synthetic:
  * fully deterministic given (seed, step) — a restart resumes mid-epoch
    exactly (the pipeline *state* is just the step counter, stored in every
    checkpoint);
  * per-host sharding hooks (shard_id / num_shards);
  * background prefetch thread with bounded queue.

The corpus generator produces Zipf-distributed token streams with local
n-gram structure so cross-entropy actually *decreases* during the example
training runs (pure-uniform tokens would pin loss at log V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    shard_id: int = 0
    num_shards: int = 1
    zipf_a: float = 1.2
    ngram_repeat_p: float = 0.35   # chance to copy token from 7 positions back


class SyntheticLM:
    """Stateless batch generator: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.probs = probs / probs.sum()
        self.perm = rng.permutation(cfg.vocab_size)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard_id))
        toks = rng.choice(cfg.vocab_size, p=self.probs,
                          size=(per_shard, cfg.seq_len + 1))
        toks = self.perm[toks]
        # inject n-gram structure: with prob p, token t copies t-7
        copy = rng.random((per_shard, cfg.seq_len + 1)) < cfg.ngram_repeat_p
        copy[:, :7] = False
        idx = np.arange(cfg.seq_len + 1)
        src = np.clip(idx - 7, 0, None)
        toks = np.where(copy, toks[:, src], toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """Background-thread prefetch over ``SyntheticLM`` with resumable state."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._next_to_produce = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            item = self.source.batch(self._next_to_produce)
            self._next_to_produce += 1
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self) -> Dict[str, np.ndarray]:
        item = self._q.get()
        self.step += 1
        return item

    def state(self) -> Dict[str, int]:
        """Checkpointable pipeline state."""
        return {"step": self.step}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
