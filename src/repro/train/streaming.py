"""Weight-streaming execution mode (paper Sec. III-A, Cerebras-style).

When the model exceeds device memory, parameters live in *host* memory
(the wafer paper's off-chip DRAM behind CXL controllers) and stream to the
device(s) one layer at a time:

  forward:   for each layer l: H2D(params_l) → fwd_l (activations saved)
  backward:  for each layer l (reverse): H2D(params_l) → vjp_l
             → D2H(grads_l) → host optimizer update (the paper's
             "lightweight near-storage core updates the model", so
             optimizer state never crosses the I/O link)

On real hardware the H2D of layer l+1 overlaps the compute of layer l via
double buffering (``jax.device_put`` is async); this CPU container executes
the same schedule synchronously.  The FRED connection: the *sustainable
stream rate* is exactly what `core.meshnet.io_linerate_factor` vs
`core.fabric` model — the mesh hotspot throttles this loop to 0.65× line
rate, FRED runs it at 1.0 (EXPERIMENTS.md §Fig10).

``stream_grads`` is verified bit-for-bit (up to dtype) against the
monolithic ``jax.grad`` path in tests/test_streaming.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ParallelConfig
from repro.models.modules import rms_norm, softmax_cross_entropy, split
from repro.models.layers import apply_attn_block
from repro.models.ssm import mamba2_forward
from repro.train.optim import OptimConfig


# --------------------------------------------------------------------------
# layer-granular forward/backward with host-resident parameters
# --------------------------------------------------------------------------

class HostParams:
    """Parameters as host numpy arrays, sliced per layer for streaming."""

    def __init__(self, params: Any, n_layers: int):
        self.n_layers = n_layers
        # writable copies: the near-storage optimizer updates in place
        self.host = jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), params)

    def layer(self, i: int):
        """Device copy of layer i's block params (the H2D stream)."""
        blocks = self.host["blocks"]
        return jax.tree.map(lambda a: jnp.asarray(a[i]), blocks)

    def top(self):
        rest = {k: v for k, v in self.host.items() if k != "blocks"}
        return jax.tree.map(jnp.asarray, rest)

    def apply_grad_update(self, i: Optional[int], grads, update_fn):
        """Near-storage optimizer: update host weights in place.
        ``i``: layer index or None for the non-block params."""
        if i is None:
            top = {k: v for k, v in self.host.items() if k != "blocks"}
            self.host.update(jax.tree.map(update_fn, top, grads))
        else:
            layer_host = jax.tree.map(lambda a: a[i], self.host["blocks"])
            new_layer = jax.tree.map(update_fn, layer_host, grads)
            def write(dst, src):
                dst[i] = src
                return dst
            self.host["blocks"] = jax.tree.map(write, self.host["blocks"],
                                               new_layer)


def _block_fwd(cfg: ModelConfig, pcfg: ParallelConfig):
    """One decoder block as a pure fn of (layer_params, x)."""
    def f(bp, x):
        if cfg.family in ("ssm", "hybrid"):
            hin = rms_norm(x, bp["ln"], cfg.norm_eps)
            return x + mamba2_forward(bp["ssm"], hin, cfg)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        y, _, _, _ = apply_attn_block(bp, cfg, pcfg, x, positions=positions,
                                      mode="train")
        return y
    return jax.jit(f)


def stream_forward(hp: HostParams, batch, cfg: ModelConfig,
                   pcfg: ParallelConfig) -> Tuple[jnp.ndarray, List]:
    """Layer-streaming forward; returns (loss, saved boundary activations)."""
    top = hp.top()
    x = jnp.take(top["embed"], batch["tokens"], axis=0)
    block = _block_fwd(cfg, pcfg)
    acts = [x]
    for i in range(hp.n_layers):
        x = block(hp.layer(i), x)          # H2D stream of layer i
        acts.append(x)
    loss = _head_loss(top, x, batch, cfg)
    return loss, acts


def _head_loss(top, x, batch, cfg):
    x = rms_norm(x, top["final_norm"], cfg.norm_eps)
    head = top["embed"].T if cfg.tie_embeddings else top["lm_head"]
    logits = x @ head
    loss, _ = softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return loss


def stream_grads(hp: HostParams, batch, cfg: ModelConfig,
                 pcfg: ParallelConfig):
    """Streaming backward: grads computed layer-by-layer, streamed to host.

    Returns (loss, top_grads, layer_grads_list[host]) — layer weights are
    fetched a second time during backward, exactly the paper's 'model
    loaded at least twice per iteration' accounting."""
    loss_and_acts = stream_forward(hp, batch, cfg, pcfg)
    loss, acts = loss_and_acts
    top = hp.top()

    # head + final-norm grads, and the cotangent entering the last block
    def head_fn(top_p, x_last):
        return _head_loss(top_p, x_last, batch, cfg)
    (loss_v, (g_top, g_x)) = (loss, jax.grad(head_fn, argnums=(0, 1))(
        top, acts[-1]))

    block = _block_fwd(cfg, pcfg)
    layer_grads: List[Any] = [None] * hp.n_layers
    for i in reversed(range(hp.n_layers)):
        bp = hp.layer(i)                    # second H2D stream
        _, vjp = jax.vjp(lambda p, x: block(p, x), bp, acts[i])
        g_bp, g_x = vjp(g_x)
        layer_grads[i] = jax.tree.map(
            lambda a: np.asarray(jax.device_get(a)), g_bp)  # D2H stream

    # embedding grad from the input gather
    def embed_fn(emb, gx):
        return jnp.sum(jnp.take(emb, batch["tokens"], axis=0) * gx)
    g_embed_in = jax.grad(embed_fn)(top["embed"], g_x)
    g_top["embed"] = g_top["embed"] + g_embed_in
    return loss_v, g_top, layer_grads


def stream_train_step(hp: HostParams, batch, cfg, pcfg, lr: float = 1e-3):
    """One full weight-streaming SGD step with near-storage update."""
    loss, g_top, layer_grads = stream_grads(hp, batch, cfg, pcfg)
    upd = lambda w, g: (np.asarray(w) - lr * np.asarray(jax.device_get(g))
                        ).astype(np.asarray(w).dtype)
    for i, g in enumerate(layer_grads):
        hp.apply_grad_update(i, g, upd)
    hp.apply_grad_update(None, g_top, upd)
    return float(loss)
