"""AdamW from scratch (no optax), with mixed precision + memory modes.

Modes (``OptimConfig``):

* ``master=True``  — fp32 master copy of the (bf16) params; updates applied
  to the master, params re-cast each step (the standard mixed-precision
  recipe).
* ``master=False`` — params updated in their own dtype with fp32 math
  (saves 4 bytes/param — used by arctic-480b to fit HBM).
* ``moments_dtype`` ∈ {float32, bfloat16, int8} — int8 stores blockless
  *per-row* quantized moments (scale shape = param.shape[:-1]), the 8-bit
  Adam memory trick; scales inherit the row dims' sharding so every
  optimizer-state leaf keeps an exactly-divisible jit input sharding.

All state leaves mirror the parameter tree structure, so the sharding rules
in ``parallel.sharding`` apply leaf-for-leaf (``Ruleset.opt_spec``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

try:                                   # the JAX-free CI core lane imports
    import jax                         # this module only for OptimConfig
    import jax.numpy as jnp            # (via parallel.policy); every
except ImportError:                    # array function below needs jax
    jax = jnp = None


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master: bool = True
    moments_dtype: str = "float32"   # float32 | bfloat16 | int8


class QTensor(NamedTuple):
    """Per-row int8 quantized tensor (non-negative ⇒ unsigned mapping)."""
    q: jnp.ndarray          # int8, same shape as the original
    scale: jnp.ndarray      # fp32, shape = original.shape[:-1] (or () for 1-d)


class AdamState(NamedTuple):
    step: jnp.ndarray
    master: Any             # fp32 params or None
    m: Any                  # moments (array | QTensor per leaf)
    v: Any


def _quantize(x: jnp.ndarray, signed: bool) -> QTensor:
    # bf16 quantization input: halves the materialized temporary for the
    # amax reduction on multi-GB moment leaves; int8 output precision is
    # unaffected (7 bits << bf16's 8 mantissa bits)
    xh = x.astype(jnp.bfloat16)
    xf = xh.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1) if x.ndim > 1 else jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.round(xf / scale[..., None] if x.ndim > 1 else xf / scale)
    q = jnp.clip(q, -127 if signed else 0, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale.astype(jnp.float32))


def _dequantize(t: QTensor) -> jnp.ndarray:
    s = t.scale[..., None] if t.q.ndim > 1 else t.scale
    return t.q.astype(jnp.float32) * s


def _encode_moment(x, dtype: str, signed: bool):
    if dtype == "int8":
        return _quantize(x, signed)
    return x.astype(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)


def _decode_moment(x):
    if isinstance(x, QTensor):
        return _dequantize(x)
    return x.astype(jnp.float32)


def init_adam(params, ocfg: OptimConfig) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        master=(jax.tree.map(lambda p: p.astype(jnp.float32), params)
                if ocfg.master else None),
        m=jax.tree.map(lambda z: _encode_moment(z, ocfg.moments_dtype, True), zeros),
        v=jax.tree.map(lambda z: _encode_moment(z, ocfg.moments_dtype, False), zeros),
    )


def lr_schedule(step, ocfg: OptimConfig):
    """Linear warmup → cosine decay to ``min_lr_ratio``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - ocfg.warmup_steps) /
                    jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * warm * (ocfg.min_lr_ratio + (1 - ocfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(params, grads, state: AdamState, ocfg: OptimConfig
                ) -> Tuple[Any, AdamState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(step, ocfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if ocfg.grad_clip else 1.0

    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)

    def leaf_core(p, g, m, v, mw):
        g = g.astype(jnp.float32) * clip
        mf = _decode_moment(m)
        vf = _decode_moment(v)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * jnp.square(g)
        upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + ocfg.eps)
        base = mw if mw is not None else p.astype(jnp.float32)
        new_master = base - lr * (upd + ocfg.weight_decay * base)
        return (new_master.astype(p.dtype),
                _encode_moment(mf, ocfg.moments_dtype, True),
                _encode_moment(vf, ocfg.moments_dtype, False),
                new_master if mw is not None else None)

    # Huge stacked leaves (MoE expert banks: Gbytes of fp32 intermediates)
    # are updated slice-by-slice over the leading 'layers' dim so the fp32
    # temporaries stay one-layer-sized.
    SCAN_THRESHOLD = 1 << 62   # disabled: broke XLA aliasing (measured +16GiB)

    def leaf(p, g, m, v, mw):
        if p.size <= SCAN_THRESHOLD or p.ndim < 2:
            return leaf_core(p, g, m, v, mw)
        if mw is None:
            def body(_, xs):
                np_, nm, nv, _none = leaf_core(*xs, None)
                return None, (np_, nm, nv)
            _, (np_, nm, nv) = jax.lax.scan(body, None, (p, g, m, v))
            return np_, nm, nv, None
        def body(_, xs):
            return None, leaf_core(*xs)
        _, (np_, nm, nv, nmw) = jax.lax.scan(body, None, (p, g, m, v, mw))
        return np_, nm, nv, nmw

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state.m)
    v_flat = treedef.flatten_up_to(state.v)
    mw_flat = (treedef.flatten_up_to(state.master)
               if state.master is not None else [None] * len(p_flat))
    results = [leaf(p, g, m, v, mw) for p, g, m, v, mw
               in zip(p_flat, g_flat, m_flat, v_flat, mw_flat)]
    unflat = lambda i: jax.tree.unflatten(treedef, [r[i] for r in results])
    new_state = AdamState(
        step=step,
        master=unflat(3) if state.master is not None else None,
        m=unflat(1), v=unflat(2))
    return unflat(0), new_state, {"grad_norm": gnorm, "lr": lr}
