"""Elastic scaling: resume a run on a different device count / mesh.

Checkpoints store *logical* (unsharded) arrays (see ``checkpoint``), so
elasticity is a restore-time concern: build the new mesh, derive the new
shardings from the same Ruleset rules, and ``device_put`` each leaf to its
new layout.  Batch-divisibility is re-validated and the data pipeline's
shard count updated; everything else (optimizer state, step counter) is
mesh-independent by construction.

This is the recovery path for node failures at scale: drop to a smaller
healthy mesh, restore, continue; grow back later the same way.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.parallel.steps import CellSetup, make_train_setup
from repro.train import checkpoint as ckpt
from repro.train.optim import OptimConfig


def validate_shape_for_mesh(shape: ShapeConfig, mesh) -> None:
    total = 1
    for n in mesh.shape.values():
        total *= n
    if shape.global_batch % mesh.shape.get("data", 1):
        raise ValueError(
            f"global batch {shape.global_batch} not divisible by data axis "
            f"{mesh.shape.get('data')} on the new mesh")


def resume_on_mesh(checkpoint_dir: str, cfg: ModelConfig, shape: ShapeConfig,
                   new_mesh, pcfg: Optional[ParallelConfig] = None,
                   ocfg: Optional[OptimConfig] = None,
                   step: Optional[int] = None) -> Tuple[CellSetup, Any, int]:
    """Build the setup for ``new_mesh`` and restore state onto it.

    Returns (setup, train_state, resumed_step)."""
    validate_shape_for_mesh(shape, new_mesh)
    setup = make_train_setup(cfg, shape, new_mesh, pcfg, ocfg)
    state, extras = ckpt.restore(checkpoint_dir, setup.state_shapes,
                                 step=step,
                                 shardings=setup.state_shardings)
    return setup, state, int(extras.get("step", 0))
