"""Elastic scaling: resume a run on a different device count / mesh.

Checkpoints store *logical* (unsharded) arrays (see ``checkpoint``), so
elasticity is a restore-time concern: build the new mesh, derive the new
shardings from the same Ruleset rules, and ``device_put`` each leaf to its
new layout.  Batch-divisibility is re-validated and the data pipeline's
shard count updated; everything else (optimizer state, step counter) is
mesh-independent by construction.

This is the recovery path for node failures at scale — the JAX-runtime
analogue of the cost model's defect masks (``core/defects.py``): a wafer
(or host) dies mid-run, the surviving devices are rebuilt into the
largest still-valid mesh (:func:`shrink_mesh` — the model axis is kept,
the data-parallel degree drops), and :func:`resume_after_failure`
restores the last committed checkpoint onto it and continues.  Growing
back later is the same code path with more devices.
"""

from __future__ import annotations

from typing import Any, Iterable, Optional, Tuple

import jax

from repro.models.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.parallel.steps import CellSetup, make_train_setup
from repro.train import checkpoint as ckpt
from repro.train.optim import OptimConfig


def validate_shape_for_mesh(shape: ShapeConfig, mesh) -> None:
    """Reject (shape, mesh) pairs the step builders cannot tile.

    The global batch must split evenly over *every* batch-sharded mesh
    axis — ``data``, plus ``pod`` on multi-pod meshes where the gradient
    sync spans both (``parallel.collectives.build_sync``).  A mesh with
    more batch shards than samples fails the same test (the remainder is
    the whole batch)."""
    shards = 1
    for axis in ("pod", "data"):
        shards *= mesh.shape.get(axis, 1)
    if shape.global_batch % shards:
        raise ValueError(
            f"global batch {shape.global_batch} not divisible by the "
            f"{shards} batch shards of the new mesh "
            f"(axes {dict(mesh.shape)})")


def _best_dp(n_alive: int, tp: int, global_batch: int) -> int:
    """Largest DP degree that fits the survivors and divides the batch."""
    dp = n_alive // tp
    while dp > 1 and global_batch % dp:
        dp -= 1
    return dp


def plan_shrink(n_alive: int, tp: int, global_batch: int, *,
                model_cfg: Optional[ModelConfig] = None,
                shape: Optional[ShapeConfig] = None,
                npu_hbm_bytes: Optional[float] = None) -> Tuple[int, int]:
    """Largest ``(data, model)`` logical shape on ``n_alive`` devices.

    While ``n_alive >= tp`` the model axis is kept at ``tp`` — elasticity
    flexes the *data* axis only (exactly the cost model's story: a defect
    draw shrinks the DP degree, never the MP group) and the DP degree is
    the largest value that both fits the survivors and divides the global
    batch.

    When the failure eats into the model axis itself (``n_alive < tp``)
    and ``model_cfg`` is given, the model axis is re-planned over the
    divisors of ``tp`` (largest first): a candidate ``tp'`` must divide
    the query heads, KV heads and FFN width (tensor-parallel layouts are
    tied to head/FFN divisibility), and — when ``shape`` and
    ``npu_hbm_bytes`` are also given — the resharded model must still fit
    per-NPU memory under the cost model's :class:`MemoryModel`.  Without
    ``model_cfg`` there is nothing safe to re-plan against and the
    shrink fails."""
    if tp < 1:
        raise ValueError(f"model axis must be ≥ 1, got tp={tp}")
    if n_alive < 1:
        raise ValueError(f"no surviving devices (n_alive={n_alive})")
    if n_alive >= tp:
        return _best_dp(n_alive, tp, global_batch), tp
    if model_cfg is None:
        raise ValueError(
            f"{n_alive} surviving devices cannot host the model axis of "
            f"{tp} — pass model_cfg to re-plan tp over its divisors, or "
            f"restore onto repaired hardware")
    from repro.core.placement import Strategy
    from repro.core.workloads import (MemoryModel, from_model_config,
                                      is_feasible)
    rejected = []
    for cand in (d for d in range(min(tp - 1, n_alive), 0, -1)
                 if tp % d == 0):
        if (model_cfg.n_heads % cand or model_cfg.n_kv_heads % cand
                or model_cfg.d_ff % cand):
            rejected.append(f"tp={cand}: heads/FFN not divisible")
            continue
        dp = _best_dp(n_alive, cand, global_batch)
        if shape is not None and npu_hbm_bytes is not None:
            w = from_model_config(model_cfg, shape,
                                  Strategy(mp=cand, dp=dp, pp=1))
            if not is_feasible(w, MemoryModel(npu_hbm_bytes=npu_hbm_bytes)):
                rejected.append(f"tp={cand}: exceeds per-NPU memory")
                continue
        return dp, cand
    detail = "; ".join(rejected) if rejected else "no divisor fits"
    raise ValueError(
        f"{n_alive} surviving devices cannot host the model axis of "
        f"{tp} and no smaller divisor works ({detail})")


def shrink_mesh(mesh, failed: Iterable, shape: ShapeConfig,
                cfg: Optional[ModelConfig] = None,
                npu_hbm_bytes: Optional[float] = None):
    """The largest valid ``(data, model)`` mesh on the devices surviving
    ``failed`` (device objects or device ids; duplicates are deduped
    before filtering, so a doubly-reported failure is one failure).

    The surviving devices keep their original mesh order, so DP replica 0
    stays on the same hardware whenever it survived — re-sharding moves
    the minimum number of bytes.  With ``cfg`` a failure that eats into
    the model axis re-plans ``tp`` over its valid divisors instead of
    failing (see :func:`plan_shrink`)."""
    from repro.launch.mesh import make_mesh
    failed_ids = frozenset(
        dict.fromkeys(getattr(d, "id", d) for d in failed))
    alive = [d for d in mesh.devices.flat if d.id not in failed_ids]
    tp = mesh.shape.get("model", 1)
    dp, tp = plan_shrink(len(alive), tp, shape.global_batch,
                         model_cfg=cfg, shape=shape,
                         npu_hbm_bytes=npu_hbm_bytes)
    return make_mesh((dp, tp), ("data", "model"), devices=alive[:dp * tp])


def resume_on_mesh(checkpoint_dir: str, cfg: ModelConfig, shape: ShapeConfig,
                   new_mesh, pcfg: Optional[ParallelConfig] = None,
                   ocfg: Optional[OptimConfig] = None,
                   step: Optional[int] = None) -> Tuple[CellSetup, Any, int]:
    """Build the setup for ``new_mesh`` and restore state onto it.

    Returns (setup, train_state, resumed_step).  Stale ``.tmp`` debris
    from a save interrupted by the failure is swept first — only
    committed checkpoints are ever restored."""
    validate_shape_for_mesh(shape, new_mesh)
    ckpt.cleanup_incomplete(checkpoint_dir)
    setup = make_train_setup(cfg, shape, new_mesh, pcfg, ocfg)
    state, extras = ckpt.restore(checkpoint_dir, setup.state_shapes,
                                 step=step,
                                 shardings=setup.state_shardings)
    return setup, state, int(extras.get("step", 0))


def resume_after_failure(checkpoint_dir: str, cfg: ModelConfig,
                         shape: ShapeConfig, mesh, failed: Iterable,
                         pcfg: Optional[ParallelConfig] = None,
                         ocfg: Optional[OptimConfig] = None,
                         step: Optional[int] = None
                         ) -> Tuple[CellSetup, Any, int, Any]:
    """One-call failure recovery: shrink, re-shard, resume.

    ``failed`` lists the dead devices (objects or ids) of ``mesh``; the
    survivors become the largest still-valid ``(data, model)`` mesh and
    the last committed checkpoint is restored onto it.  Returns
    (setup, train_state, resumed_step, new_mesh) — the caller re-enters
    its train loop under ``new_mesh`` with the DP degree dropped, or —
    when the failure ate into the model axis — with ``tp`` re-planned
    onto a smaller head/FFN-divisible divisor."""
    new_mesh = shrink_mesh(mesh, failed, shape, cfg=cfg)
    setup, state, at = resume_on_mesh(checkpoint_dir, cfg, shape, new_mesh,
                                      pcfg, ocfg, step=step)
    return setup, state, at, new_mesh
