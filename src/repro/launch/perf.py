import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=256")
# ^ first statements — jax locks device count on first init.

"""§Perf hillclimbing driver.

Runs the three chosen cells through named ParallelConfig variants
(hypothesis → change → re-lower → re-analyse), writing
``artifacts/perf/<cell>__<variant>.json`` records with the same roofline
schema as the dry-run.  The hypothesis text is stored in the record so
EXPERIMENTS.md §Perf can quote exactly what was predicted vs measured.

    PYTHONPATH=src python -m repro.launch.perf [--cell qwen3-32b:train_4k]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

# (cell, variant, hypothesis, pcfg overrides)
PLAN = [
    # ---- qwen3-32b train_4k: representative Megatron-style dense train ----
    ("qwen3-32b", "train_4k", "v1_no_tp_fsdp256",
     "TP=16 activations collectives (~4·B_loc·S·d·2B per layer ≈ 1.1TB/dev/"
     "step) dominate. Remapping the model axis to data parallelism (DP=256, "
     "pure FSDP; per-layer param gathers ≈ 0.2TB/dev) should cut the "
     "collective term ~5x. This is the paper's own thesis: the fabric must "
     "let the compiler pick the strategy.",
     {"tp_axis": "", "seq_shard": False}),
    ("qwen3-32b", "train_4k", "v2_no_tp_block_remat",
     "With remat=full the HLO recomputes the whole fwd (~8/6 model FLOPs). "
     "FSDP freed HBM; switching to remat=block (save projection outputs) "
     "should cut HLO flops ~20% and bytes-accessed, at +memory.",
     {"tp_axis": "", "seq_shard": False, "remat": "block"}),

    # ---- mixtral-8x7b train_4k: worst roofline fraction -------------------
    ("mixtral-8x7b", "train_4k", "v1_bucket_constraint",
     "Baseline replicated the (G,E,C,d) dispatch buckets across the model "
     "axis (f-sharded experts with unconstrained buckets): 206s collective "
     "term, 256GiB/dev. Pinning bucket sharding (G over data, f over model "
     "post-projection) turns the boundary into one all-to-all-class "
     "reshard; expect >5x collective reduction.",
     {}),
    ("mixtral-8x7b", "train_4k", "v2_no_tp_fsdp256",
     "8 experts cannot TP-shard over 16; with experts f-sharded every "
     "token's activations cross the model axis each layer. No-TP FSDP-256 "
     "keeps tokens local (experts fully replicated per device at bf16 "
     "1.3GB/layer gathers) — collective term should approach the dense-"
     "FSDP level (~4s).",
     {"tp_axis": "", "seq_shard": False}),

    # ---- round 2 ------------------------------------------------------------
    ("qwen3-32b", "train_4k", "v3_no_tp_big_attn_chunks",
     "Memory term (11.5s) now dominates; a large share is the online-"
     "softmax chunk-scan state (m,l,acc) round-tripping HBM per (q,k) "
     "block pair (the cost the Pallas flash kernel removes on real TPUs). "
     "Raising chunks (q=2048, k=4096) quarters the scan trip count; expect "
     "~20-30% bytes-accessed reduction.",
     {"tp_axis": "", "seq_shard": False,
      "attn_q_chunk": 2048, "attn_k_chunk": 4096}),
    ("mixtral-8x7b", "train_4k", "v3_no_tp_block_remat",
     "Same flops hypothesis as qwen3 v2: remat=block on the no-TP mapping "
     "should cut HLO flops ~20%; memory/dev will rise (23.9 -> ~55GiB?), "
     "likely past 16GB — measure the trade anyway.",
     {"tp_axis": "", "seq_shard": False, "remat": "block"}),
    ("arctic-480b", "train_4k", "v3_dense_residual_tp",
     "HLO shows 794GiB/dev of all-reduce: the dense-residual FFN had its "
     "contraction dim (d_model) FSDP-sharded over data, forcing partial-"
     "sum ARs of ~1M-token activations every layer. Re-sharding it as "
     "Megatron column/row TP (contraction unsharded) should remove most "
     "of that AR traffic (predict collective 52 -> ~20s).",
     {}),

    # ---- bonus sweep: does the strategy remap generalize? -----------------
    ("llava-next-34b", "train_4k", "v1_no_tp_fsdp256",
     "Same lever as qwen3: llava's 56 uneven heads make TP especially "
     "awkward (GSPMD pads to 64); no-TP FSDP-256 removes both the TP "
     "activation collectives and the padding waste.",
     {"tp_axis": "", "seq_shard": False}),
    ("chatglm3-6b", "train_4k", "v1_no_tp_fsdp256",
     "Generalization check on a mid-size dense arch with extreme GQA "
     "(kv=2, replicated under TP).",
     {"tp_axis": "", "seq_shard": False}),
    ("mamba2-1.3b", "train_4k", "v1_no_tp_fsdp256",
     "Attention-free control: SSD blocks have no TP all-reduces of "
     "attention activations, but the in/out projections still psum over "
     "model; expect a smaller but positive gain.",
     {"tp_axis": "", "seq_shard": False}),

    ("arctic-480b", "train_4k", "v4_ep_over_data",
     "v3 shows the remaining 1.35TB all-gather + ~1TB AR live at the "
     "token->expert boundary (G data-sharded vs E model-sharded: every "
     "shard pair exchanges bucket slices twice per layer). True EP — "
     "experts sharded over the DATA axis (128/16=8), hidden dim TP over "
     "model — makes dispatch a single all-to-all over data "
     "(~2.4GB/layer/dev) and expert compute a standard Megatron psum; "
     "predict collective 52 -> ~15-25s.",
     {"moe_ep_axis": "data"}),

    # ---- arctic-480b train_4k: most collective-bound ----------------------
    ("arctic-480b", "train_4k", "v1_bucket_constraint",
     "Dispatch buckets to model-sharded experts were being gathered to all "
     "shards (~37.6GB/layer/dev): pinning buckets to (data x model on G,E) "
     "makes the token->expert boundary an all-to-all (2.35GB/layer/dev), "
     "expect ~3-5x collective reduction.",
     {}),
    ("arctic-480b", "train_4k", "v2_seqshard_off",
     "SP resharding (seq<->heads transposes around every attention) adds "
     "all-to-alls without memory benefit at B_loc=16; disabling SP should "
     "trim collectives a few % with no memory regression.",
     {"seq_shard": False}),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None,
                    help="arch:shape filter, e.g. qwen3-32b:train_4k")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args(argv)

    from repro.launch.dryrun import run_cell

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    for arch, shape, variant, hypothesis, overrides in PLAN:
        if args.cell and f"{arch}:{shape}" != args.cell:
            continue
        name = f"{arch}__{shape}__{variant}"
        if (outdir / f"{name}.json").exists():
            print(f"[perf] {name}: cached", flush=True)
            continue
        try:
            rec = run_cell(arch, shape, "single", pcfg_overrides=overrides)
            rec["variant"] = variant
            rec["hypothesis"] = hypothesis
            rec["overrides"] = overrides
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "variant": variant,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
        (outdir / f"{name}.json").write_text(
            json.dumps(rec, indent=2, default=str))
        if rec["status"] == "ok":
            rf = rec["roofline"]
            print(f"[perf] {name}: frac={rf['roofline_fraction']:.4f} "
                  f"comp={rf['compute_s']:.2f} mem={rf['memory_s']:.2f} "
                  f"coll={rf['collective_s']:.2f} "
                  f"mem/dev={rec['memory_per_device']['total_bytes']/2**30:.1f}GiB",
                  flush=True)
        else:
            print(f"[perf] {name}: {rec['status']}", flush=True)


if __name__ == "__main__":
    main()
