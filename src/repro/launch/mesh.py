"""Production mesh construction (+ FRED-style device placement).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state — required for the
dry-run, which must set ``xla_force_host_platform_device_count`` before any
jax initialization.

Placement note (paper §V, option 4): FRED maps workers of the same MP group
onto *consecutive* physical NPUs, then PP, then DP.  On a TPU torus the
analogous property is "TP groups on ICI-contiguous chips", which
``jax.make_mesh`` already provides when ``model`` is the innermost axis —
the device order is row-major, so the 16 chips of one model group are
physically adjacent.  ``fred_device_order`` makes the policy explicit (and
testable) for arbitrary logical (mp, dp, pp) shapes, mirroring
``repro.core.placement`` which implements the same algorithm for the
wafer-scale simulator.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def _axis_kwargs(n_axes: int):
    """``axis_types`` exists from jax 0.5; omit it on older runtimes where
    every axis is Auto anyway."""
    import jax
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False, devices=None):
    """(16, 16) ``(data, model)`` single-pod or (2, 16, 16)
    ``(pod, data, model)`` multi-pod mesh."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if devices is not None:
        devs = np.asarray(devices).reshape(shape)
        return jax.sharding.Mesh(devs, axes)
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """Arbitrary mesh for tests/examples (e.g. (4,2) on 8 host devices)."""
    import jax
    if devices is not None:
        devs = np.asarray(devices).reshape(tuple(shape))
        return jax.sharding.Mesh(devs, tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def fred_device_order(n_devices: int, mp: int, dp: int, pp: int) -> np.ndarray:
    """FRED placement: worker (m, d, p) → physical NPU index.

    Workers of the same MP group sit on consecutive devices; MP groups of
    the same PP stage follow; DP replicas iterate outermost (paper Sec. V:
    "map the training workers within the same MP group on consecutive
    physical NPUs followed by iterating over workers within PP and DP").

    Returns an (mp, dp, pp) → device-id array.
    """
    assert mp * dp * pp <= n_devices
    order = np.zeros((mp, dp, pp), dtype=np.int64)
    nid = 0
    for d in range(dp):
        for p in range(pp):
            for m in range(mp):
                order[m, d, p] = nid
                nid += 1
    return order
