import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")
# ^ MUST be the first statements: jax locks the device count on first init.

"""Multi-pod dry-run driver (deliverable (e)).

For every (architecture × input shape) cell this lowers + compiles the
train/prefill/decode step on the production meshes:

  * single-pod: (data=16, model=16)   — 256 chips
  * multi-pod:  (pod=2, data=16, model=16) — 512 chips

and records ``memory_analysis()`` (proves the cell fits),
``cost_analysis()`` (FLOPs/bytes for §Roofline) and the collective schedule
parsed from optimized HLO (with ``known_trip_count`` scan multipliers).

Because ``cost_analysis`` counts a ``lax.scan`` body ONCE (verified
empirically — see DESIGN.md §7), the driver also compiles a single-layer
**probe** with identical shardings and reports trip-count-corrected totals.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path


def _build_mesh(kind: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             pcfg_overrides=None, probe: bool = True,
             autostrategy: bool = False) -> dict:
    """Lower + compile one cell; return the roofline record.

    ``autostrategy=True`` lets the FRED simulator sweep pick the cell's
    (mp, dp, pp, wafers) — the chosen strategy and the *why* (candidate /
    infeasible / dominated counts) are recorded under ``"autostrategy"``
    and the strategy is stamped on the recorded pcfg as a
    :class:`~repro.models.config.StrategyDecision` (the artifact's
    ``pcfg.auto_strategy`` is its named-field dict, not the legacy
    positional 5-list).  ``pcfg_overrides`` still win afterwards
    (§Perf hillclimbs)."""
    import jax
    from repro.configs.registry import get_config, shape_applicability
    from repro.models.config import SHAPES_BY_NAME
    from repro.parallel.steps import make_setup
    from repro.launch.roofline import (collect_cost, collective_bytes_from_hlo,
                                       roofline_terms)
    from repro.parallel.policy import cell_policy

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicability(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = _build_mesh(mesh_kind)
    auto_rec = None
    decision = None
    if autostrategy:
        from repro.core.autostrategy import choose
        from repro.core.specs import DeploymentRequest
        from repro.parallel.policy import paper_defaults
        pcfg0, ocfg0 = paper_defaults(cfg, shape)
        decision = choose(DeploymentRequest(
            model=cfg, shape=shape, master=ocfg0.master,
            moments_dtype=ocfg0.moments_dtype, remat=pcfg0.remat))
        d = decision
        auto_rec = {
            "chosen": {"mp": d.mp, "dp": d.dp, "pp": d.pp,
                       "wafers": d.wafers, "fabric": d.fabric,
                       "wafer_shape": list(d.wafer_shape),
                       "inter_topology": d.inter_topology,
                       "hierarchy": list(d.hierarchy),
                       "execution": d.execution},
            "time_per_sample_s": d.time_per_sample_s,
            "memory_bytes_per_npu": d.memory_bytes_per_npu,
            "npu_hbm_bytes": d.npu_hbm_bytes,
            "why": {"n_candidates": d.n_candidates,
                    "n_infeasible": d.n_infeasible,
                    "n_dominated": d.n_dominated},
            "sweep_seconds": round(d.sweep_seconds, 3),
        }
    pcfg, ocfg = cell_policy(cfg, shape, mesh, autostrategy=autostrategy,
                             decision=decision)
    if pcfg_overrides:
        pcfg = pcfg.replace(**pcfg_overrides)

    t0 = time.time()
    setup = make_setup(cfg, shape, mesh, pcfg, ocfg)
    with mesh:
        lowered = setup.step_fn.lower(*setup.example_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = collect_cost(compiled)
    hlo = compiled.as_text()
    colls = collective_bytes_from_hlo(hlo)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "kind": shape.kind,
        "n_devices": mesh.devices.size,
        "seconds": {"lower": round(t_lower, 2), "compile": round(t_compile, 2)},
        "memory_per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes +
                            mem.output_size_in_bytes +
                            mem.temp_size_in_bytes -
                            mem.alias_size_in_bytes),
        },
        "cost_analysis": cost,
        "collectives": colls,
        "pcfg": {k: v for k, v in dataclasses.asdict(pcfg).items()},
    }
    if auto_rec is not None:
        rec["autostrategy"] = auto_rec

    if probe:
        rec["probe"] = probe_layer_cost(cfg, shape, mesh, pcfg)
        rec["corrected"] = corrected_totals(rec, cfg)
    rec["roofline"] = roofline_terms(rec, cfg, shape)
    return rec


def probe_layer_cost(cfg, shape, mesh, pcfg) -> dict:
    """Compile the step on an L=1 copy and an L=2 copy of the arch with the
    same shardings; per-layer cost = cost(L2) − cost(L1), base = L1 − layer.
    This sidesteps cost_analysis's count-scan-body-once behaviour exactly."""
    import jax
    from repro.parallel.steps import make_setup
    from repro.launch.roofline import collect_cost, collective_bytes_from_hlo

    out = {}
    for L in (1, 2):
        c = dataclasses.replace(
            cfg, num_layers=L if cfg.family != "hybrid" else cfg.attn_every * L,
            n_enc_layers=min(cfg.n_enc_layers, L))
        setup = make_setup(c, shape, mesh, pcfg.replace(scan_layers=False))
        with mesh:
            compiled = setup.step_fn.lower(*setup.example_args).compile()
        cost = collect_cost(compiled)
        colls = collective_bytes_from_hlo(compiled.as_text())
        out[f"L{L}"] = {"cost": cost, "collective_bytes": colls["total_bytes"]}
    return out


def corrected_totals(rec, cfg) -> dict:
    """Trip-count-corrected FLOPs/bytes using the probe deltas."""
    p = rec.get("probe")
    if not p:
        return {}
    L = cfg.num_layers
    eff_layers = L // cfg.attn_every if cfg.family == "hybrid" else L
    l1, l2 = p["L1"], p["L2"]
    out = {}
    for key in ("flops", "bytes accessed"):
        per_layer = max(l2["cost"].get(key, 0) - l1["cost"].get(key, 0), 0)
        base = max(l1["cost"].get(key, 0) - per_layer, 0)
        out[key.replace(" ", "_")] = base + per_layer * eff_layers
    per_layer_coll = max(l2["collective_bytes"] - l1["collective_bytes"], 0)
    base_coll = max(l1["collective_bytes"] - per_layer_coll, 0)
    out["collective_bytes"] = base_coll + per_layer_coll * eff_layers
    return out


def ep_compare(arch: str = "mixtral-8x7b", n_devices: int = 8,
               seq: int = 16, d_model: int = 64, d_ff: int = 128) -> dict:
    """Measure the expert-parallel All-to-All against the analytical model.

    Compiles the explicit shard_map dispatch
    (:func:`repro.models.moe.moe_ffn_ep`) on a reduced copy of an MoE
    arch (host devices; one sequence per EP rank) and parses the
    optimized HLO for all-to-all wire bytes.  The expectation has two
    layers: the *bucket* payload 2·E·C·d (what the dispatch+combine
    exchange physically moves, capacity headroom included) should match
    the HLO exactly, and the cost model's *token* payload 2·T·k·d
    (``Workload.a2a_bytes_per_sample_layer`` per token, dispatch+combine)
    relates to it by the capacity factor — both ratios are recorded, and
    tests/test_multidevice.py pins the bucket ratio at 1."""
    import math as _math
    import numpy as _np
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config
    from repro.models.moe import init_moe, moe_ffn_ep, _v
    from repro.launch.roofline import collective_bytes_from_hlo

    base = get_config(arch)
    if not base.n_experts:
        raise ValueError(f"{arch} is not an MoE arch")
    cfg = dataclasses.replace(base, d_model=d_model, d_ff=d_ff,
                              moe_dense_ff=0)
    n = min(n_devices, len(jax.devices()), cfg.n_experts)
    mesh = Mesh(_np.array(jax.devices()[:n]), ("data",))
    params = {k: _v(v) for k, v in
              init_moe(jax.random.PRNGKey(0), cfg).items()}
    sharded = {"router": params["router"],
               **{k: jax.device_put(params[k],
                                    NamedSharding(mesh, P("data", None, None)))
                  for k in ("w_gate", "w_up", "w_down")}}
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (n, seq, d_model)),
        NamedSharding(mesh, P("data", None, None)))
    with mesh:
        compiled = jax.jit(
            lambda p, t: moe_ffn_ep(p, t, cfg, mesh=mesh, ep_axis="data")
        ).lower(sharded, x).compile()
    colls = collective_bytes_from_hlo(compiled.as_text())
    measured = colls["per_kind_bytes"].get("all-to-all", 0)

    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    T_l = seq                                 # tokens per EP rank
    capacity = max(int(_math.ceil(T_l * k * cf / E)), 4)
    capacity = -(-capacity // 4) * 4
    bucket_bytes = 2 * E * capacity * d_model * 4      # dispatch+combine, f32
    token_bytes = 2 * T_l * k * d_model * 4            # the cost-model payload
    return {
        "arch": arch, "n_devices": n, "seq": seq,
        "d_model": d_model, "d_ff": d_ff,
        "n_experts": E, "top_k": k, "capacity_factor": cf,
        "capacity": capacity,
        "measured_a2a_bytes_per_device": measured,
        "expected_bucket_bytes_per_device": bucket_bytes,
        "model_token_bytes_per_device": token_bytes,
        "measured_over_bucket": measured / bucket_bytes,
        "bucket_over_token": bucket_bytes / token_bytes,
        "per_kind_bytes": colls["per_kind_bytes"],
    }


def serving_compare(arch: str = "llama3.2-1b", *, prompt_tokens: int = 16,
                    output_tokens: int = 24, batch: int = 4,
                    d_model: int = 128, num_layers: int = 4,
                    vocab_size: int = 512) -> dict:
    """Measure real per-token decode latency against the analytical
    serving model (the PR-3 "rank-only serving" fix, measurement side).

    Runs the batched :class:`repro.serve.engine.Engine` on a reduced copy
    of ``arch`` (host CPU — absolute times are not comparable to wafer
    NPUs, so the record keeps both columns side by side rather than
    asserting a ratio) and records the measured decode-step latency
    distribution next to the analytical prefill/decode/p50/p99 the
    serving objective would quote for the *full* arch on wafer hardware.
    """
    import jax
    from repro.configs.registry import get_config
    from repro.core.autostrategy import SERVE_OBJECTIVE, \
        choose_serving_strategy
    from repro.core.specs import Objective
    from repro.models import transformer as tfm
    from repro.models.modules import split
    from repro.serve.engine import Engine, EngineConfig, Request

    base = get_config(arch)
    objective = Objective.serving(
        target_p99_ms=SERVE_OBJECTIVE.target_p99_ms,
        concurrent_users=SERVE_OBJECTIVE.concurrent_users,
        think_time_s=SERVE_OBJECTIVE.think_time_s,
        prompt_tokens=prompt_tokens, output_tokens=output_tokens)
    decision = choose_serving_strategy(base, objective)

    cfg = base.reduced(d_model=d_model, num_layers=num_layers,
                       vocab_size=vocab_size)
    params, _ = split(tfm.init(jax.random.PRNGKey(0), cfg))
    ecfg = EngineConfig(max_batch=batch,
                        cache_len=prompt_tokens + output_tokens,
                        target_p99_ms=objective.target_p99_ms,
                        arrival_rate_rps=(objective.concurrent_users /
                                          objective.think_time_s))
    engine = Engine(params, cfg, ecfg=ecfg)
    reqs = [Request(uid=i, prompt=list(range(1, prompt_tokens + 1)),
                    max_new_tokens=output_tokens) for i in range(batch)]
    engine.run_batch(reqs)
    steps = engine.decode_step_s[1:]       # drop the jit-compile step
    steps_sorted = sorted(steps)

    def _q(p):
        return steps_sorted[min(len(steps_sorted) - 1,
                                int(p * len(steps_sorted)))]

    return {
        "arch": arch, "status": "ok",
        "reduced": {"d_model": d_model, "num_layers": num_layers,
                    "vocab_size": vocab_size, "batch": batch,
                    "prompt_tokens": prompt_tokens,
                    "output_tokens": output_tokens},
        "measured": {
            "backend": jax.default_backend(),
            "n_decode_steps": len(steps),
            "decode_step_mean_s": sum(steps) / len(steps),
            "decode_step_p50_s": _q(0.50),
            "decode_step_p99_s": _q(0.99),
        },
        "analytical": {
            "placement": decision.placement,
            "wafers_per_cell": decision.wafers_per_cell,
            "total_wafers": decision.total_wafers,
            "prefill_s": decision.prefill_s,
            "decode_step_s": decision.decode_step_s,
            "ttft_p50_ms": decision.ttft_p50_ms,
            "ttft_p99_ms": decision.ttft_p99_ms,
            "target_p99_ms": decision.target_p99_ms,
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--autostrategy", action="store_true",
                    help="let the FRED simulator sweep pick (mp, dp, pp, "
                         "wafers) per cell; records the decision + "
                         "dominated/infeasible counts in the artifact")
    ap.add_argument("--serving", action="store_true",
                    help="run the batched serving engine on a reduced "
                         "llama3.2-1b and record measured per-token decode "
                         "latency next to the analytical serving-cell "
                         "p50/p99; writes <out>/serving_compare.json and "
                         "exits")
    ap.add_argument("--ep-compare", action="store_true",
                    help="compile the shard_map expert-parallel All-to-All "
                         "on a reduced MoE arch and diff the measured HLO "
                         "wire bytes against the analytical payload; writes "
                         "<out>/ep_compare.json and exits")
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    args = ap.parse_args(argv)

    if args.serving:
        outdir = Path(args.out)
        outdir.mkdir(parents=True, exist_ok=True)
        rec = serving_compare(args.arch or "llama3.2-1b")
        (outdir / "serving_compare.json").write_text(
            json.dumps(rec, indent=2, default=str))
        m, a = rec["measured"], rec["analytical"]
        print(f"[dryrun] serving {rec['arch']}: measured decode "
              f"p50={m['decode_step_p50_s'] * 1e3:.2f}ms "
              f"p99={m['decode_step_p99_s'] * 1e3:.2f}ms "
              f"({m['backend']}, reduced) | analytical cell "
              f"step={a['decode_step_s'] * 1e3:.3f}ms "
              f"ttft_p99={a['ttft_p99_ms']:.2f}ms "
              f"({a['placement']}, {a['total_wafers']} wafers)", flush=True)
        return 0

    if args.ep_compare:
        outdir = Path(args.out)
        outdir.mkdir(parents=True, exist_ok=True)
        rec = ep_compare(args.arch or "mixtral-8x7b")
        (outdir / "ep_compare.json").write_text(
            json.dumps(rec, indent=2, default=str))
        ok = abs(rec["measured_over_bucket"] - 1.0) < 0.01
        print(f"[dryrun] ep_compare {rec['arch']}: "
              f"measured/bucket={rec['measured_over_bucket']:.3f} "
              f"bucket/token={rec['bucket_over_token']:.3f} "
              f"{'OK' if ok else 'MISMATCH'}", flush=True)
        return 0 if ok else 1

    from repro.configs.registry import ARCH_IDS
    from repro.models.config import SHAPES

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                name = f"{arch}__{shape}__{mk}"
                path = outdir / f"{name}.json"
                try:
                    rec = run_cell(arch, shape, mk, probe=not args.no_probe,
                                   autostrategy=args.autostrategy)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mk,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                path.write_text(json.dumps(rec, indent=2, default=str))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mb = rec["memory_per_device"]["total_bytes"] / 2**30
                    extra = (f" mem/dev={mb:.2f}GiB "
                             f"compile={rec['seconds']['compile']}s")
                    if "autostrategy" in rec:
                        c = rec["autostrategy"]["chosen"]
                        topo = (f"+{c['inter_topology']}"
                                if c.get("inter_topology") else "")
                        extra += (f" auto=MP{c['mp']}-DP{c['dp']}-"
                                  f"PP{c['pp']}-W{c['wafers']}{topo}"
                                  f"@{c['fabric']}/{c['execution']}")
                print(f"[dryrun] {name}: {status}{extra}", flush=True)
    if failures:
        print(f"[dryrun] {failures} FAILURES", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
