"""Roofline-term extraction from compiled XLA artifacts.

Hardware model (task spec; TPU v5e-class chip):
  * 197 TFLOP/s bf16 peak per chip
  * 819 GB/s HBM bandwidth per chip
  * ~50 GB/s per ICI link

Terms (per the task spec, all in seconds):
  compute    = HLO_FLOPs  / (chips × peak)
  memory     = HLO_bytes  / (chips × HBM_bw)
  collective = coll_bytes / (chips × link_bw)

``cost_analysis()`` on an SPMD executable reports *per-partition* numbers,
so per-chip terms divide by the per-chip rate directly.

Collective bytes are NOT in cost_analysis; ``collective_bytes_from_hlo``
parses the optimized per-partition HLO, sums operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(sync or async-start), and multiplies ops inside ``while`` bodies by the
``known_trip_count`` XLA annotates — this is how per-layer collectives
inside the layer scan are counted L times.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CALLEE_RE = re.compile(r"(?:body|condition|calls|to_apply)=([%\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name → its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "{" in line and ("(" in line):
            m = re.match(r"(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?", stripped)
            if m:
                cur = m.group(1).lstrip("%")
                comps[cur] = []
                if "ENTRY" in line:
                    comps["__entry__"] = comps[cur]
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Execution-count multiplier per computation (while-body trip counts,
    propagated through nested calls).  Unknown trip counts default to 1."""
    edges: Dict[str, List[Tuple[str, int]]] = {k: [] for k in comps}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for ln in lines:
            trip = 1
            tm = _TRIP_RE.search(ln)
            if tm and " while(" in ln:
                trip = int(tm.group(1))
            for callee in _CALLEE_RE.findall(ln):
                callee = callee.lstrip("%")
                if callee in comps:
                    edges[name].append((callee, trip if "body=" in ln else 1))
    mult: Dict[str, int] = {}
    entry = comps.get("__entry__")
    entry_name = None
    for k, v in comps.items():
        if v is entry and k != "__entry__":
            entry_name = k
    if entry_name is None:  # fall back: treat every computation once
        return {k: 1 for k in comps}

    import collections
    mult = collections.defaultdict(int)
    stack = [(entry_name, 1)]
    seen_depth = 0
    while stack and seen_depth < 100000:
        seen_depth += 1
        name, m = stack.pop()
        mult[name] += m
        for callee, trip in edges.get(name, []):
            stack.append((callee, m * trip))
    return dict(mult)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_op_bytes(ln: str) -> Tuple[str, int]:
    """(kind, per-device wire bytes) for one collective instruction line.

    Optimized HLO prints operands as bare ``%name`` references, so sizes
    come from the *output* shape(s) on the LHS (including tuple elements).
    Per-device wire-byte model:
      all-gather          → output size (each chip receives all shards)
      all-reduce          → output size (ring ≈ 2·(n-1)/n·size; we follow
                            the task-spec "operand size" convention)
      reduce-scatter      → output × group size (input operand size)
      all-to-all          → output size
      collective-permute  → output size
    Returns ("", 0) if the line is not a (start of a) collective.
    """
    cm = _COLL_RE.search(ln)
    if not cm:
        return "", 0
    lhs, _, rhs = ln.partition("=")
    if "-done" in rhs[:60]:
        return "", 0
    kind = cm.group(1)
    # output shapes: between '=' and the op name occurrence
    out_region = rhs[:rhs.find(kind)]
    shapes = _SHAPE_RE.findall(out_region)
    nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    if kind == "reduce-scatter":
        gm = _GROUPS_RE.search(ln)
        if gm:
            nbytes *= int(gm.group(2))
    return kind, nbytes


def collective_bytes_from_hlo(hlo: str) -> dict:
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    per_kind: Dict[str, int] = {}
    count = 0
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1)
        for ln in lines:
            kind, nbytes = collective_op_bytes(ln)
            if not kind:
                continue
            per_kind[kind] = per_kind.get(kind, 0) + nbytes * m
            count += m
    return {"per_kind_bytes": per_kind,
            "total_bytes": sum(per_kind.values()),
            "op_count": count}


def collect_cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returned [dict]
        ca = ca[0]
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        if k in ca:
            keep[k] = float(ca[k])
    # per-operand bytes keys are noisy; keep the aggregate only
    return keep


# --------------------------------------------------------------------------
# model FLOPs & terms
# --------------------------------------------------------------------------

def param_counts(cfg) -> Tuple[int, int]:
    """(total, active) parameter counts, computed analytically."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    L = cfg.num_layers

    def attn_params():
        return d * (cfg.n_heads * cfg.head_dim) * 2 + \
            d * (cfg.n_kv_heads * cfg.head_dim) * 2

    def mlp_params(ff):
        return 3 * d * ff

    total = active = 2 * V * d if not cfg.tie_embeddings else V * d
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        per = d * (2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) \
            + di * d + 4 * (di + 2 * cfg.ssm_groups * cfg.ssm_state)
        total += per * L
        active += per * L
        if cfg.family == "hybrid":
            shared = attn_params() + mlp_params(f)
            uses = L // cfg.attn_every
            total += shared
            active += shared * uses   # applied `uses` times per token
    elif cfg.n_experts:
        per_expert = mlp_params(f)
        per_layer = attn_params() + cfg.n_experts * per_expert + d * cfg.n_experts
        per_layer_active = attn_params() + cfg.top_k * per_expert + d * cfg.n_experts
        if cfg.moe_dense_ff:
            per_layer += mlp_params(cfg.moe_dense_ff)
            per_layer_active += mlp_params(cfg.moe_dense_ff)
        total += per_layer * L
        active += per_layer_active * L
    else:
        per = attn_params() + mlp_params(f)
        total += per * L
        active += per * L
    if cfg.family == "audio":
        enc = (attn_params() + mlp_params(f)) * cfg.n_enc_layers
        # decoder cross-attention
        total += enc + attn_params() * L
        active += enc + attn_params() * L
    return int(total), int(active)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the task spec: 6·N·D train (N=active params,
    D=tokens), 2·N·D for single forward (prefill/decode)."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * active * tokens


def exposed_comm_s(comm_s: float, overlappable_compute_s: float) -> float:
    """Exposed (non-hidden) communication time under an overlap budget.

    The schedulable model: communication hides behind up to
    ``overlappable_compute_s`` of independent compute, and only the
    excess lands on the critical path.  This is the same
    ``max(0, comm − overlappable)`` identity the analytical cost model
    applies per phase (core/simulator.py ``comm_overlap_fraction``) —
    tests/test_fabric_sim.py pins the two implementations equal so the
    XLA-side roofline and the simulator cannot drift."""
    return max(0.0, comm_s - overlappable_compute_s)


def roofline_terms(rec: dict, cfg, shape,
                   comm_overlap_fraction: float = 0.0) -> dict:
    chips = rec.get("n_devices", 1)
    corrected = rec.get("corrected") or {}
    flops_pd = corrected.get("flops") or rec["cost_analysis"].get("flops", 0.0)
    bytes_pd = corrected.get("bytes_accessed") or \
        rec["cost_analysis"].get("bytes accessed", 0.0)
    coll_pd = corrected.get("collective_bytes") or \
        rec["collectives"]["total_bytes"]

    t_compute = flops_pd / PEAK_FLOPS
    t_memory = bytes_pd / HBM_BW
    t_collective = coll_pd / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_total = flops_pd * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model FLOPs over the time the dominant
    # term implies, relative to the all-chips peak
    frac = (mf / (chips * PEAK_FLOPS)) / bound if bound else 0.0
    return {**terms,
            "exposed_comm_s": exposed_comm_s(
                t_collective, comm_overlap_fraction * t_compute),
            "dominant": dominant.replace("_s", ""),
            "model_flops_total": mf,
            "hlo_flops_total": hlo_total,
            "useful_flops_ratio": useful,
            "roofline_fraction": frac}
