"""Pallas tree-reduction combiner — the R-µswitch analogue on TPU.

FRED's in-switch reduction sums N incoming streams *during routing*; the
TPU analogue is the on-chip combiner that reduce-scatter/all-reduce
implementations invoke on each arriving shard.  This kernel performs the
pairwise-tree summation of N stacked shards over VMEM-resident blocks with
fp32 accumulation (deterministic reduction order — unlike a naive serial
sum, the pairwise tree keeps error O(log N), which matters at N=512 pods).

ref oracle: ``ref_reduce`` (fp32 pairwise sum in jnp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...].astype(jnp.float32)            # (n, block)
    # pairwise tree reduction
    m = n
    while m > 1:
        half = m // 2
        x = x[:half] + x[half:2 * half] if m % 2 == 0 else \
            jnp.concatenate([x[:half] + x[half:2 * half], x[2 * half:]], 0)
        m = half + (m % 2)
    o_ref[...] = x[0].astype(o_ref.dtype)


def tree_reduce(shards: jnp.ndarray, *, block: int = 4096,
                interpret: bool = True) -> jnp.ndarray:
    """shards: (N, L) → (L,) sum with fp32 tree accumulation."""
    n, L = shards.shape
    block = min(block, L)
    nb = -(-L // block)
    pad = nb * block - L
    x = jnp.pad(shards, ((0, 0), (0, pad))) if pad else shards
    out = pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), shards.dtype),
        interpret=interpret,
    )(x)
    return out[:L]


def ref_reduce(shards: jnp.ndarray) -> jnp.ndarray:
    """Pairwise-tree fp32 oracle."""
    x = shards.astype(jnp.float32)
    m = x.shape[0]
    while m > 1:
        half = m // 2
        head = x[:half] + x[half:2 * half]
        x = head if m % 2 == 0 else jnp.concatenate([head, x[2 * half:]], 0)
        m = x.shape[0]
    return x[0].astype(shards.dtype)
