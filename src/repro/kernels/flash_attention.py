"""Pallas TPU flash attention (target: MXU + VMEM tiling).

Grid: (batch·heads, n_q_blocks, n_kv_blocks) — the last axis iterates
sequentially on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch and is carried across kv blocks; @pl.when guards initialize at
kv==0 and finalize at the last visited block.  Causal masking prunes
fully-masked kv blocks at trace time via the index map (no wasted MXU
cycles past the diagonal).

Block shapes default to (128, 128) q×kv tiles with the full head_dim in
the minor dimension — MXU-aligned for hd ∈ {64, 80, 128}.

Validated in interpret mode against ``ref.dense_attention`` over shape and
dtype sweeps (tests/test_kernels.py); the production fallback is the pure
jnp ``models.attention.chunked_attention`` (same math).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, kv_len: int, block_q: int,
            block_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, :, :] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)[:, None]
                          ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (B, S, H, hd) with H equal across q/k/v (repeat GQA first).

    Returns (B, S, H, hd) in q.dtype."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = -(-Sq // block_q), -(-Sk // block_k)

    def to_bh(x, S):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, x.shape[-1])

    qb, kb, vb = to_bh(q, Sq), to_bh(k, Sk), to_bh(v, Sk)
    pad_q, pad_k = nq * block_q - Sq, nk * block_k - Sk
    if pad_q:
        qb = jnp.pad(qb, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kb = jnp.pad(kb, ((0, 0), (0, pad_k), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               kv_len=Sk, block_q=block_q, block_k=block_k,
                               n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qb, kb, vb)
    out = out[:, :Sq].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)
