"""Pallas blockwise int8 quantize/dequantize (gradient compression path).

Same math as ``parallel.compress`` (its jnp functions are the oracle);
this kernel fuses amax + scale + round per VMEM block so the compressed
collective's quantization never round-trips HBM at fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (1, block)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0] = scale


def _dq_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0]).astype(
        x_ref.dtype)


def quantize(x: jnp.ndarray, block: int = 1024, *, interpret: bool = True):
    """x: (n,) → (q int8 (n,), scales fp32 (ceil(n/block),))."""
    n = x.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    xp = (jnp.pad(x, (0, pad)) if pad else x).reshape(nb, block)
    q, s = pl.pallas_call(
        _q_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q.reshape(-1)[:n], s


def dequantize(q: jnp.ndarray, scales: jnp.ndarray, block: int = 1024, *,
               out_dtype=jnp.float32, interpret: bool = True) -> jnp.ndarray:
    n = q.shape[0]
    nb = scales.shape[0]
    pad = nb * block - n
    qp = (jnp.pad(q, (0, pad)) if pad else q).reshape(nb, block)
    x = pl.pallas_call(
        _dq_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), out_dtype),
        interpret=interpret,
    )(qp, scales)
    return x.reshape(-1)[:n]
