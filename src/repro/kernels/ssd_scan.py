"""Pallas SSD (Mamba2) chunked scan — the intra-chunk quadratic dual plus
the cross-chunk state recurrence, carried in VMEM.

Grid: (batch·heads, n_chunks); the chunk axis iterates sequentially so the
(hd × N) SSM state lives in VMEM scratch across chunks (same carry pattern
as flash attention's online softmax).  Per chunk the kernel computes

    seg[i,j]   = exp(Σ_{k=j+1..i} dt_k·A)          (lower triangular)
    y_intra    = (C·Bᵀ ∘ seg ∘ dt) · x
    y_inter    = C · state_in  ∘ exp(cumsum dt·A)
    state_out  = decay_chunk · state_in + Σ_q B_q (dt_q·decayto_end_q) x_qᵀ

Inputs are pre-arranged to (B·H, S, ·) with B/C repeated per head (the
jnp oracle is ``models.ssm.ssd_chunked`` / ``ssd_reference``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    A = a_ref[0]                              # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    dA = dt * A                               # (Q,) ≤ 0
    cs = jnp.cumsum(dA)
    seg = cs[:, None] - cs[None, :]
    Q = dt.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :]
    y_intra = jax.lax.dot(M, x, preferred_element_type=jnp.float32)

    state_in = state_scr[...]                 # (hd, N)
    in_decay = jnp.exp(cs)                    # decay from chunk start
    y_inter = jax.lax.dot(Cm, state_in.T,
                          preferred_element_type=jnp.float32) * \
        in_decay[:, None]
    # wrong orientation guard: y_inter rows index Q, cols hd
    y_ref[0, :, :] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cs[-1] - cs)       # (Q,)
    contrib = jax.lax.dot_general(
        x * (dt * decay_to_end)[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (hd, N)
    state_scr[...] = jnp.exp(cs[-1]) * state_in + contrib


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk: int = 64,
             interpret: bool = True):
    """x: (B,S,H,hd); dt: (B,S,H); A: (H,); B/C: (B,S,G,N) with H%G==0.
    Returns y: (B,S,H,hd)."""
    Bsz, S, H, hd = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S

    xb = jnp.moveaxis(x, 2, 1).reshape(Bsz * H, S, hd)
    dtb = jnp.moveaxis(dt, 2, 1).reshape(Bsz * H, S)
    Bb = jnp.repeat(Bmat, rep, axis=2)
    Cb = jnp.repeat(Cmat, rep, axis=2)
    Bb = jnp.moveaxis(Bb, 2, 1).reshape(Bsz * H, S, N)
    Cb = jnp.moveaxis(Cb, 2, 1).reshape(Bsz * H, S, N)
    Ab = jnp.tile(A.astype(jnp.float32), Bsz)

    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
        dtb = jnp.pad(dtb, ((0, 0), (0, pad)))
        Bb = jnp.pad(Bb, ((0, 0), (0, pad), (0, 0)))
        Cb = jnp.pad(Cb, ((0, 0), (0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc),
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1,), lambda bh, c: (bh,)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz * H, nc * chunk, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xb, dtb, Ab, Bb, Cb)
    out = out[:, :S].reshape(Bsz, H, S, hd)
    return jnp.moveaxis(out, 1, 2)
