"""jit'd dispatch layer over the Pallas kernels.

On TPU (``use_pallas=True``) these call the compiled kernels; elsewhere
(and in all CPU tests) they run interpret-mode Pallas or the pure-jnp
reference — same semantics, identical signatures.  Model code goes through
this module only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention, repeat_kv
from . import flash_attention as _fa
from . import quant8 as _q8
from . import reduce_tree as _rt
from . import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def attention(q, k, v, *, causal: bool = True, use_pallas: bool = False):
    if k.shape[2] != q.shape[2]:
        k = repeat_kv(k, q.shape[2] // k.shape[2])
        v = repeat_kv(v, q.shape[2] // v.shape[2])
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   interpret=not _on_tpu())
    return chunked_attention(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ssd(x, dt, A, Bmat, Cmat, *, use_pallas: bool = False):
    if use_pallas:
        return _ssd.ssd_scan(x, dt, A, Bmat, Cmat,
                             interpret=not _on_tpu())
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bmat, Cmat)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def reduce_shards(shards, *, use_pallas: bool = False):
    if use_pallas:
        return _rt.tree_reduce(shards, interpret=not _on_tpu())
    return _rt.ref_reduce(shards)


def quantize(x, block: int = 1024, *, use_pallas: bool = False):
    if use_pallas:
        return _q8.quantize(x, block, interpret=not _on_tpu())
    from repro.parallel.compress import quantize as qref
    return qref(x, block)


def dequantize(q, scales, block: int = 1024, *, use_pallas: bool = False):
    if use_pallas:
        return _q8.dequantize(q, scales, block, interpret=not _on_tpu())
    from repro.parallel.compress import dequantize as dqref
    return dqref(q, scales, block)
